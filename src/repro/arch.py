"""Uniform architecture surface: every assigned arch (+ the paper's own
SqueezeNet) is an ``Arch`` with a family adapter providing abstract params,
state, and per-shape step functions.  configs/<id>.py files instantiate these;
launch/, tests/, and benchmarks/ consume only this API.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .models import convnets, diffusion, lm, vision
from .models.common import ParamSpec, param_count, spec


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | denoise_train | denoise_step | classify_train | classify_serve
    batch: int
    seq: int = 0  # LM sequence / KV-cache length
    img: int = 0  # image resolution (pixel space)
    steps: int = 0  # sampler steps (documentation; one step is lowered)


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str  # lm | dit | flux | vit | swin | resnet | effnet | squeezenet
    cfg: Any
    shapes: tuple[ShapeSpec, ...]
    notes: str = ""
    # Per-arch overrides merged into the mesh sharding rule table (e.g. flux:
    # 24 heads don't divide the 16-way model axis, so head_dim sharding only
    # forces qkv re-gathers — replicate attention weights instead).
    sharding_overrides: dict | None = None

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name!r}; have {[s.name for s in self.shapes]}")


# Family adapters ------------------------------------------------------------

_HAS_STATE = {"resnet", "effnet"}


def abstract_params(arch: Arch):
    f = arch.family
    if f == "lm":
        return lm.abstract_params(arch.cfg), {}
    if f == "dit":
        return diffusion.dit_abstract_params(arch.cfg), {}
    if f == "flux":
        return diffusion.flux_abstract_params(arch.cfg), {}
    if f == "vit":
        return vision.vit_abstract_params(arch.cfg), {}
    if f == "swin":
        return vision.swin_abstract_params(arch.cfg), {}
    if f == "resnet":
        return convnets.resnet_abstract(arch.cfg)
    if f == "effnet":
        return convnets.effnet_abstract(arch.cfg)
    if f == "squeezenet":
        return convnets.squeezenet_abstract(arch.cfg)
    raise ValueError(f"unknown family {f}")


def classifier_forward(arch: Arch, params, state, images, *, train: bool):
    f = arch.family
    if f == "vit":
        return vision.vit_forward(arch.cfg, params, images), state
    if f == "swin":
        return vision.swin_forward(arch.cfg, params, images), state
    if f == "resnet":
        return convnets.resnet_forward(arch.cfg, params, state, images, train=train)
    if f == "effnet":
        return convnets.effnet_forward(arch.cfg, params, state, images, train=train)
    if f == "squeezenet":
        return convnets.squeezenet_forward(arch.cfg, params, state, images, train=train)
    raise ValueError(f"{f} is not a classifier family")


def n_params(arch: Arch) -> int:
    return param_count(abstract_params(arch)[0])


# Input specs per (arch, shape) ----------------------------------------------


def _img_latent(arch: Arch, img: int) -> tuple[int, int]:
    if arch.family == "dit":
        return img // 8, arch.cfg.in_ch
    if arch.family == "flux":
        return img // 8, arch.cfg.in_ch
    raise ValueError


def input_specs(arch: Arch, shape: ShapeSpec) -> dict[str, ParamSpec]:
    """Abstract (ShapeDtypeStruct-convertible) batch inputs with logical axes.

    Returned as ParamSpec so launch/ can derive both ShapeDtypeStructs and
    shardings from one object.
    """
    B = shape.batch
    f = arch.family
    if f == "lm":
        if shape.kind == "train":
            return {
                "tokens": spec((B, shape.seq), ("batch", "seq"), dtype=jnp.int32, init="zeros"),
                "labels": spec((B, shape.seq), ("batch", "seq"), dtype=jnp.int32, init="zeros"),
            }
        if shape.kind == "prefill":
            return {"tokens": spec((B, shape.seq), ("batch", "seq"), dtype=jnp.int32, init="zeros")}
        if shape.kind == "decode":
            return {"token": spec((B, 1), ("batch", None), dtype=jnp.int32, init="zeros")}
    if f in ("dit", "flux"):
        lat, ch = _img_latent(arch, shape.img)
        base = {
            "x": spec((B, lat, lat, ch), ("batch", None, None, None)),
            "t": spec((B,), ("batch",)),
        }
        if f == "dit":
            base["y"] = spec((B,), ("batch",), dtype=jnp.int32, init="zeros")
        else:
            base["txt"] = spec((B, arch.cfg.txt_len, arch.cfg.txt_dim), ("batch", None, None))
            base["vec"] = spec((B, arch.cfg.vec_dim), ("batch", None))
            base["guidance"] = spec((B,), ("batch",))
        if shape.kind == "denoise_train":
            base["noise"] = spec((B, lat, lat, ch), ("batch", None, None, None))
        else:
            base["dt"] = spec((B,), ("batch",))
        return base
    if f in ("vit", "swin", "resnet", "effnet", "squeezenet"):
        base = {"images": spec((B, shape.img, shape.img, 3), ("batch", "spatial", None, None))}
        if shape.kind == "classify_train":
            base["labels"] = spec((B,), ("batch",), dtype=jnp.int32, init="zeros")
        return base
    raise ValueError(f"no input spec for {arch.name}/{shape.name}")


def make_inputs(arch: Arch, shape: ShapeSpec, key=None) -> dict[str, jax.Array]:
    """Concrete (small-scale test) inputs for smoke tests."""
    key = key if key is not None else jax.random.key(0)
    specs = input_specs(arch, shape)
    out = {}
    for i, (name, s) in enumerate(sorted(specs.items())):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = 1000
            if arch.family == "lm":
                hi = arch.cfg.vocab
            elif name == "y":
                hi = arch.cfg.n_classes
            elif name == "labels":
                hi = getattr(arch.cfg, "n_classes", 1000)
            out[name] = jax.random.randint(k, s.shape, 0, hi, dtype=s.dtype)
        else:
            if name == "t":
                out[name] = jax.random.uniform(k, s.shape, s.dtype, 0.02, 0.98)
            elif name == "dt":
                out[name] = jnp.full(s.shape, 0.02, s.dtype)
            elif name == "guidance":
                out[name] = jnp.full(s.shape, 4.0, s.dtype)
            else:
                out[name] = jax.random.normal(k, s.shape, s.dtype)
    return out
