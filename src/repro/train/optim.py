"""AdamW + schedules, from scratch (no optax in this environment).

Optimizer state is a pytree mirroring params: {"m": ..., "v": ...} in f32,
plus a scalar step.  ``adamw_update`` is pure and jit-friendly; the sharding
of m/v follows the param shardings (ZeRO-1/2 falls out of the FSDP param
rules for free, since state mirrors the sharded master params).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(c: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip(
        (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0
    )
    cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(c: AdamWConfig, params: Any, grads: Any, opt: dict) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9)) if c.grad_clip else 1.0
    lr = lr_at(c, step)
    b1t = 1 - c.b1 ** step.astype(jnp.float32)
    b2t = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = c.b1 * m + (1 - c.b1) * g
        v2 = c.b2 * v + (1 - c.b2) * g * g
        mhat = m2 / b1t
        vhat = v2 / b2t
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (mhat / (jnp.sqrt(vhat) + c.eps) + c.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
