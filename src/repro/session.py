"""The front door: declarative scenarios + one Session facade for every run mode.

A :class:`ScenarioSpec` describes a whole experiment — stream shape, model
profiles, network trace, scheduling policy (a registry ``PolicySpec``), and
optional multi-tenant fleet options — and round-trips through JSON, so an
experiment is a file, not a script.  :class:`Session` routes one spec to any
of the four execution engines behind a uniform :class:`RunReport`:

    run_sim      single stream through the audited simulator (§VI figures)
    run_multi    N streams on a shared fluid uplink + edge server
    run_online   the OnlineController with *estimated* bandwidth, audited
                 against the true trace (the deployable configuration)
    run_serving  real JAX models behind the controller (launch/serve stack)

Quickstart::

    from repro.core.registry import PolicySpec
    from repro.session import ScenarioSpec, Session

    spec = ScenarioSpec(policy=PolicySpec("max_accuracy"), n_frames=120)
    report = Session(spec).run_sim()
    print(report.stats.mean_accuracy)

or from the shell (the CI smoke path)::

    PYTHONPATH=src python -m repro.session scenario.json --mode sim

Adding a policy is one ``@register_policy`` decorator; adding a scenario is
one JSON file — nothing else re-plumbs profiles, traces, or kwargs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

from .core.controller import BandwidthEstimator, OnlineController
from .core.edge_server import ALLOCATION_POLICIES, EdgeServerScheduler, make_fleet
from .core.profiles import PAPER_MODELS, ModelProfile, StreamSpec
from .core.registry import PolicySpec, available_policies
from .core.schedule import StreamStats, Where, validate_plan
from .core.simulator import Trace, simulate, simulate_multi

__all__ = [
    "FleetSpec",
    "RunReport",
    "ScenarioSpec",
    "Session",
    "TraceSpec",
]

_PRESET_MODELS: dict[str, ModelProfile] = {m.name: m for m in PAPER_MODELS}


# ---------------------------------------------------------------------------
# Serializable pieces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceSpec:
    """Declarative network trace: constant or piecewise bandwidth over time."""

    kind: str = "constant"  # "constant" | "piecewise"
    mbps: float = 2.5
    rtt_ms: float = 100.0
    points: tuple[tuple[float, float], ...] = ()  # [(t_start_s, mbps), ...]

    def __post_init__(self) -> None:
        if self.kind not in ("constant", "piecewise"):
            raise ValueError(f"unknown trace kind {self.kind!r}; want constant|piecewise")
        if self.kind == "piecewise" and not self.points:
            raise ValueError("piecewise trace needs at least one (t_start, mbps) point")
        # Normalize fields the active kind does not use, so equality (and the
        # JSON round-trip, which only serializes the active fields) is exact.
        if self.kind == "constant":
            object.__setattr__(self, "points", ())
        else:
            object.__setattr__(self, "mbps", 2.5)
            object.__setattr__(
                self, "points", tuple((float(t), float(v)) for t, v in self.points)
            )

    def build(self) -> Trace:
        if self.kind == "piecewise":
            return Trace.piecewise(list(self.points), rtt_ms=self.rtt_ms)
        return Trace.constant(self.mbps, rtt_ms=self.rtt_ms)

    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind, "rtt_ms": self.rtt_ms}
        if self.kind == "constant":
            out["mbps"] = self.mbps
        else:
            out["points"] = [list(p) for p in self.points]
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "TraceSpec":
        return TraceSpec(
            kind=str(data.get("kind", "constant")),
            mbps=float(data.get("mbps", 2.5)),
            rtt_ms=float(data.get("rtt_ms", 100.0)),
            points=tuple((float(t), float(v)) for t, v in data.get("points", ())),
        )


@dataclass(frozen=True)
class FleetSpec:
    """Multi-tenant options for ``run_multi``: N clients, one edge server."""

    n_clients: int = 2
    allocation: str = "weighted_fair"  # see edge_server.ALLOCATION_POLICIES
    capacity: int = 4
    backlog_limit: float = 0.0
    weights: tuple[float, ...] | None = None
    priorities: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if self.n_clients < 1:
            raise ValueError("fleet needs n_clients >= 1")
        if self.allocation not in ALLOCATION_POLICIES:
            raise ValueError(
                f"unknown allocation {self.allocation!r}; want one of {ALLOCATION_POLICIES}"
            )
        for name in ("weights", "priorities"):
            v = getattr(self, name)
            if v is not None:
                v = tuple(v)
                object.__setattr__(self, name, v)
                if len(v) != self.n_clients:
                    raise ValueError(f"{name} must have n_clients={self.n_clients} entries")

    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "n_clients": self.n_clients,
            "allocation": self.allocation,
            "capacity": self.capacity,
            "backlog_limit": self.backlog_limit,
        }
        if self.weights is not None:
            out["weights"] = list(self.weights)
        if self.priorities is not None:
            out["priorities"] = list(self.priorities)
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "FleetSpec":
        return FleetSpec(
            n_clients=int(data.get("n_clients", 2)),
            allocation=str(data.get("allocation", "weighted_fair")),
            capacity=int(data.get("capacity", 4)),
            backlog_limit=float(data.get("backlog_limit", 0.0)),
            weights=tuple(data["weights"]) if data.get("weights") is not None else None,
            priorities=tuple(data["priorities"]) if data.get("priorities") is not None else None,
        )


def _model_to_json(m: ModelProfile) -> Any:
    """Presets serialize by name; custom profiles serialize in full."""
    preset = _PRESET_MODELS.get(m.name)
    if preset == m:
        return m.name
    return {
        "name": m.name,
        "t_npu_ms": m.t_npu * 1e3 if m.t_npu != float("inf") else None,
        "t_server_ms": m.t_server * 1e3 if m.t_server != float("inf") else None,
        "acc_server": {str(r): a for r, a in m.acc_server.items()},
        "acc_npu": {str(r): a for r, a in m.acc_npu.items()},
    }


def _model_from_json(data: Any) -> ModelProfile:
    if isinstance(data, ModelProfile):
        return data
    if isinstance(data, str):
        try:
            return _PRESET_MODELS[data]
        except KeyError:
            raise ValueError(
                f"unknown model preset {data!r}; presets: {sorted(_PRESET_MODELS)}"
            ) from None
    if not isinstance(data, Mapping) or "name" not in data:
        raise ValueError(f"not a model payload: {data!r}")
    t_npu = data.get("t_npu_ms")
    t_server = data.get("t_server_ms")
    return ModelProfile(
        name=str(data["name"]),
        t_npu=float(t_npu) / 1e3 if t_npu is not None else float("inf"),
        t_server=float(t_server) / 1e3 if t_server is not None else float("inf"),
        acc_server={int(r): float(a) for r, a in (data.get("acc_server") or {}).items()},
        acc_npu={int(r): float(a) for r, a in (data.get("acc_npu") or {}).items()},
    )


def _stream_to_json(s: StreamSpec) -> dict[str, Any]:
    return {
        "fps": s.fps,
        "deadline_ms": s.deadline * 1e3,
        "resolutions": list(s.resolutions),
        "png_ratio": s.png_ratio,
    }


def _stream_from_json(data: Mapping[str, Any]) -> StreamSpec:
    base = StreamSpec()
    return StreamSpec(
        fps=float(data.get("fps", base.fps)),
        deadline=float(data.get("deadline_ms", base.deadline * 1e3)) / 1e3,
        resolutions=tuple(int(r) for r in data.get("resolutions", base.resolutions)),
        png_ratio=float(data.get("png_ratio", base.png_ratio)),
    )


@dataclass(frozen=True)
class ScenarioSpec:
    """One experiment, declaratively: who streams what, over which network,
    scheduled by which policy.  JSON round-trippable (``to_json``/``from_json``)
    so benchmark sweeps and CI smoke runs are reproducible artifacts.

    ``models`` entries may be preset names (``"resnet-50"``/``"squeezenet"``)
    or full :class:`ModelProfile` objects; they normalize to profiles.
    ``fleet`` is only consulted by ``run_multi``; ``seed`` only by serving.
    """

    policy: PolicySpec
    n_frames: int = 120
    stream: StreamSpec = field(default_factory=StreamSpec)
    models: tuple[ModelProfile, ...] = ("resnet-50", "squeezenet")  # type: ignore[assignment]
    trace: TraceSpec = field(default_factory=TraceSpec)
    fleet: FleetSpec | None = None
    strict: bool = True
    seed: int = 0
    label: str = ""

    def __post_init__(self) -> None:
        if isinstance(self.policy, (str, Mapping)):
            spec = (
                PolicySpec(self.policy)
                if isinstance(self.policy, str)
                else PolicySpec.from_json(self.policy)
            )
            object.__setattr__(self, "policy", spec)
        if self.n_frames < 1:
            raise ValueError("n_frames must be >= 1")
        object.__setattr__(
            self, "models", tuple(_model_from_json(m) for m in self.models)
        )
        if not self.models:
            raise ValueError("scenario needs at least one model")

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "policy": self.policy.to_json(),
            "n_frames": self.n_frames,
            "stream": _stream_to_json(self.stream),
            "models": [_model_to_json(m) for m in self.models],
            "trace": self.trace.to_json(),
            "strict": self.strict,
            "seed": self.seed,
        }
        if self.fleet is not None:
            out["fleet"] = self.fleet.to_json()
        if self.label:
            out["label"] = self.label
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any] | str) -> "ScenarioSpec":
        if isinstance(data, str):
            data = json.loads(data)
        if not isinstance(data, Mapping) or "policy" not in data:
            raise ValueError("not a ScenarioSpec payload (missing 'policy')")
        return ScenarioSpec(
            policy=PolicySpec.from_json(data["policy"]),
            n_frames=int(data.get("n_frames", 120)),
            stream=_stream_from_json(data.get("stream") or {}),
            models=tuple(data.get("models") or ("resnet-50", "squeezenet")),
            trace=TraceSpec.from_json(data.get("trace") or {}),
            fleet=FleetSpec.from_json(data["fleet"]) if data.get("fleet") else None,
            strict=bool(data.get("strict", True)),
            seed=int(data.get("seed", 0)),
            label=str(data.get("label", "")),
        )


# ---------------------------------------------------------------------------
# Uniform result wrapper
# ---------------------------------------------------------------------------


@dataclass
class RunReport:
    """What every run mode returns: audited per-stream stats + metadata."""

    mode: str
    spec: ScenarioSpec
    streams: list[StreamStats]
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def stats(self) -> StreamStats:
        """The single stream's stats (modes sim/online; first client in multi)."""
        return self.streams[0]

    @property
    def aggregate_accuracy(self) -> float:
        total = sum(s.frames_total for s in self.streams)
        return sum(s.accuracy_sum for s in self.streams) / total if total else 0.0

    @property
    def max_miss_rate(self) -> float:
        return max(
            (s.frames_missed_deadline / s.frames_total for s in self.streams if s.frames_total),
            default=0.0,
        )

    def to_json(self) -> dict[str, Any]:
        return {
            "mode": self.mode,
            "label": self.spec.label,
            "policy": self.spec.policy.to_json(),
            "streams": [dataclasses.asdict(s) for s in self.streams],
            "aggregate_accuracy": self.aggregate_accuracy,
            "max_miss_rate": self.max_miss_rate,
            "meta": self.meta,
        }


# ---------------------------------------------------------------------------
# Session facade
# ---------------------------------------------------------------------------


class Session:
    """Routes one :class:`ScenarioSpec` to any execution engine.

    Engines share the spec's policy/models/stream/trace; they differ in what
    the world looks like (one stream, a contended fleet, estimated bandwidth,
    or real JAX models).  Every mode returns a :class:`RunReport`.
    """

    MODES = ("sim", "multi", "online", "serving")

    def __init__(self, spec: ScenarioSpec):
        self.spec = spec

    def run(self, mode: str = "sim") -> RunReport:
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {self.MODES}")
        return getattr(self, f"run_{mode}")()

    # -- mode: audited single-stream simulation ----------------------------
    def run_sim(self) -> RunReport:
        spec = self.spec
        stats = simulate(
            spec.policy.build(),
            list(spec.models),
            spec.stream,
            spec.trace.build(),
            spec.n_frames,
            strict=spec.strict,
        )
        return RunReport("sim", spec, [stats], meta={"policy": spec.policy.name})

    # -- mode: N streams, shared fluid uplink + edge server ----------------
    def run_multi(self) -> RunReport:
        spec = self.spec
        fleet = spec.fleet if spec.fleet is not None else FleetSpec()
        clients = make_fleet(
            fleet.n_clients,
            stream=spec.stream,
            models=list(spec.models),
            policy=spec.policy,
            weights=fleet.weights,
            priorities=fleet.priorities,
        )
        sched = EdgeServerScheduler(
            clients,
            policy=fleet.allocation,
            capacity=fleet.capacity,
            backlog_limit=fleet.backlog_limit,
        )
        ms = simulate_multi(sched, spec.trace.build(), spec.n_frames, strict=spec.strict)
        return RunReport(
            "multi",
            spec,
            ms.per_client,
            meta={
                "allocation": fleet.allocation,
                "server_jobs": ms.server_jobs,
                "server_utilization": ms.server_utilization,
                "grants": sched.audit.grants,
                "denials": sched.audit.denials,
            },
        )

    # -- mode: online controller with estimated bandwidth ------------------
    def run_online(self) -> RunReport:
        """Drive :class:`OnlineController` over the trace: the policy sees
        only the EWMA estimator's belief (fed back from the uploads the plans
        actually perform), while the audit uses the *true* trace — offload
        finish times are recomputed at real bandwidth, so an optimistic
        estimate shows up as deadline misses, exactly as in deployment."""
        spec = self.spec
        models = list(spec.models)
        stream = spec.stream
        trace = spec.trace.build()
        gamma, deadline = stream.gamma, stream.deadline
        controller = OnlineController(
            models=models,
            stream=stream,
            policy=spec.policy,
            estimator=BandwidthEstimator(init_bps=trace.at(0.0).bandwidth_bps),
        )
        controller.estimator.observe_rtt(trace.at(0.0).rtt)
        stats = StreamStats(frames_total=spec.n_frames, elapsed=spec.n_frames * gamma)
        head = 0
        net_free_abs = 0.0  # true-link serial occupancy
        while head < spec.n_frames:
            t0 = head * gamma
            true_net = trace.at(t0)
            wall = time.perf_counter()
            plan = controller.next_plan(head)
            stats.schedule_time += time.perf_counter() - wall
            stats.schedule_calls += 1
            horizon = max(plan.horizon, 1)

            npu_only = dataclasses.replace(
                plan, decisions=[d for d in plan.decisions if d.where is Where.NPU]
            )
            errors = (
                validate_plan(npu_only, gamma=gamma, deadline=deadline) if spec.strict else []
            )
            bad = {e.frame for e in errors}

            for d in plan.decisions:
                if d.frame >= horizon or head + d.frame >= spec.n_frames:
                    continue
                if not d.is_processed():
                    continue
                m = models[d.model]
                if d.where is Where.NPU:
                    if d.frame in bad:
                        continue
                    stats.frames_processed += 1
                    stats.accuracy_sum += m.accuracy(stream.r_max, where="npu")
                else:
                    arrival_abs = t0 + d.frame * gamma
                    nbytes = stream.frame_bytes(d.resolution)
                    t_up = true_net.upload_time(nbytes)
                    start = max(net_free_abs, t0 + max(d.start, 0.0))
                    finish = start + t_up + true_net.rtt + m.t_server
                    net_free_abs = start + t_up
                    controller.report_upload(nbytes, t_up)
                    controller.report_rtt(true_net.rtt)
                    if finish <= arrival_abs + deadline + 1e-9:
                        stats.frames_processed += 1
                        stats.frames_offloaded += 1
                        stats.accuracy_sum += m.accuracy(d.resolution, where="server")
                    else:
                        stats.frames_missed_deadline += 1
            stats.frames_missed_deadline += len(bad)
            head += horizon
        return RunReport(
            "online",
            spec,
            [stats],
            meta={
                "rounds": controller.rounds,
                "estimated_bps": controller.estimator.state().bandwidth_bps,
            },
        )

    # -- mode: real models behind the controller ---------------------------
    def run_serving(self) -> RunReport:
        """Stand up the real-model serving stack (launch/serve) for this
        scenario: trains/quantizes the classifier pair, profiles it live, and
        runs the controller over a synthetic labeled video."""
        from .launch.serve import run_scenario  # heavy deps; import lazily

        summary = run_scenario(self.spec)
        frames = int(summary.get("frames", 0))
        stats = StreamStats(
            frames_total=self.spec.n_frames,
            frames_processed=frames,
            frames_missed_deadline=int(round((1.0 - summary.get("deadline_met_frac", 1.0)) * frames)),
            frames_offloaded=int(summary.get("edge_frames", 0)),
            accuracy_sum=float(summary.get("accuracy", 0.0)) * frames,
            elapsed=self.spec.n_frames * self.spec.stream.gamma,
            schedule_calls=int(summary.get("scheduler_rounds", 0)),
        )
        return RunReport("serving", self.spec, [stats], meta=summary)


# ---------------------------------------------------------------------------
# CLI: one ScenarioSpec JSON in, one RunReport JSON out.
# ---------------------------------------------------------------------------

_EXAMPLE = ScenarioSpec(
    policy=PolicySpec("max_accuracy"),
    n_frames=90,
    trace=TraceSpec(mbps=2.5),
    label="example",
)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.session",
        description="Run a declarative FastVA scenario (ScenarioSpec JSON).",
    )
    ap.add_argument("spec", nargs="?", help="path to ScenarioSpec JSON, or '-' for stdin")
    ap.add_argument("--mode", default="sim", choices=Session.MODES)
    ap.add_argument("--list-policies", action="store_true", help="list registered policies and exit")
    ap.add_argument("--example", action="store_true", help="print an example spec JSON and exit")
    args = ap.parse_args(argv)

    if args.list_policies:
        for name in available_policies():
            print(name)
        return 0
    if args.example:
        print(json.dumps(_EXAMPLE.to_json(), indent=2))
        return 0
    if not args.spec:
        ap.error("need a spec path (or --list-policies / --example)")
    payload = sys.stdin.read() if args.spec == "-" else open(args.spec).read()
    spec = ScenarioSpec.from_json(payload)
    report = Session(spec).run(args.mode)
    print(json.dumps(report.to_json(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
