"""The front door: declarative scenarios + one Session facade for every run mode.

A :class:`ScenarioSpec` describes a whole experiment — stream shape, model
profiles, network trace, scheduling policy (a registry ``PolicySpec``), and
optional multi-tenant fleet options — and round-trips through JSON, so an
experiment is a file, not a script.  :class:`Session` routes one spec to any
of the four execution engines behind a uniform :class:`RunReport`:

    run_sim      single stream through the audited simulator (§VI figures)
    run_multi    N streams on a shared fluid uplink + edge server
    run_online   the OnlineController with *estimated* bandwidth, audited
                 against the true trace (the deployable configuration)
    run_serving  real JAX models behind the controller (launch/serve stack)
    run_sweep    a whole (bandwidth x deadline x fps x fleet x policy-param)
                 grid in one call — vectorized on device for ``batched=True``
                 policies, reference loop otherwise (docs/simulation.md)

Quickstart::

    from repro.core.registry import PolicySpec
    from repro.session import ScenarioSpec, Session

    spec = ScenarioSpec(policy=PolicySpec("max_accuracy"), n_frames=120)
    report = Session(spec).run_sim()
    print(report.stats.mean_accuracy)

or from the shell (the CI smoke path)::

    PYTHONPATH=src python -m repro.session scenario.json --mode sim

Adding a policy is one ``@register_policy`` decorator; adding a scenario is
one JSON file — nothing else re-plumbs profiles, traces, or kwargs.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import logging
import sys
import time
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Sequence

from .core import sim_batch, sim_multi_batch, sim_online_batch
from .core.audit import AUDIT_TOL, apply_round, audit_round
from .core.compile_cache import default_cache_dir, enable_compile_cache
from .core.controller import BandwidthEstimator, OnlineController
from .core.edge_server import ALLOCATION_POLICIES, EdgeServerScheduler, make_fleet
from .core.profiles import PAPER_MODELS, ModelProfile, StreamSpec
from .core.registry import PolicySpec, available_policies, get_policy
from .core.schedule import StreamStats
from .core.simulator import Trace, simulate, simulate_multi
from .core.tracking import WorkloadSpec

__all__ = [
    "FleetSpec",
    "RunReport",
    "ScenarioSpec",
    "Session",
    "SweepGrid",
    "SweepPoint",
    "SweepReport",
    "SweepSummary",
    "TraceSpec",
    "WorkloadSpec",
]

_PRESET_MODELS: dict[str, ModelProfile] = {m.name: m for m in PAPER_MODELS}
_LOG = logging.getLogger("repro.session")


# ---------------------------------------------------------------------------
# Serializable pieces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceSpec:
    """Declarative network trace: constant or piecewise bandwidth over time."""

    kind: str = "constant"  # "constant" | "piecewise"
    mbps: float = 2.5
    rtt_ms: float = 100.0
    points: tuple[tuple[float, float], ...] = ()  # [(t_start_s, mbps), ...]

    def __post_init__(self) -> None:
        if self.kind not in ("constant", "piecewise"):
            raise ValueError(f"unknown trace kind {self.kind!r}; want constant|piecewise")
        if self.kind == "piecewise" and not self.points:
            raise ValueError("piecewise trace needs at least one (t_start, mbps) point")
        # Normalize fields the active kind does not use, so equality (and the
        # JSON round-trip, which only serializes the active fields) is exact.
        if self.kind == "constant":
            object.__setattr__(self, "points", ())
        else:
            object.__setattr__(self, "mbps", 2.5)
            pts = tuple((float(t), float(v)) for t, v in self.points)
            # Same validation as Trace.piecewise, surfaced at spec time (and
            # as CLI exit 2) instead of as a nonsense lookup mid-simulation.
            for (t0, _), (t1, _) in zip(pts, pts[1:]):
                if t1 <= t0:
                    raise ValueError(
                        f"piecewise trace time points must be strictly "
                        f"increasing, got t={t1!r} after t={t0!r}"
                    )
            for ts, v in pts:
                if v < 0:
                    raise ValueError(
                        f"piecewise trace bandwidth must be >= 0 Mbps, "
                        f"got {v!r} at t={ts!r}"
                    )
            object.__setattr__(self, "points", pts)

    def build(self) -> Trace:
        if self.kind == "piecewise":
            return Trace.piecewise(list(self.points), rtt_ms=self.rtt_ms)
        return Trace.constant(self.mbps, rtt_ms=self.rtt_ms)

    def segments(self) -> tuple[tuple[float, float], ...]:
        """Lower to ``(t_start_s, bandwidth_bps)`` segments — the batched
        engines' on-device trace representation (a constant trace is one
        segment at t=0).  Points are validated strictly increasing at
        construction; this mirrors ``Trace.piecewise``'s bps conversion
        exactly."""
        if self.kind == "piecewise":
            return tuple((float(t), float(v) * 1e6) for t, v in self.points)
        return ((0.0, float(self.mbps) * 1e6),)

    @property
    def rtt_s(self) -> float:
        return self.rtt_ms / 1e3

    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind, "rtt_ms": self.rtt_ms}
        if self.kind == "constant":
            out["mbps"] = self.mbps
        else:
            out["points"] = [list(p) for p in self.points]
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "TraceSpec":
        return TraceSpec(
            kind=str(data.get("kind", "constant")),
            mbps=float(data.get("mbps", 2.5)),
            rtt_ms=float(data.get("rtt_ms", 100.0)),
            points=tuple((float(t), float(v)) for t, v in data.get("points", ())),
        )


@dataclass(frozen=True)
class FleetSpec:
    """Multi-tenant options for ``run_multi``: N clients, one edge server."""

    n_clients: int = 2
    allocation: str = "weighted_fair"  # see edge_server.ALLOCATION_POLICIES
    capacity: int = 4
    backlog_limit: float = 0.0
    weights: tuple[float, ...] | None = None
    priorities: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if self.n_clients < 1:
            raise ValueError("fleet needs n_clients >= 1")
        if self.allocation not in ALLOCATION_POLICIES:
            raise ValueError(
                f"unknown allocation {self.allocation!r}; want one of {ALLOCATION_POLICIES}"
            )
        for name in ("weights", "priorities"):
            v = getattr(self, name)
            if v is not None:
                v = tuple(v)
                object.__setattr__(self, name, v)
                if len(v) != self.n_clients:
                    raise ValueError(f"{name} must have n_clients={self.n_clients} entries")

    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "n_clients": self.n_clients,
            "allocation": self.allocation,
            "capacity": self.capacity,
            "backlog_limit": self.backlog_limit,
        }
        if self.weights is not None:
            out["weights"] = list(self.weights)
        if self.priorities is not None:
            out["priorities"] = list(self.priorities)
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "FleetSpec":
        return FleetSpec(
            n_clients=int(data.get("n_clients", 2)),
            allocation=str(data.get("allocation", "weighted_fair")),
            capacity=int(data.get("capacity", 4)),
            backlog_limit=float(data.get("backlog_limit", 0.0)),
            weights=tuple(data["weights"]) if data.get("weights") is not None else None,
            priorities=tuple(data["priorities"]) if data.get("priorities") is not None else None,
        )


def _model_to_json(m: ModelProfile) -> Any:
    """Presets serialize by name; custom profiles serialize in full."""
    preset = _PRESET_MODELS.get(m.name)
    if preset == m:
        return m.name
    return {
        "name": m.name,
        "t_npu_ms": m.t_npu * 1e3 if m.t_npu != float("inf") else None,
        "t_server_ms": m.t_server * 1e3 if m.t_server != float("inf") else None,
        "acc_server": {str(r): a for r, a in m.acc_server.items()},
        "acc_npu": {str(r): a for r, a in m.acc_npu.items()},
    }


def _model_from_json(data: Any) -> ModelProfile:
    if isinstance(data, ModelProfile):
        return data
    if isinstance(data, str):
        try:
            return _PRESET_MODELS[data]
        except KeyError:
            raise ValueError(
                f"unknown model preset {data!r}; presets: {sorted(_PRESET_MODELS)}"
            ) from None
    if not isinstance(data, Mapping) or "name" not in data:
        raise ValueError(f"not a model payload: {data!r}")
    t_npu = data.get("t_npu_ms")
    t_server = data.get("t_server_ms")
    return ModelProfile(
        name=str(data["name"]),
        t_npu=float(t_npu) / 1e3 if t_npu is not None else float("inf"),
        t_server=float(t_server) / 1e3 if t_server is not None else float("inf"),
        acc_server={int(r): float(a) for r, a in (data.get("acc_server") or {}).items()},
        acc_npu={int(r): float(a) for r, a in (data.get("acc_npu") or {}).items()},
    )


def _stream_to_json(s: StreamSpec) -> dict[str, Any]:
    return {
        "fps": s.fps,
        "deadline_ms": s.deadline * 1e3,
        "resolutions": list(s.resolutions),
        "png_ratio": s.png_ratio,
    }


def _stream_from_json(data: Mapping[str, Any]) -> StreamSpec:
    base = StreamSpec()
    return StreamSpec(
        fps=float(data.get("fps", base.fps)),
        deadline=float(data.get("deadline_ms", base.deadline * 1e3)) / 1e3,
        resolutions=tuple(int(r) for r in data.get("resolutions", base.resolutions)),
        png_ratio=float(data.get("png_ratio", base.png_ratio)),
    )


@dataclass(frozen=True)
class ScenarioSpec:
    """One experiment, declaratively: who streams what, over which network,
    scheduled by which policy.  JSON round-trippable (``to_json``/``from_json``)
    so benchmark sweeps and CI smoke runs are reproducible artifacts.

    ``models`` entries may be preset names (``"resnet-50"``/``"squeezenet"``)
    or full :class:`ModelProfile` objects; they normalize to profiles.
    ``fleet`` is only consulted by ``run_multi``; ``seed`` only by serving.
    ``workload`` selects the frame semantics (classification by default,
    detect+track with ``WorkloadSpec(kind="track")``) and must be one the
    policy declares it can plan (``PolicyEntry.workloads``).
    """

    policy: PolicySpec
    n_frames: int = 120
    stream: StreamSpec = field(default_factory=StreamSpec)
    models: tuple[ModelProfile, ...] = ("resnet-50", "squeezenet")  # type: ignore[assignment]
    trace: TraceSpec = field(default_factory=TraceSpec)
    fleet: FleetSpec | None = None
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    strict: bool = True
    seed: int = 0
    label: str = ""

    def __post_init__(self) -> None:
        if isinstance(self.policy, (str, Mapping)):
            spec = (
                PolicySpec(self.policy)
                if isinstance(self.policy, str)
                else PolicySpec.from_json(self.policy)
            )
            object.__setattr__(self, "policy", spec)
        if self.n_frames < 1:
            raise ValueError("n_frames must be >= 1")
        object.__setattr__(
            self, "models", tuple(_model_from_json(m) for m in self.models)
        )
        if not self.models:
            raise ValueError("scenario needs at least one model")
        if isinstance(self.workload, str):
            object.__setattr__(self, "workload", WorkloadSpec(kind=self.workload))
        elif isinstance(self.workload, Mapping):
            object.__setattr__(self, "workload", WorkloadSpec.from_json(self.workload))
        entry = get_policy(self.policy.name)
        if self.workload.kind not in entry.workloads:
            raise ValueError(
                f"policy {self.policy.name!r} plans "
                f"{'/'.join(entry.workloads)} workloads, not "
                f"{self.workload.kind!r}"
            )

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "policy": self.policy.to_json(),
            "n_frames": self.n_frames,
            "stream": _stream_to_json(self.stream),
            "models": [_model_to_json(m) for m in self.models],
            "trace": self.trace.to_json(),
            "strict": self.strict,
            "seed": self.seed,
        }
        if self.fleet is not None:
            out["fleet"] = self.fleet.to_json()
        if self.workload != WorkloadSpec():
            out["workload"] = self.workload.to_json()
        if self.label:
            out["label"] = self.label
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any] | str) -> "ScenarioSpec":
        if isinstance(data, str):
            data = json.loads(data)
        if not isinstance(data, Mapping) or "policy" not in data:
            raise ValueError("not a ScenarioSpec payload (missing 'policy')")
        return ScenarioSpec(
            policy=PolicySpec.from_json(data["policy"]),
            n_frames=int(data.get("n_frames", 120)),
            stream=_stream_from_json(data.get("stream") or {}),
            models=tuple(data.get("models") or ("resnet-50", "squeezenet")),
            trace=TraceSpec.from_json(data.get("trace") or {}),
            fleet=FleetSpec.from_json(data["fleet"]) if data.get("fleet") else None,
            workload=(
                WorkloadSpec.from_json(data["workload"])
                if data.get("workload")
                else WorkloadSpec()
            ),
            strict=bool(data.get("strict", True)),
            seed=int(data.get("seed", 0)),
            label=str(data.get("label", "")),
        )


# ---------------------------------------------------------------------------
# Uniform result wrapper
# ---------------------------------------------------------------------------


@dataclass
class RunReport:
    """What every run mode returns: audited per-stream stats + metadata."""

    mode: str
    spec: ScenarioSpec
    streams: list[StreamStats]
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def stats(self) -> StreamStats:
        """The single stream's stats (modes sim/online; first client in multi)."""
        return self.streams[0]

    @property
    def aggregate_accuracy(self) -> float:
        total = sum(s.frames_total for s in self.streams)
        return sum(s.accuracy_sum for s in self.streams) / total if total else 0.0

    @property
    def max_miss_rate(self) -> float:
        return max(
            (s.frames_missed_deadline / s.frames_total for s in self.streams if s.frames_total),
            default=0.0,
        )

    def to_json(self) -> dict[str, Any]:
        return {
            "mode": self.mode,
            "label": self.spec.label,
            "policy": self.spec.policy.to_json(),
            "streams": [dataclasses.asdict(s) for s in self.streams],
            "aggregate_accuracy": self.aggregate_accuracy,
            "max_miss_rate": self.max_miss_rate,
            "meta": self.meta,
        }


# ---------------------------------------------------------------------------
# Sweeps: a declarative grid over one base scenario
# ---------------------------------------------------------------------------


def _axis_values(name: str, values: Any) -> tuple:
    """Normalize one grid axis to a tuple, rejecting scalars and strings —
    ``"fifo"`` must not silently become the 4-point axis ('f','i','f','o')."""
    if isinstance(values, (str, bytes)) or not hasattr(values, "__iter__"):
        raise ValueError(
            f"SweepGrid axis {name!r} must be a list of values, got {values!r}"
        )
    return tuple(values)


@dataclass(frozen=True)
class SweepGrid:
    """A cartesian scenario grid over one base :class:`ScenarioSpec`.

    Scenario axes override spec fields; ``params`` axes override the policy's
    parameters (e.g. ``{"alpha": (50.0, 200.0)}``).  Empty axes are simply
    absent from the product — an all-empty grid is the single base scenario.
    JSON round-trippable like every other spec in this module.
    """

    bandwidth_mbps: tuple[float, ...] = ()
    deadline_ms: tuple[float, ...] = ()
    fps: tuple[float, ...] = ()
    rtt_ms: tuple[float, ...] = ()
    n_clients: tuple[int, ...] = ()
    allocation: tuple[str, ...] = ()
    params: Mapping[str, tuple] = field(default_factory=dict)

    SCENARIO_AXES = ("bandwidth_mbps", "deadline_ms", "fps", "rtt_ms", "n_clients", "allocation")

    def __post_init__(self) -> None:
        for name in self.SCENARIO_AXES:
            object.__setattr__(self, name, _axis_values(name, getattr(self, name)))
        if not isinstance(self.params, Mapping):
            raise ValueError(
                f"SweepGrid params must be a mapping of axis name -> values, "
                f"got {self.params!r}"
            )
        params = {str(k): _axis_values(k, v) for k, v in self.params.items()}
        for k in params:
            if k in self.SCENARIO_AXES:
                raise ValueError(f"param axis {k!r} shadows a scenario axis")
            if not params[k]:
                raise ValueError(f"param axis {k!r} is empty")
        object.__setattr__(self, "params", params)

    def axes(self) -> list[tuple[str, tuple]]:
        """Non-empty (name, values) axes, scenario axes first."""
        out = [(n, getattr(self, n)) for n in self.SCENARIO_AXES if getattr(self, n)]
        out.extend(self.params.items())
        return out

    def iter_points(self) -> Iterator[dict[str, Any]]:
        """Lazily yield every grid point as an override dict, in row-major
        axis order — the streaming twin of :meth:`points` for grids too
        large to materialize on the host at once."""
        axes = self.axes()
        if not axes:
            yield {}
            return
        names = [n for n, _ in axes]
        for combo in itertools.product(*(vals for _, vals in axes)):
            yield dict(zip(names, combo))

    def points(self) -> list[dict[str, Any]]:
        """Every grid point as an override dict, in row-major axis order."""
        return list(self.iter_points())

    def __len__(self) -> int:
        n = 1
        for _, vals in self.axes():
            n *= len(vals)
        return n

    def to_json(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            n: list(getattr(self, n)) for n in self.SCENARIO_AXES if getattr(self, n)
        }
        if self.params:
            out["params"] = {k: list(v) for k, v in self.params.items()}
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any] | str) -> "SweepGrid":
        if isinstance(data, str):
            data = json.loads(data)
        if not isinstance(data, Mapping):
            raise ValueError(f"not a SweepGrid payload: {data!r}")
        unknown = set(data) - set(SweepGrid.SCENARIO_AXES) - {"params"}
        if unknown:
            raise ValueError(
                f"unknown SweepGrid axes {sorted(unknown)}; "
                f"scenario axes: {SweepGrid.SCENARIO_AXES} (policy params go under 'params')"
            )
        return SweepGrid(  # axis-shape validation happens in __post_init__
            **{n: data.get(n, ()) for n in SweepGrid.SCENARIO_AXES},
            params=data.get("params") or {},
        )


def _apply_point(base: ScenarioSpec, pt: Mapping[str, Any]) -> ScenarioSpec:
    """Materialize one grid point: base spec + axis overrides."""
    stream_kw: dict[str, Any] = {}
    if "deadline_ms" in pt:
        stream_kw["deadline"] = float(pt["deadline_ms"]) / 1e3
    if "fps" in pt:
        stream_kw["fps"] = float(pt["fps"])
    stream = dataclasses.replace(base.stream, **stream_kw) if stream_kw else base.stream

    trace = base.trace
    if "bandwidth_mbps" in pt:  # a bandwidth axis implies a constant trace
        trace = TraceSpec(
            kind="constant",
            mbps=float(pt["bandwidth_mbps"]),
            rtt_ms=float(pt.get("rtt_ms", base.trace.rtt_ms)),
        )
    elif "rtt_ms" in pt:
        trace = dataclasses.replace(trace, rtt_ms=float(pt["rtt_ms"]))

    fleet = base.fleet
    if "n_clients" in pt or "allocation" in pt:
        fleet = fleet if fleet is not None else FleetSpec()
        if "n_clients" in pt and (fleet.weights is not None or fleet.priorities is not None):
            raise ValueError(
                "an n_clients grid axis cannot resize a fleet with explicit "
                "per-client weights/priorities"
            )
        fleet_kw: dict[str, Any] = {}
        if "n_clients" in pt:
            fleet_kw["n_clients"] = int(pt["n_clients"])
        if "allocation" in pt:
            fleet_kw["allocation"] = str(pt["allocation"])
        fleet = dataclasses.replace(fleet, **fleet_kw)

    param_over = {k: v for k, v in pt.items() if k not in SweepGrid.SCENARIO_AXES}
    policy = base.policy
    if param_over:
        policy = PolicySpec(policy.name, {**policy.params, **param_over})

    return dataclasses.replace(
        base, policy=policy, stream=stream, trace=trace, fleet=fleet
    )


@dataclass
class SweepPoint:
    """One audited grid point: its axis overrides + per-stream stats."""

    overrides: dict[str, Any]
    streams: list[StreamStats]
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def stats(self) -> StreamStats:
        return self.streams[0]

    @property
    def aggregate_accuracy(self) -> float:
        total = sum(s.frames_total for s in self.streams)
        return sum(s.accuracy_sum for s in self.streams) / total if total else 0.0

    @property
    def max_miss_rate(self) -> float:
        return max(
            (s.frames_missed_deadline / s.frames_total for s in self.streams if s.frames_total),
            default=0.0,
        )

    def to_json(self) -> dict[str, Any]:
        return {
            "overrides": dict(self.overrides),
            "streams": [dataclasses.asdict(s) for s in self.streams],
            "meta": self.meta,
        }

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "SweepPoint":
        return SweepPoint(
            overrides=dict(data.get("overrides") or {}),
            streams=[StreamStats(**s) for s in data.get("streams") or []],
            meta=dict(data.get("meta") or {}),
        )


@dataclass
class SweepSummary:
    """Streaming reduction of a sweep's per-point stats.

    ``run_sweep`` folds each executed chunk into one of these, so a
    10^5+-point grid can report aggregate frames/accuracy/miss extremes
    without ever materializing every :class:`SweepPoint` on the host
    (``keep_points=False``).  Attached to ``SweepReport.meta["summary"]``
    as plain JSON whenever the sweep ran chunked or point-free."""

    n_points: int = 0
    n_streams: int = 0
    frames_total: int = 0
    frames_processed: int = 0
    frames_missed_deadline: int = 0
    frames_offloaded: int = 0
    accuracy_sum: float = 0.0
    best_accuracy: float = 0.0
    best_point: dict[str, Any] | None = None
    max_miss_rate: float = 0.0
    worst_point: dict[str, Any] | None = None

    def update(self, point: SweepPoint) -> None:
        self.n_points += 1
        self.n_streams += len(point.streams)
        for s in point.streams:
            self.frames_total += s.frames_total
            self.frames_processed += s.frames_processed
            self.frames_missed_deadline += s.frames_missed_deadline
            self.frames_offloaded += s.frames_offloaded
            self.accuracy_sum += s.accuracy_sum
        acc = point.aggregate_accuracy
        if self.best_point is None or acc > self.best_accuracy:
            self.best_accuracy, self.best_point = acc, dict(point.overrides)
        miss = point.max_miss_rate
        if self.worst_point is None or miss > self.max_miss_rate:
            self.max_miss_rate, self.worst_point = miss, dict(point.overrides)

    @property
    def mean_accuracy(self) -> float:
        return self.accuracy_sum / self.frames_total if self.frames_total else 0.0

    def to_json(self) -> dict[str, Any]:
        out = dataclasses.asdict(self)
        out["mean_accuracy"] = self.mean_accuracy
        return out

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "SweepSummary":
        fields = {f.name for f in dataclasses.fields(SweepSummary)}
        return SweepSummary(**{k: v for k, v in data.items() if k in fields})


@dataclass
class SweepReport:
    """What ``Session.run_sweep`` returns: the base spec, the grid, which
    engine actually ran (``backend``), and one :class:`SweepPoint` per grid
    point in ``grid.points()`` order.  ``to_json``/``from_json`` round-trip
    losslessly (property-tested), so a sweep is a replayable artifact.

    Chunked/streamed sweeps (``chunk_size=``/``keep_points=False``) carry
    their incremental :class:`SweepSummary` in ``meta["summary"]``; with
    ``keep_points=False`` the summary is the whole artifact and ``points``
    is empty."""

    base: ScenarioSpec
    grid: SweepGrid
    backend: str  # "reference" | "batched" — the engine that actually ran
    points: list[SweepPoint]
    meta: dict[str, Any] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self) -> Iterator[SweepPoint]:
        return iter(self.points)

    def to_json(self) -> dict[str, Any]:
        return {
            "base": self.base.to_json(),
            "grid": self.grid.to_json(),
            "backend": self.backend,
            "points": [p.to_json() for p in self.points],
            "meta": self.meta,
        }

    @staticmethod
    def from_json(data: Mapping[str, Any] | str) -> "SweepReport":
        if isinstance(data, str):
            data = json.loads(data)
        if not isinstance(data, Mapping) or "base" not in data or "grid" not in data:
            raise ValueError("not a SweepReport payload (missing 'base'/'grid')")
        return SweepReport(
            base=ScenarioSpec.from_json(data["base"]),
            grid=SweepGrid.from_json(data["grid"]),
            backend=str(data.get("backend", "reference")),
            points=[SweepPoint.from_json(p) for p in data.get("points") or []],
            meta=dict(data.get("meta") or {}),
        )


# ---------------------------------------------------------------------------
# Session facade
# ---------------------------------------------------------------------------


class Session:
    """Routes one :class:`ScenarioSpec` to any execution engine.

    Engines share the spec's policy/models/stream/trace; they differ in what
    the world looks like (one stream, a contended fleet, estimated bandwidth,
    or real JAX models).  Every mode returns a :class:`RunReport`.
    """

    MODES = ("sim", "multi", "online", "serving")

    def __init__(self, spec: ScenarioSpec):
        self.spec = spec

    def run(self, mode: str = "sim") -> RunReport:
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {self.MODES}")
        return getattr(self, f"run_{mode}")()

    # -- mode: audited single-stream simulation ----------------------------
    def run_sim(self) -> RunReport:
        spec = self.spec
        stats = simulate(
            spec.policy.build(),
            list(spec.models),
            spec.stream,
            spec.trace.build(),
            spec.n_frames,
            strict=spec.strict,
            workload=spec.workload,
        )
        return RunReport("sim", spec, [stats], meta={"policy": spec.policy.name})

    # -- mode: N streams, shared fluid uplink + edge server ----------------
    def run_multi(self) -> RunReport:
        spec = self.spec
        fleet = spec.fleet if spec.fleet is not None else FleetSpec()
        clients = make_fleet(
            fleet.n_clients,
            stream=spec.stream,
            models=list(spec.models),
            policy=spec.policy,
            weights=fleet.weights,
            priorities=fleet.priorities,
        )
        sched = EdgeServerScheduler(
            clients,
            policy=fleet.allocation,
            capacity=fleet.capacity,
            backlog_limit=fleet.backlog_limit,
        )
        ms = simulate_multi(
            sched,
            spec.trace.build(),
            spec.n_frames,
            strict=spec.strict,
            workload=spec.workload,
        )
        return RunReport(
            "multi",
            spec,
            ms.per_client,
            meta={
                "allocation": fleet.allocation,
                "server_jobs": ms.server_jobs,
                "server_utilization": ms.server_utilization,
                "grants": sched.audit.grants,
                "denials": sched.audit.denials,
            },
        )

    # -- mode: online controller with estimated bandwidth ------------------
    def run_online(self) -> RunReport:
        """Drive :class:`OnlineController` over the trace: the policy sees
        only the EWMA estimator's belief (fed back from the uploads the plans
        actually perform), while the audit uses the *true* trace — offload
        finish times are recomputed at real bandwidth, so an optimistic
        estimate shows up as deadline misses, exactly as in deployment."""
        spec = self.spec
        if spec.workload.is_track:
            raise ValueError(
                "mode 'online' does not execute the tracking workload yet; "
                "use run_sim/run_multi/run_sweep"
            )
        models = list(spec.models)
        stream = spec.stream
        trace = spec.trace.build()
        gamma, deadline = stream.gamma, stream.deadline
        controller = OnlineController(
            models=models,
            stream=stream,
            policy=spec.policy,
            estimator=BandwidthEstimator(init_bps=trace.at(0.0).bandwidth_bps),
        )
        controller.estimator.observe_rtt(trace.at(0.0).rtt)
        stats = StreamStats(frames_total=spec.n_frames, elapsed=spec.n_frames * gamma)
        head = 0
        net_free_abs = 0.0  # true-link serial occupancy
        while head < spec.n_frames:
            t0 = head * gamma
            true_net = trace.at(t0)
            wall = time.perf_counter()
            plan = controller.next_plan(head)
            stats.schedule_time += time.perf_counter() - wall
            stats.schedule_calls += 1

            horizon, bad = audit_round(
                plan, gamma=gamma, deadline=deadline, strict=spec.strict, npu_only=True
            )

            def offload(d, m, *, t0=t0, true_net=true_net):
                nonlocal net_free_abs
                arrival_abs = t0 + d.frame * gamma
                nbytes = stream.frame_bytes(d.resolution)
                t_up = true_net.upload_time(nbytes)
                start = max(net_free_abs, t0 + max(d.start, 0.0))
                finish = start + t_up + true_net.rtt + m.t_server
                net_free_abs = start + t_up
                controller.report_upload(nbytes, t_up)
                controller.report_rtt(true_net.rtt)
                if finish <= arrival_abs + deadline + AUDIT_TOL:
                    stats.frames_processed += 1
                    stats.frames_offloaded += 1
                    stats.accuracy_sum += m.accuracy(d.resolution, where="server")
                else:
                    stats.frames_missed_deadline += 1

            apply_round(
                stats,
                plan,
                models=models,
                stream=stream,
                head=head,
                n_frames=spec.n_frames,
                horizon=horizon,
                bad_frames=bad,
                on_offload=offload,
            )
            head += horizon
        return RunReport(
            "online",
            spec,
            [stats],
            meta={
                "rounds": controller.rounds,
                "estimated_bps": controller.estimator.state().bandwidth_bps,
            },
        )

    # -- mode: real models behind the controller ---------------------------
    def run_serving(self) -> RunReport:
        """Stand up the real-model serving stack (launch/serve) for this
        scenario: trains/quantizes the classifier pair, profiles it live, and
        runs the controller over a synthetic labeled video."""
        if self.spec.workload.is_track:
            raise ValueError(
                "mode 'serving' does not execute the tracking workload yet; "
                "use run_sim/run_multi/run_sweep"
            )
        from .launch.serve import run_scenario  # heavy deps; import lazily

        summary = run_scenario(self.spec)
        frames = int(summary.get("frames", 0))
        stats = StreamStats(
            frames_total=self.spec.n_frames,
            frames_processed=frames,
            frames_missed_deadline=int(round((1.0 - summary.get("deadline_met_frac", 1.0)) * frames)),
            frames_offloaded=int(summary.get("edge_frames", 0)),
            accuracy_sum=float(summary.get("accuracy", 0.0)) * frames,
            elapsed=self.spec.n_frames * self.spec.stream.gamma,
            schedule_calls=int(summary.get("scheduler_rounds", 0)),
        )
        return RunReport("serving", self.spec, [stats], meta=summary)

    # -- mode: a whole scenario grid in one call ---------------------------
    BACKENDS = ("auto", "reference", "batched")
    SWEEP_MODES = ("auto", "online")

    def run_sweep(
        self,
        grid: SweepGrid,
        *,
        backend: str = "auto",
        mode: str = "auto",
        chunk_size: int | None = None,
        keep_points: bool = True,
        compile_cache: str | None = None,
    ) -> SweepReport:
        """Run the base scenario across every point of ``grid``.

        Backend routing: single-stream grids of policies registered
        ``batched=True`` execute as one jit+vmap program
        (``core/sim_batch``) — the network-aware planners
        (``max_accuracy``/``max_utility``) replay constant and piecewise
        traces on device; fleet grids of ``batched_multi=True`` policies
        execute through the vectorized multi-stream engine
        (``core/sim_multi_batch`` — per-client DP planning over granted
        bandwidth, shared fluid uplink with piecewise-constant trace
        replay, scheduler admission, server queue on device, equivalence
        certified to ``sim_multi_batch.MULTI_TOL``).  Anything else runs
        the per-point reference engines (``run_sim``, or ``run_multi``
        when the point has a fleet).  Requesting ``backend="batched"`` for
        a policy/grid combination without a vectorized engine logs a
        warning and falls back to the reference loop — never a silent
        wrong answer.

        Scale-out knobs (docs/simulation.md "Scaling sweeps"):

        * ``chunk_size`` — plan the grid as a lazy iterator of shape-grouped
          chunks instead of materializing every spec upfront.  Chunking is
          result-invariant (the engines' shape buckets are per-scenario and
          padding is inert, so a chunked sweep is bit-identical to the
          unchunked one — golden-tested), and each chunk's stats fold into
          an incremental :class:`SweepSummary` in ``meta["summary"]``.
        * ``keep_points=False`` — drop per-point results after folding them
          into the summary, so a 10^5–10^6-point grid never lands on the
          host at once.
        * ``compile_cache`` — enable jax's persistent compilation cache at
          this directory (defaults to ``$REPRO_COMPILE_CACHE`` when set),
          so re-runs load planner executables instead of recompiling.

        ``mode="online"`` sweeps the observe->replan->execute world of
        ``run_online`` instead of the oracle-bandwidth simulator: each grid
        point carries its own EWMA estimator belief and the audit uses the
        true trace.  Policies registered ``batched_online=True`` run the
        whole grid through ``core/sim_online_batch`` (estimator state
        scan-carried on device; integer stats exact, accuracy within
        AUDIT_TOL of the reference — see docs/simulation.md "Online
        adaptation"); everything else falls back to per-point
        ``run_online``.  Online sweeps are single-stream: a fleet anywhere
        in the grid is a ``ValueError``.
        """
        if backend not in self.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; want one of {self.BACKENDS}")
        if mode not in self.SWEEP_MODES:
            raise ValueError(f"unknown sweep mode {mode!r}; want one of {self.SWEEP_MODES}")
        if chunk_size is not None and int(chunk_size) < 1:
            raise ValueError(f"chunk_size must be a positive int, got {chunk_size!r}")
        cache_dir = compile_cache if compile_cache is not None else default_cache_dir()
        if cache_dir:
            enable_compile_cache(cache_dir)
        entry = get_policy(self.spec.policy.name)
        n_points = len(grid)
        chunk = n_points if chunk_size is None else int(chunk_size)
        # A bandwidth_mbps axis *replaces* the base trace; on a piecewise
        # base that silently discards the time-varying profile — surface it
        # (logged once, recorded per point below) instead of staying mute.
        # The axis applies to every point or none, so this is grid-uniform.
        clobbers = bool(grid.bandwidth_mbps) and self.spec.trace.kind == "piecewise"
        if clobbers:
            _LOG.warning(
                "sweep axis 'bandwidth_mbps' replaces the piecewise base trace "
                "with a constant trace at %d grid point(s); drop the axis (or "
                "use a constant base trace) if the time-varying profile matters",
                n_points,
            )
        meta: dict[str, Any] = {"requested_backend": backend, "grid_points": n_points}
        if mode != "auto":
            meta["mode"] = mode
        if cache_dir:
            meta["compile_cache"] = str(cache_dir)
        streaming = chunk_size is not None or not keep_points
        summary = SweepSummary() if streaming else None
        out_points: list[SweepPoint] = []
        use_batched: bool | None = None  # decided on the first chunk
        t0 = time.perf_counter()
        it = grid.iter_points()
        n_chunks = 0
        while True:
            pts = list(itertools.islice(it, chunk))
            if not pts:
                break
            n_chunks += 1
            specs = [_apply_point(self.spec, p) for p in pts]
            if mode == "online" and any(s.fleet is not None for s in specs):
                raise ValueError(
                    "sweep mode 'online' is single-stream (run_online has no "
                    "fleet engine); drop the fleet or use mode='auto'"
                )
            if mode == "online" and any(s.workload.is_track for s in specs):
                raise ValueError(
                    "mode 'online' does not execute the tracking workload "
                    "yet; use run_sim/run_multi/run_sweep"
                )
            if use_batched is None:
                capable, why = self._batched_capability(entry, specs, mode=mode)
                use_batched = capable if backend == "auto" else backend == "batched"
                if use_batched and not capable:
                    _LOG.warning(
                        "%s; run_sweep falling back to the reference loop "
                        "(batched policies: %s; batched fleet policies: %s; "
                        "batched online policies: %s)",
                        why,
                        sim_batch.batched_policies(),
                        sim_multi_batch.multi_batched_policies(),
                        sim_online_batch.batched_online_policies(),
                    )
                    meta["fallback"] = why
                    use_batched = False
                if use_batched:
                    if mode == "online":
                        meta["engine"] = "sim_online_batch"
                    else:
                        meta["engine"] = (
                            "sim_multi_batch"
                            if any(s.fleet is not None for s in specs)
                            else "sim_batch"
                        )
            if use_batched:
                if meta["engine"] == "sim_online_batch":
                    points = self._sweep_batched_online(specs, pts)
                elif meta["engine"] == "sim_multi_batch":
                    points = self._sweep_batched_multi(specs, pts)
                else:
                    points = self._sweep_batched(specs, pts)
            else:
                points = [
                    self._sweep_reference(s, p, mode=mode) for s, p in zip(specs, pts)
                ]
            if clobbers:
                for point in points:
                    point.meta["trace_override"] = (
                        "bandwidth_mbps axis replaced the piecewise base trace "
                        "with a constant trace"
                    )
            if summary is not None:
                for point in points:
                    summary.update(point)
            if keep_points:
                out_points.extend(points)
        meta["wall_s"] = time.perf_counter() - t0
        if chunk_size is not None:
            meta["chunks"] = n_chunks
            meta["chunk_size"] = chunk
        if summary is not None:
            meta["summary"] = summary.to_json()
        if not keep_points:
            meta["points_streamed"] = n_points
        return SweepReport(
            base=self.spec,
            grid=grid,
            backend="batched" if use_batched else "reference",
            points=out_points,
            meta=meta,
        )

    def _batched_capability(
        self, entry, specs: Sequence[ScenarioSpec], mode: str = "auto"
    ) -> tuple[bool, str]:
        """Can this (policy, grid) combination run on a vectorized engine?

        Single-stream grids need ``batched=True`` (``sim_batch``); both
        engines replay constant *and* piecewise traces on device, so the
        trace kind never gates routing.  Fleet grids need
        ``batched_multi=True`` — every such policy has a dedicated fleet
        planner in ``sim_multi_batch`` (offloading planners compose
        per-client DP with the shared water-filled link; local-only
        planners run one lane per scenario) — and a fleet at every grid
        point (the engines do not mix fleet and single-stream lanes in
        one program).  Online sweeps need ``batched_online=True``
        (``sim_online_batch`` — the scan-carried estimator loop).
        """
        if mode == "online":
            if entry.batched_online:
                return True, ""
            return False, f"policy {entry.name!r} has no batched online backend"
        fleet_pts = sum(1 for s in specs if s.fleet is not None)
        if fleet_pts == 0:
            if entry.batched:
                return True, ""
            return False, f"policy {entry.name!r} has no batched backend"
        if not entry.batched_multi:
            return False, f"policy {entry.name!r} has no batched fleet backend"
        if fleet_pts < len(specs):
            return False, (
                f"fleet backend for {entry.name!r} needs a fleet at every "
                "grid point (grid mixes fleet and single-stream points)"
            )
        return True, ""

    def _sweep_reference(
        self, spec: ScenarioSpec, pt: Mapping[str, Any], mode: str = "auto"
    ) -> SweepPoint:
        if mode == "online":
            rep = Session(spec).run("online")
        else:
            rep = Session(spec).run("multi" if spec.fleet is not None else "sim")
        return SweepPoint(overrides=dict(pt), streams=rep.streams, meta=dict(rep.meta))

    def _sweep_batched(
        self, specs: list[ScenarioSpec], pts: list[dict[str, Any]]
    ) -> list[SweepPoint]:
        base = self.spec
        scens = [
            sim_batch.BatchScenario(
                stream=s.stream,
                n_frames=s.n_frames,
                params=s.policy.resolved,
                rtt=s.trace.rtt_s,
                bw_segments=s.trace.segments(),
                workload=s.workload,
            )
            for s in specs
        ]
        stats = sim_batch.simulate_batch(
            base.policy.name, list(base.models), scens, strict=base.strict
        )
        return [
            SweepPoint(
                overrides=dict(pt),
                streams=[st],
                meta={"policy": spec.policy.name},
            )
            for spec, pt, st in zip(specs, pts, stats)
        ]

    def _sweep_batched_online(
        self, specs: list[ScenarioSpec], pts: list[dict[str, Any]]
    ) -> list[SweepPoint]:
        """Online grid through the vectorized estimator loop: every point's
        observe->replan->execute rounds run on device; per-point meta mirrors
        what ``run_online`` reports (round count, final believed bandwidth)."""
        base = self.spec
        scens = [
            sim_online_batch.OnlineScenario(
                stream=s.stream,
                n_frames=s.n_frames,
                params=s.policy.resolved,
                rtt=s.trace.rtt_s,
                bw_segments=s.trace.segments(),
            )
            for s in specs
        ]
        results = sim_online_batch.simulate_online_batch(
            base.policy.name, list(base.models), scens, strict=base.strict
        )
        return [
            SweepPoint(
                overrides=dict(pt),
                streams=[st],
                meta={"policy": spec.policy.name, **lane_meta},
            )
            for spec, pt, (st, lane_meta) in zip(specs, pts, results)
        ]

    def _sweep_batched_multi(
        self, specs: list[ScenarioSpec], pts: list[dict[str, Any]]
    ) -> list[SweepPoint]:
        """Fleet grid through the vectorized multi-stream engine: every
        point's interacting fleet (shared uplink + server queue) runs on
        device; per-point meta mirrors what ``run_multi`` reports."""
        base = self.spec
        scens = [
            sim_multi_batch.FleetScenario(
                stream=s.stream,
                n_frames=s.n_frames,
                bw_segments=s.trace.segments(),
                rtt=s.trace.rtt_s,
                n_clients=s.fleet.n_clients,
                allocation=s.fleet.allocation,
                capacity=s.fleet.capacity,
                backlog_limit=s.fleet.backlog_limit,
                weights=s.fleet.weights,
                priorities=s.fleet.priorities,
                params=s.policy.resolved,
                workload=s.workload,
            )
            for s in specs
        ]
        results = sim_multi_batch.simulate_multi_batch(
            base.policy.name, list(base.models), scens, strict=base.strict
        )
        points = []
        for spec, pt, (ms, sched_meta) in zip(specs, pts, results):
            meta = {
                "policy": spec.policy.name,
                "allocation": spec.fleet.allocation,
                "server_jobs": ms.server_jobs,
                "server_utilization": ms.server_utilization,
                **sched_meta,
            }
            points.append(
                SweepPoint(overrides=dict(pt), streams=ms.per_client, meta=meta)
            )
        return points


# ---------------------------------------------------------------------------
# CLI: one ScenarioSpec JSON in, one RunReport/SweepReport JSON out.
#
#   python -m repro.session scenario.json --mode sim
#   python -m repro.session sweep scenario.json --grid grid.json --backend auto
#
# Malformed specs/grids (bad JSON, unknown policy, invalid parameters) exit
# nonzero with a one-line ``error: ...`` on stderr — never a traceback.
# ---------------------------------------------------------------------------

_EXAMPLE = ScenarioSpec(
    policy=PolicySpec("max_accuracy"),
    n_frames=90,
    trace=TraceSpec(mbps=2.5),
    label="example",
)

_EXAMPLE_GRID = SweepGrid(
    bandwidth_mbps=(1.0, 2.5), deadline_ms=(150.0, 200.0, 250.0)
)


def _read(path: str) -> str:
    return sys.stdin.read() if path == "-" else open(path).read()


def _fail(exc: Exception) -> int:
    print(f"error: {exc}", file=sys.stderr)
    return 2


def _sweep_main(argv: Sequence[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.session sweep",
        description="Run one ScenarioSpec across a SweepGrid; print a SweepReport JSON.",
    )
    ap.add_argument("spec", nargs="?", help="path to ScenarioSpec JSON, or '-' for stdin")
    ap.add_argument("--grid", help="path to SweepGrid JSON (see --example-grid)")
    ap.add_argument("--backend", default="auto", choices=Session.BACKENDS)
    ap.add_argument("--mode", default="auto", choices=Session.SWEEP_MODES,
                    help="'online' sweeps the estimated-bandwidth controller "
                    "loop (run_online) instead of the oracle simulator")
    ap.add_argument("--out", help="write the SweepReport JSON here; print a summary instead")
    ap.add_argument("--chunk-size", type=int, default=None, metavar="N",
                    help="stream the grid in chunks of N points (bit-identical "
                    "to unchunked; adds an incremental summary to meta)")
    ap.add_argument("--summary-only", action="store_true",
                    help="drop per-point stats, keep only the streaming summary "
                    "(for 10^5+-point grids)")
    ap.add_argument("--compile-cache", metavar="DIR",
                    help="persist compiled programs under DIR (jax persistent "
                    "compilation cache; re-runs skip XLA)")
    ap.add_argument("--example-grid", action="store_true",
                    help="print an example grid JSON and exit")
    args = ap.parse_args(argv)

    if args.example_grid:
        print(json.dumps(_EXAMPLE_GRID.to_json(), indent=2))
        return 0
    if not args.spec or not args.grid:
        ap.error("need a spec path and --grid (or --example-grid)")
    try:
        spec = ScenarioSpec.from_json(_read(args.spec))
        grid = SweepGrid.from_json(_read(args.grid))
        report = Session(spec).run_sweep(
            grid,
            backend=args.backend,
            mode=args.mode,
            chunk_size=args.chunk_size,
            keep_points=not args.summary_only,
            compile_cache=args.compile_cache,
        )
        payload = json.dumps(report.to_json(), indent=2)
        if args.out:
            with open(args.out, "w") as fh:
                fh.write(payload + "\n")
    except (OSError, TypeError, ValueError) as exc:
        return _fail(exc)
    if args.out:
        print(
            f"{len(report)} points via {report.backend} backend in "
            f"{report.meta.get('wall_s', 0.0):.2f}s -> {args.out}"
        )
    else:
        print(payload)
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv[:1] == ["sweep"]:
        return _sweep_main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.session",
        description="Run a declarative FastVA scenario (ScenarioSpec JSON). "
        "Use the 'sweep' subcommand to run a whole scenario grid.",
    )
    ap.add_argument("spec", nargs="?", help="path to ScenarioSpec JSON, or '-' for stdin")
    ap.add_argument("--mode", default="sim", choices=Session.MODES)
    ap.add_argument("--list-policies", action="store_true", help="list registered policies and exit")
    ap.add_argument("--example", action="store_true", help="print an example spec JSON and exit")
    args = ap.parse_args(argv)

    if args.list_policies:
        for name in available_policies():
            print(name)
        return 0
    if args.example:
        print(json.dumps(_EXAMPLE.to_json(), indent=2))
        return 0
    if not args.spec:
        ap.error("need a spec path (or --list-policies / --example)")
    try:
        spec = ScenarioSpec.from_json(_read(args.spec))
        report = Session(spec).run(args.mode)
    except (OSError, TypeError, ValueError) as exc:
        return _fail(exc)
    print(json.dumps(report.to_json(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
