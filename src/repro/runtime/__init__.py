from .fault_tolerance import (  # noqa: F401
    ElasticPlan,
    HeartbeatMonitor,
    StragglerMitigator,
    WorkerState,
    plan_elastic_remesh,
)
