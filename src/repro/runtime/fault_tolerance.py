"""Fault tolerance & elasticity for multi-pod runs.

Pieces (each independently unit-tested; the train driver wires them up):

  HeartbeatMonitor    workers report liveness; missed-deadline detection with
                      a configurable grace window.  On a real cluster the
                      transport is the coordination service; here it is a
                      clock-injected in-process registry so failure scenarios
                      are simulated deterministically in tests.

  StragglerMitigator  per-step worker timing EWMAs; flags workers slower than
                      ``threshold x`` the fleet median.  Mitigation on TPU
                      pods = redistribute input shards / replace the host
                      (not work-stealing, since SPMD steps are collective) —
                      the mitigator emits those decisions.

  plan_elastic_remesh Given surviving chips, pick the largest (pod, data,
                      model) mesh <= survivors that preserves the model axis
                      (TP degree is fixed by weight shardings), shrinking the
                      data axis — then the restart path is: restore the last
                      checkpoint with restore_resharded + skip-ahead the data
                      pipeline (both deterministic).

The FastVA tie-in: the serving tier treats an edge-pool failure exactly like
the paper treats a network outage — the controller's profile for the edge
path degrades (t_server -> inf) and Max-Accuracy/Max-Utility route frames to
the NPU path until the pool re-forms.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import defaultdict
from typing import Callable


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class _Worker:
    last_beat: float
    state: WorkerState = WorkerState.HEALTHY


class HeartbeatMonitor:
    def __init__(
        self,
        *,
        interval_s: float = 10.0,
        suspect_after: float = 2.0,  # multiples of interval
        dead_after: float = 6.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.interval = interval_s
        self.suspect_after = suspect_after * interval_s
        self.dead_after = dead_after * interval_s
        self.clock = clock
        self.workers: dict[str, _Worker] = {}

    def register(self, worker_id: str) -> None:
        """Idempotent membership: a re-registration of a known worker must not
        resurrect it — only a real heartbeat (:meth:`beat`) proves liveness."""
        if worker_id not in self.workers:
            self.workers[worker_id] = _Worker(last_beat=self.clock())

    def beat(self, worker_id: str) -> None:
        w = self.workers.setdefault(worker_id, _Worker(last_beat=self.clock()))
        w.last_beat = self.clock()
        w.state = WorkerState.HEALTHY

    def sweep(self) -> dict[str, WorkerState]:
        """Re-evaluate every worker; returns ids whose state CHANGED."""
        now = self.clock()
        changed = {}
        for wid, w in self.workers.items():
            age = now - w.last_beat
            new = (
                WorkerState.DEAD
                if age > self.dead_after
                else WorkerState.SUSPECT
                if age > self.suspect_after
                else WorkerState.HEALTHY
            )
            if new is not w.state:
                w.state = new
                changed[wid] = new
        return changed

    def dead(self) -> list[str]:
        return [w for w, s in self.workers.items() if s.state is WorkerState.DEAD]


class StragglerMitigator:
    """EWMA step-time tracking; flags persistent stragglers."""

    def __init__(self, *, beta: float = 0.3, threshold: float = 1.5, min_samples: int = 3):
        self.beta = beta
        self.threshold = threshold
        self.min_samples = min_samples
        self.ewma: dict[str, float] = {}
        self.samples: dict[str, int] = defaultdict(int)

    def observe(self, worker_id: str, step_seconds: float) -> None:
        prev = self.ewma.get(worker_id, step_seconds)
        self.ewma[worker_id] = (1 - self.beta) * prev + self.beta * step_seconds
        self.samples[worker_id] += 1

    def fleet_median(self) -> float:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def stragglers(self) -> list[str]:
        med = self.fleet_median()
        if med <= 0:
            return []
        return [
            w
            for w, v in self.ewma.items()
            if self.samples[w] >= self.min_samples and v > self.threshold * med
        ]

    def mitigation(self, worker_id: str) -> str:
        """Decision for a flagged worker (SPMD: collective lockstep, so the
        options are input-side or replacement, never work stealing)."""
        ewma = self.ewma.get(worker_id)
        if ewma is None:
            return "observe"  # no timing data yet: gather samples first
        ratio = ewma / max(self.fleet_median(), 1e-9)
        if ratio > 3.0:
            return "replace"  # cordon host, trigger elastic remesh
        return "rebalance_input"  # shift data-loader shards away from it


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_chips: int
    data_parallel_scale: float  # new DP degree / old DP degree


def _dp_degree(chips: int, model_axis: int, pod_size: int) -> int:
    """Total data-parallel degree of the largest coherent mesh on ``chips``:
    full pods when >= 2 pods fit, otherwise whole multiples of the model axis."""
    pods = chips // pod_size
    if pods >= 2:
        return pods * (pod_size // model_axis)
    return chips // model_axis


def plan_elastic_remesh(
    surviving_chips: int,
    *,
    model_axis: int = 16,
    pod_size: int = 256,
    prior_chips: int | None = None,
) -> ElasticPlan:
    """Largest coherent mesh from the survivors.

    TP (model axis) is pinned — weight shards assume it.  We keep whole
    multiples of the model axis, preferring full pods, and shrink data
    parallelism; global batch is preserved by raising grad-accumulation in
    the train driver (batch semantics stay bit-identical).
    ``data_parallel_scale`` is measured against the mesh the cluster ran
    *before* the failure: ``prior_chips`` (default: the historical two-pod
    cluster, ``2 * pod_size``).
    """
    if surviving_chips < model_axis:
        raise ValueError(f"cannot form a mesh: {surviving_chips} chips < model axis {model_axis}")
    if prior_chips is None:
        prior_chips = 2 * pod_size
    if prior_chips < model_axis:
        raise ValueError(f"prior cluster invalid: {prior_chips} chips < model axis {model_axis}")
    old_dp = _dp_degree(prior_chips, model_axis, pod_size)
    pods = surviving_chips // pod_size
    if pods >= 2:
        data = pod_size // model_axis
        return ElasticPlan(
            (pods, data, model_axis), ("pod", "data", "model"),
            surviving_chips - pods * pod_size, pods * data / old_dp,
        )
    data = surviving_chips // model_axis
    return ElasticPlan(
        (data, model_axis), ("data", "model"), surviving_chips - data * model_axis,
        data / old_dp,
    )
