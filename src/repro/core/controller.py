"""Online streaming controller: the piece that makes FastVA deployable.

The paper assumes B and T_c are known; a real deployment estimates them from
observed transfers.  ``OnlineController`` keeps EWMA estimates (with a
pessimism factor for deadline safety), invokes the configured policy per
round, and exposes the same plan stream the simulator consumes — so the
whole controller can be replayed deterministically in tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .profiles import ModelProfile, NetworkState, StreamSpec
from .registry import PolicySpec
from .schedule import RoundPlan
from .simulator import Policy


@dataclass
class BandwidthEstimator:
    """EWMA over observed (bytes, seconds) upload samples.

    ``pessimism`` < 1 shades the estimate down so a late sample does not blow
    a deadline: the scheduler plans against bandwidth * pessimism.
    """

    init_bps: float = 2e6
    beta: float = 0.3  # EWMA weight of the newest sample
    pessimism: float = 0.9
    _bps: float = field(default=0.0, init=False)
    _rtt: float = field(default=0.1, init=False)  # stub prior until the first sample
    samples: int = field(default=0, init=False)
    rtt_samples: int = field(default=0, init=False)

    def __post_init__(self) -> None:
        self._bps = self.init_bps

    def observe_upload(self, nbytes: float, seconds: float) -> None:
        if seconds <= 0 or nbytes <= 0:
            return
        sample = nbytes * 8.0 / seconds
        self._bps = (1 - self.beta) * self._bps + self.beta * sample
        self.samples += 1

    def observe_rtt(self, seconds: float) -> None:
        # The 0.1 s default is a stub prior, not a measurement: the first real
        # sample replaces it outright; later samples blend in by EWMA.
        if self.rtt_samples == 0:
            self._rtt = seconds
        else:
            self._rtt = (1 - self.beta) * self._rtt + self.beta * seconds
        self.rtt_samples += 1

    def state(self) -> NetworkState:
        return NetworkState(bandwidth_bps=self._bps * self.pessimism, rtt=self._rtt)


@dataclass
class OnlineController:
    """Drives a policy over a live stream with estimated network state.

    The policy is a registry :class:`PolicySpec` (or a bare name).  The
    legacy ``policy_name``/``alpha`` pair is still accepted when ``policy``
    is left unset, and is folded into a spec — so the controller itself is
    serializable as part of a ``ScenarioSpec``.
    """

    models: Sequence[ModelProfile]
    stream: StreamSpec
    policy: PolicySpec | str | None = None
    policy_name: str = "max_accuracy"  # legacy; used only when policy is None
    alpha: float | None = None  # legacy; used only when policy is None
    estimator: BandwidthEstimator = field(default_factory=BandwidthEstimator)
    _policy: Policy = field(init=False)
    npu_busy_abs: float = field(default=0.0, init=False)
    rounds: int = field(default=0, init=False)

    def __post_init__(self) -> None:
        self.policy = PolicySpec.coerce(self.policy, policy_name=self.policy_name, alpha=self.alpha)
        self.policy_name = self.policy.name
        self._policy = self.policy.build()

    def next_plan(self, head_frame: int) -> RoundPlan:
        t0 = head_frame * self.stream.gamma
        plan = self._policy(
            self.models,
            self.stream,
            self.estimator.state(),
            npu_free=max(0.0, self.npu_busy_abs - t0),
        )
        self.npu_busy_abs = t0 + plan.npu_busy_until
        self.rounds += 1
        return plan

    # Feedback hooks called by the serving runtime after real transfers run.
    def report_upload(self, nbytes: float, seconds: float) -> None:
        self.estimator.observe_upload(nbytes, seconds)

    def report_rtt(self, seconds: float) -> None:
        self.estimator.observe_rtt(seconds)
