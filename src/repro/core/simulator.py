"""Event-driven stream simulator: executes any policy's round plans over a
video trace with a (possibly time-varying) network, and audits feasibility.

The simulator is the ground truth for every figure benchmark: policies only
*propose* plans; accuracy/utility are re-derived here from the profiles, and
``validate_plan`` rejects any deadline/overlap violation (a violating frame
counts as missed, accuracy 0 — defence against buggy policies).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Protocol, Sequence

from .profiles import ModelProfile, NetworkState, StreamSpec
from .schedule import RoundPlan, StreamStats, Where, validate_plan


class Policy(Protocol):
    def __call__(
        self,
        models: Sequence[ModelProfile],
        stream: StreamSpec,
        net: NetworkState,
        *,
        npu_free: float,
    ) -> RoundPlan: ...


@dataclass
class Trace:
    """Bandwidth/RTT as functions of time (seconds) — supports live variation."""

    bandwidth_bps: Callable[[float], float]
    rtt: Callable[[float], float] = lambda t: 0.100

    @staticmethod
    def constant(mbps: float, rtt_ms: float = 100.0) -> "Trace":
        return Trace(lambda t: mbps * 1e6, lambda t: rtt_ms / 1e3)

    @staticmethod
    def piecewise(points: Sequence[tuple[float, float]], rtt_ms: float = 100.0) -> "Trace":
        """points: [(t_start, mbps), ...] sorted by t_start."""
        pts = sorted(points)

        def bw(t: float) -> float:
            cur = pts[0][1]
            for ts, v in pts:
                if t >= ts:
                    cur = v
                else:
                    break
            return cur * 1e6

        return Trace(bw, lambda t: rtt_ms / 1e3)

    def at(self, t: float) -> NetworkState:
        return NetworkState(bandwidth_bps=self.bandwidth_bps(t), rtt=self.rtt(t))


def simulate(
    policy: Policy,
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    trace: Trace,
    n_frames: int,
    *,
    strict: bool = True,
) -> StreamStats:
    """Run ``policy`` over ``n_frames`` frames; return audited stream stats."""
    stats = StreamStats(frames_total=n_frames, elapsed=n_frames * stream.gamma)
    gamma = stream.gamma
    head = 0
    npu_busy_abs = 0.0
    while head < n_frames:
        t0 = head * gamma
        net = trace.at(t0)
        wall = time.perf_counter()
        plan = policy(models, stream, net, npu_free=max(0.0, npu_busy_abs - t0))
        stats.schedule_time += time.perf_counter() - wall
        stats.schedule_calls += 1

        horizon = max(plan.horizon, 1)
        errors = validate_plan(plan, gamma=gamma, deadline=stream.deadline) if strict else []
        bad_frames = {int(e.split()[1].rstrip(":")) for e in errors} if errors else set()

        for d in plan.decisions:
            if d.frame >= horizon or head + d.frame >= n_frames:
                continue
            if not d.is_processed() or d.frame in bad_frames:
                continue
            m = models[d.model]
            acc = (
                m.accuracy(d.resolution, where="server")
                if d.where is Where.SERVER
                else m.accuracy(stream.r_max, where="npu")
            )
            stats.frames_processed += 1
            stats.accuracy_sum += acc
        stats.frames_missed_deadline += len(bad_frames)
        npu_busy_abs = t0 + plan.npu_busy_until
        head += horizon
    return stats


def make_policy(name: str, *, alpha: float | None = None, **kw) -> Policy:
    """Factory mapping paper policy names to plan_round callables."""
    from . import baselines, max_accuracy, max_utility

    if name == "max_accuracy":
        return lambda m, s, n, *, npu_free: max_accuracy.plan_round(m, s, n, npu_free=npu_free, **kw)
    if name == "max_utility":
        assert alpha is not None, "max_utility needs alpha"
        return lambda m, s, n, *, npu_free: max_utility.plan_round(
            m, s, n, alpha=alpha, npu_free=npu_free, **kw
        )
    if name == "offload":
        return lambda m, s, n, *, npu_free: baselines.offload_plan_round(
            m, s, n, npu_free=npu_free, alpha=alpha, **kw
        )
    if name == "local":
        return lambda m, s, n, *, npu_free: baselines.local_plan_round(
            m, s, n, npu_free=npu_free, alpha=alpha, **kw
        )
    if name == "deepdecision":
        return lambda m, s, n, *, npu_free: baselines.deepdecision_plan_round(
            m, s, n, npu_free=npu_free, alpha=alpha, **kw
        )
    raise ValueError(f"unknown policy {name!r}")
