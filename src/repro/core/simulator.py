"""Event-driven stream simulator: executes any policy's round plans over a
video trace with a (possibly time-varying) network, and audits feasibility.

The simulator is the ground truth for every figure benchmark: policies only
*propose* plans; accuracy/utility are re-derived here from the profiles, and
``validate_plan`` rejects any deadline/overlap violation (a violating frame
counts as missed, accuracy 0 — defence against buggy policies).

Two entry points (both have vectorized grid counterparts: ``sim_batch``
for single streams, ``sim_multi_batch`` for interacting fleets):
  simulate        one stream, the paper's setting (§VI figures);
  simulate_multi  N streams contending for one shared uplink + edge server,
                  driven by ``edge_server.EdgeServerScheduler`` (see
                  docs/scheduling.md, "Edge-server admission").  Uploads share
                  the link as a fluid: each in-flight transfer gets a
                  weight-proportional share of ``Trace`` bandwidth, capped at
                  its scheduler-granted rate — so coordinated clients see
                  exactly what they were promised, while uncoordinated (fifo)
                  clients stretch each other's uploads and miss deadlines.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Protocol, Sequence

from .audit import TrackState, apply_round, apply_track_round, audit_round
from .edge_server import fluid_rates
from .profiles import ModelProfile, NetworkState, StreamSpec
from .schedule import RoundPlan, StreamStats
from .tracking import WorkloadSpec


class Policy(Protocol):
    def __call__(
        self,
        models: Sequence[ModelProfile],
        stream: StreamSpec,
        net: NetworkState,
        *,
        npu_free: float,
    ) -> RoundPlan: ...


@dataclass
class Trace:
    """Bandwidth/RTT as functions of time (seconds) — supports live variation."""

    bandwidth_bps: Callable[[float], float]
    rtt: Callable[[float], float] = lambda t: 0.100

    @staticmethod
    def constant(mbps: float, rtt_ms: float = 100.0) -> "Trace":
        return Trace(lambda t: mbps * 1e6, lambda t: rtt_ms / 1e3)

    @staticmethod
    def piecewise(points: Sequence[tuple[float, float]], rtt_ms: float = 100.0) -> "Trace":
        """points: [(t_start, mbps), ...] with strictly increasing t_start.

        Non-monotonic time points or negative bandwidth raise ``ValueError``
        up front instead of producing silent nonsense lookups later.
        """
        pts = list(points)
        if not pts:
            raise ValueError("piecewise trace needs at least one (t_start, mbps) point")
        for (t0, _), (t1, _) in zip(pts, pts[1:]):
            if t1 <= t0:
                raise ValueError(
                    f"piecewise trace time points must be strictly increasing, "
                    f"got t={t1!r} after t={t0!r}"
                )
        for ts, v in pts:
            if v < 0:
                raise ValueError(
                    f"piecewise trace bandwidth must be >= 0 Mbps, got {v!r} at t={ts!r}"
                )

        def bw(t: float) -> float:
            cur = pts[0][1]
            for ts, v in pts:
                if t >= ts:
                    cur = v
                else:
                    break
            return cur * 1e6

        return Trace(bw, lambda t: rtt_ms / 1e3)

    def at(self, t: float) -> NetworkState:
        return NetworkState(bandwidth_bps=self.bandwidth_bps(t), rtt=self.rtt(t))


def simulate(
    policy: Policy,
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    trace: Trace,
    n_frames: int,
    *,
    strict: bool = True,
    workload: WorkloadSpec | None = None,
) -> StreamStats:
    """Run ``policy`` over ``n_frames`` frames; return audited stream stats.

    The audit semantics (what validates, what scores, what counts missed)
    live in :mod:`repro.core.audit` and are shared with the vectorized
    ``sim_batch`` backend — this loop is the reference implementation.

    ``workload`` selects the frame semantics: ``None`` / ``"classify"``
    keeps the paper's independent frames; ``"track"`` executes rounds as
    detect+track intervals (``audit.apply_track_round``), carrying the
    detection-age state across rounds.
    """
    track = workload is not None and workload.is_track
    ret = workload.retention if track else 0.0
    state = TrackState()
    stats = StreamStats(frames_total=n_frames, elapsed=n_frames * stream.gamma)
    gamma = stream.gamma
    head = 0
    npu_busy_abs = 0.0
    while head < n_frames:
        t0 = head * gamma
        net = trace.at(t0)
        wall = time.perf_counter()
        plan = policy(models, stream, net, npu_free=max(0.0, npu_busy_abs - t0))
        stats.schedule_time += time.perf_counter() - wall
        stats.schedule_calls += 1

        horizon, bad_frames = audit_round(
            plan, gamma=gamma, deadline=stream.deadline, strict=strict
        )
        if track:
            state = apply_track_round(
                stats,
                plan,
                models=models,
                stream=stream,
                state=state,
                head=head,
                n_frames=n_frames,
                horizon=horizon,
                bad_frames=bad_frames,
                retention=ret,
            )
        else:
            apply_round(
                stats,
                plan,
                models=models,
                stream=stream,
                head=head,
                n_frames=n_frames,
                horizon=horizon,
                bad_frames=bad_frames,
            )
        npu_busy_abs = t0 + plan.npu_busy_until
        head += horizon
    return stats


def make_policy(name: str, *, alpha: float | None = None, **kw) -> Policy:
    """Deprecated shim over the policy registry — prefer ``PolicySpec``.

    Builds the named policy through :mod:`repro.core.registry`, so unknown
    names, unknown parameters, and a missing required ``alpha`` (e.g. for
    ``max_utility``) all raise ``ValueError`` instead of being silently
    swallowed.  ``alpha=None`` is dropped before validation because the
    legacy signature passed it unconditionally.
    """
    import warnings

    from .registry import PolicySpec

    warnings.warn(
        "make_policy() is deprecated; construct policies with "
        "repro.core.registry.PolicySpec(name, params) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    params = dict(kw)
    if alpha is not None:
        params["alpha"] = alpha
    return PolicySpec(name, params).build()


# ---------------------------------------------------------------------------
# Multi-stream simulation: N clients, one shared uplink, one edge server.
# ---------------------------------------------------------------------------

_EPS = 1e-9
# An upload also counts as delivered below this many residual bits (far below
# any real frame — smallest is ~24k bits).  The primary completion mechanism
# is by event identity (see ``due`` in ``simulate_multi``); this threshold
# only mops up transfers that cross zero during a planning-event advance.
_BITS_EPS = 1e-3


@dataclass
class _Upload:
    """One in-flight offloaded frame on the shared (fluid) uplink."""

    client_id: int
    bits_left: float
    weight: float
    rate_cap: float  # scheduler-granted bps; inf under the fifo policy
    deadline_abs: float
    accuracy: float
    t_server: float
    rtt: float
    start_at: float = 0.0  # abs time the frame exists and may start uploading
    # Tracking workload only: absolute frame index of the detection this
    # upload carries (-1 for classification frames).  On on-time completion
    # the client's TrackState refreshes iff this is newer than what a later
    # NPU detection may already have installed.
    det_frame: int = -1


@dataclass
class MultiStreamStats:
    """Per-client audited stats plus fleet-level aggregates."""

    per_client: list[StreamStats]
    server_jobs: int = 0
    server_busy_s: float = 0.0
    elapsed: float = 0.0

    @property
    def aggregate_accuracy(self) -> float:
        """Fleet mean accuracy over all frames of all clients (missed = 0)."""
        total = sum(s.frames_total for s in self.per_client)
        return sum(s.accuracy_sum for s in self.per_client) / total if total else 0.0

    @property
    def miss_rates(self) -> list[float]:
        return [
            s.frames_missed_deadline / s.frames_total if s.frames_total else 0.0
            for s in self.per_client
        ]

    @property
    def max_miss_rate(self) -> float:
        return max(self.miss_rates, default=0.0)

    @property
    def server_utilization(self) -> float:
        return self.server_busy_s / self.elapsed if self.elapsed > 0 else 0.0


def _fluid_rates(bandwidth_bps: float, uploads: Sequence[_Upload]) -> list[float]:
    """Weighted max-min (water-filling) split of the link across uploads.

    Pure arithmetic lives in :func:`repro.core.edge_server.fluid_rates`
    (shared with the vectorized fleet backend); this wrapper just unpacks
    the in-flight ``_Upload`` records.
    """
    return fluid_rates(
        bandwidth_bps,
        [u.weight for u in uploads],
        [u.rate_cap for u in uploads],
        eps=_EPS,
    )


def simulate_multi(
    scheduler,
    trace: Trace,
    n_frames: int,
    *,
    strict: bool = True,
    workload: WorkloadSpec | None = None,
) -> MultiStreamStats:
    """Drive every client of ``scheduler`` (an ``EdgeServerScheduler``) for
    ``n_frames`` frames each over one shared ``trace``.

    Event loop: the next event is either some client's round boundary (it
    plans against its *allocated* bandwidth) or an upload completing on the
    fluid link.  NPU decisions are audited exactly as in :func:`simulate`;
    offloaded frames are audited at *actual* completion — shared-link upload
    time, then a server worker (FIFO queue over ``scheduler.capacity`` slots),
    then the RTT — so a plan that assumed more bandwidth than the link really
    delivers shows up as deadline misses here, not as optimistic accuracy.

    With a tracking ``workload``, detections contend on the shared link but
    tracker-carried frames do not: NPU detections refresh the client's
    detection state at the planning event, offloaded detections at their
    *actual* on-time completion (guarded by detection recency, so a slow
    upload never clobbers a newer NPU detection), and tracked frames score
    against the state current at their round's planning event.
    """
    scheduler.reset()  # clock restarts at 0; stale leases/backlog must not leak in
    track = workload is not None and workload.is_track
    ret = workload.retention if track else 0.0
    clients = list(scheduler.clients.values())
    stats = {
        c.client_id: StreamStats(frames_total=n_frames, elapsed=n_frames * c.stream.gamma)
        for c in clients
    }
    tstate = {c.client_id: TrackState() for c in clients}
    head = {c.client_id: 0 for c in clients}
    npu_busy_abs = {c.client_id: 0.0 for c in clients}
    uploads: list[_Upload] = []
    n_workers = max(int(scheduler.capacity), 1)
    worker_free = [0.0] * n_workers
    server_jobs = 0
    server_busy = 0.0
    now = 0.0

    def next_plan_event() -> tuple[float, "object"] | None:
        best = None
        for c in clients:
            if head[c.client_id] >= n_frames:
                continue
            t = head[c.client_id] * c.stream.gamma
            key = (t, -c.priority, -c.weight, c.client_id)
            if best is None or key < best[0]:
                best = (key, c)
        return (best[0][0], best[1]) if best is not None else None

    # Server-slot leases are held until the job leaves the server, not just
    # until its upload drains: (abs finish time, client_id), kept sorted.
    pending_releases: list[tuple[float, int]] = []

    while True:
        plan_ev = next_plan_event()
        # Earliest upload completion under current rates (piecewise-constant
        # approximation: rates are re-evaluated at every event boundary).
        # A client's radio is serial: only its OLDEST pending upload transmits
        # (later frames of a multi-offload round queue behind it), and frames
        # that have not arrived yet (start_at in the future) hold no link
        # share; their activation is an event of its own.
        heads: dict[int, _Upload] = {}
        for u in uploads:
            heads.setdefault(u.client_id, u)
        active = [u for u in heads.values() if u.start_at <= now + _EPS]
        rates = _fluid_rates(trace.at(now).bandwidth_bps, active) if active else []
        t_done = None
        due: list[_Upload] = []
        if active:
            finish_at = [
                now + (u.bits_left / r if r > _EPS else float("inf"))
                for u, r in zip(active, rates)
            ]
            t_done = min(finish_at)
            if t_done < float("inf"):
                # Completion events drain by identity, not by a residual-bits
                # threshold: near the end of a transfer the remaining time can
                # underflow ``now + dt == now`` and a threshold test livelocks.
                due = [u for u, t in zip(active, finish_at) if t <= t_done + _EPS]
            else:
                t_done = None
        t_start = min(
            (u.start_at for u in heads.values() if u.start_at > now + _EPS), default=None
        )
        events = [t for t in (t_done, t_start) if t is not None]
        if plan_ev is not None:
            events.append(plan_ev[0])
        if not events:
            break
        t_next = min(events)
        client = plan_ev[1] if plan_ev is not None and plan_ev[0] <= t_next + _EPS else None

        # Advance the fluid link to t_next (active uploads only).
        if active and t_next > now:
            for u, r in zip(active, rates):
                u.bits_left = max(0.0, u.bits_left - r * (t_next - now))
        if t_done is not None and t_next >= t_done - _EPS:
            for u in due:  # this IS the completion event for these uploads
                u.bits_left = 0.0
        now = max(now, t_next)

        # Free server slots whose jobs have finished by now.
        while pending_releases and pending_releases[0][0] <= now + _EPS:
            scheduler.release(pending_releases.pop(0)[1])

        # Drain any uploads that finished: server queue, then deadline audit.
        # Only head uploads can have transmitted, so queued ones stay put.
        still: list[_Upload] = []
        for u in uploads:
            if u.bits_left > _BITS_EPS or u.start_at > now + _EPS:
                still.append(u)
                continue
            scheduler.release_link(u.client_id)
            wi = min(range(n_workers), key=lambda i: worker_free[i])
            start = max(now, worker_free[wi])
            finish = start + u.t_server
            worker_free[wi] = finish
            server_jobs += 1
            server_busy += u.t_server
            pending_releases.append((finish, u.client_id))
            pending_releases.sort()
            s = stats[u.client_id]
            if finish + u.rtt <= u.deadline_abs + _EPS:
                s.frames_processed += 1
                s.frames_offloaded += 1
                s.accuracy_sum += u.accuracy
                if track and u.det_frame > tstate[u.client_id].det_frame:
                    tstate[u.client_id] = TrackState(u.accuracy, u.det_frame)
            else:
                s.frames_missed_deadline += 1
        uploads = still

        if client is None:
            continue

        # Round boundary for ``client``: allocate, plan, execute.
        cid = client.client_id
        t0 = head[cid] * client.stream.gamma
        net_full = trace.at(t0)
        grant = scheduler.allocate(cid, t0, net_full)
        net_c = NetworkState(bandwidth_bps=grant, rtt=net_full.rtt)
        s = stats[cid]
        wall = time.perf_counter()
        plan = client.plan(net_c, npu_free=max(0.0, npu_busy_abs[cid] - t0))
        s.schedule_time += time.perf_counter() - wall
        s.schedule_calls += 1

        horizon, bad_frames = audit_round(
            plan,
            gamma=client.stream.gamma,
            deadline=client.stream.deadline,
            strict=strict,
            npu_only=True,
        )

        def offload(d, m, *, cid=cid, client=client, t0=t0, grant=grant, rtt=net_full.rtt):
            # SERVER: hand to the shared link; audited on completion.
            scheduler.register(cid, grant, t=t0, server_s=m.t_server)
            uploads.append(
                _Upload(
                    client_id=cid,
                    bits_left=client.stream.frame_bytes(d.resolution) * 8.0,
                    weight=max(client.weight, _EPS),
                    rate_cap=grant if scheduler.policy != "fifo" else float("inf"),
                    deadline_abs=t0 + d.frame * client.stream.gamma + client.stream.deadline,
                    accuracy=m.accuracy(d.resolution, where="server"),
                    t_server=m.t_server,
                    rtt=rtt,
                    # The plan's start is round-relative; a frame cannot
                    # transmit before it exists (matters for policies that
                    # offload non-head frames, e.g. DeepDecision).
                    start_at=t0 + max(d.start, 0.0),
                    # Tracking: the upload carries this round's detection.
                    det_frame=head[cid] + d.frame if track else -1,
                )
            )

        if track:
            tstate[cid] = apply_track_round(
                s,
                plan,
                models=client.models,
                stream=client.stream,
                state=tstate[cid],
                head=head[cid],
                n_frames=n_frames,
                horizon=horizon,
                bad_frames=bad_frames,
                retention=ret,
                on_offload=offload,
            )
        else:
            apply_round(
                s,
                plan,
                models=client.models,
                stream=client.stream,
                head=head[cid],
                n_frames=n_frames,
                horizon=horizon,
                bad_frames=bad_frames,
                on_offload=offload,
            )
        npu_busy_abs[cid] = t0 + plan.npu_busy_until
        head[cid] += horizon

    # Uploads stranded at exit (link went dead with frames in flight): every
    # one is a deadline miss, and its leases must not leak.
    for u in uploads:
        scheduler.release_link(u.client_id)
        scheduler.release(u.client_id)
        stats[u.client_id].frames_missed_deadline += 1
    for _, cid in pending_releases:
        scheduler.release(cid)

    elapsed = max((s.elapsed for s in stats.values()), default=0.0)
    return MultiStreamStats(
        per_client=[stats[c.client_id] for c in clients],
        server_jobs=server_jobs,
        server_busy_s=server_busy,
        elapsed=elapsed,
    )
