"""Max-Utility scheduling (paper §V, Algorithm 2).

Round shape mirrors Max-Accuracy: the head frame I_0 is offloaded with the
(j, r) maximizing ``min(B/S(I_0,r), f) + alpha * a(j, r)`` subject to the
deadline (the rate term is capped at the stream fps — an uncapped B/S would
reward resolutions smaller than the camera can even produce).  The n_l frames
buffered during the upload go through a dominance-pruned DP over triples
(t, u, m): time the NPU frees, utility accrued, frames processed.  Frames may
be SKIPPED — that is the whole point of Max-Utility (paper Eq. 12/13).

Differences from the paper's pseudocode, both robustness fixes:
  * backtracking uses explicit parent pointers instead of float-equality
    matching (lines 19-27 of Algorithm 2);
  * ``n_l = floor(S/(B*gamma))`` — Algorithm 2 line 9 says ``S/B`` which is a
    time, not a frame count; §IV and the text define the frame count form.

docs/scheduling.md explains the weighted objective and the Pareto pruning in
prose, alongside the edge-server admission logic that wraps this solver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .profiles import ModelProfile, NetworkState, StreamSpec
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

NEG = -1e18


@dataclass
class Triple:
    t: float  # NPU free time
    u: float  # utility accrued over the local window
    m: int  # frames processed so far
    parent: "Triple | None" = None
    action: tuple[int, int] = (-1, -1)  # (frame k, model j); j=-1 => skip


def _prune(cands: list[Triple], cap: int = 256) -> list[Triple]:
    """Keep the Pareto front: (t', u') dominates (t, u) iff t' <= t and u' >= u."""
    cands.sort(key=lambda c: (c.t, -c.u))
    front: list[Triple] = []
    best_u = NEG
    for c in cands:
        if c.u > best_u + 1e-12:
            front.append(c)
            best_u = c.u
    if len(front) > cap:
        # Safety net (the Pareto set is tiny for realistic profiles): keep the
        # highest-utility cap entries, preserving t-order.
        front = sorted(front, key=lambda c: -c.u)[:cap]
        front.sort(key=lambda c: c.t)
    return front


@dataclass(frozen=True)
class LocalUtilityResult:
    utility: float
    decisions: list[tuple[int, int]]  # (frame k, model j) for processed frames
    npu_free: float
    processed: int
    feasible: bool = True


def local_utility_dp(
    models: Sequence[ModelProfile],
    *,
    n_frames: int,
    gamma: float,
    deadline: float,
    alpha: float,
    npu_free: float,
    first_arrival: float,
    window: float,
) -> LocalUtilityResult:
    """Dominance-pruned DP over (t, u, m) triples; frames may be skipped.

    ``window`` is the paper's ``n_l * gamma`` normalizer for the rate term.
    """
    if n_frames <= 0:
        return LocalUtilityResult(0.0, [], npu_free, 0)
    local = [(j, m) for j, m in enumerate(models) if m.runs_local]
    acc = {j: (m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0) for j, m in local}
    window = max(window, gamma)

    U: list[Triple] = [Triple(t=max(npu_free, 0.0), u=0.0, m=0)]
    for k in range(n_frames):
        arrival = first_arrival + k * gamma
        cands: list[Triple] = list(U)  # "no processing": carry every triple over
        for tri in U:
            for j, mod in local:
                t2 = max(tri.t, arrival) + mod.t_npu
                if t2 > arrival + deadline + 1e-12:
                    continue
                m = tri.m
                # Paper's running update: strip the old rate term, average in
                # the new accuracy, re-add the rate term for m+1 frames.
                mean_acc_term = (m / (m + 1)) * (tri.u - m / window) + alpha * acc[j] / (m + 1)
                u2 = mean_acc_term + (m + 1) / window
                cands.append(Triple(t=t2, u=u2, m=m + 1, parent=tri, action=(k, j)))
        U = _prune(cands)

    best = max(U, key=lambda c: c.u)
    decisions: list[tuple[int, int]] = []
    node: Triple | None = best
    while node is not None and node.parent is not None:
        decisions.append(node.action)
        node = node.parent
    decisions.reverse()
    return LocalUtilityResult(best.u, decisions, best.t, best.m)


def _round_utility(
    decisions: Sequence[Decision], models, stream: StreamSpec, horizon: int, alpha: float
) -> float:
    """The true round objective: processed rate + alpha * mean processed acc."""
    processed = [d for d in decisions if d.is_processed()]
    if not processed:
        return 0.0
    acc = 0.0
    for d in processed:
        m = models[d.model]
        acc += (
            m.accuracy(d.resolution, where="server")
            if d.where is Where.SERVER
            else m.accuracy(stream.r_max, where="npu")
        )
    return len(processed) / (max(horizon, 1) * stream.gamma) + alpha * acc / len(processed)


def _local_decisions(
    models,
    stream: StreamSpec,
    dp: LocalUtilityResult,
    *,
    n_frames: int,
    first_frame_id: int,
    first_arrival: float,
    npu_free: float,
) -> tuple[list[Decision], float]:
    processed_local = {k: j for k, j in dp.decisions}
    decisions: list[Decision] = []
    free = max(npu_free, 0.0)
    npu_last = free
    for k in range(n_frames):
        frame_id = k + first_frame_id
        arrival = first_arrival + k * stream.gamma
        if k in processed_local:
            j = processed_local[k]
            start = max(free, arrival)
            free = start + models[j].t_npu
            npu_last = free
            decisions.append(
                Decision(frame_id, Where.NPU, j, stream.r_max, start=start, finish=free)
            )
        else:
            decisions.append(Decision(frame_id, Where.SKIP))
    return decisions, npu_last


@register_policy(
    "max_utility",
    params=(Param.number("alpha", doc="paper Eq. (9) accuracy weight (required)"),),
    doc="Paper §V Algorithm 2: per-round Max-Utility (rate + alpha * accuracy).",
    # Network-aware vectorized backend (core/sim_batch): whole scenario
    # grids — constant AND piecewise traces — run as one jit+vmap program.
    # Fleet grids route to the dedicated fleet planner in core/sim_multi_batch:
    # per-client DP planning over granted (water-filled) bandwidth composed
    # with the shared-link completion audit, so contention is exact — not a
    # replication trick.
    batched=True,
    batched_multi=True,
    # Online sweeps (core/sim_online_batch): the believed-network re-planning
    # loop with scan-carried EWMA estimator state, audited on the true trace.
    batched_online=True,
)
def plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    alpha: float,
    npu_free: float = 0.0,
) -> RoundPlan:
    """One Max-Utility round for head frame I_0 arriving at t=0.

    Two candidates compete on the true round objective (rate + alpha * mean
    processed accuracy): the paper's offload round (offload phase + local DP
    for the buffered frames) and a pure-local round.  Without the latter,
    Max-Utility would offload low-accuracy frames it should keep on the NPU
    whenever *any* offload is feasible, and lose to the Local baseline at low
    bandwidth — contradicting Fig. 9.
    """
    gamma, T, f = stream.gamma, stream.deadline, stream.fps

    # --- offload phase: argmax_{j,r} capped-rate + alpha * a(j, r) ---
    best_off: tuple[float, int, int, float] | None = None  # (u', j, r, t_up)
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        for j, m in enumerate(models):
            if not m.runs_server:
                continue
            if t_up + m.t_server + net.rtt > T:
                continue
            u = min(1.0 / max(t_up, 1e-9), f) + alpha * m.accuracy(r, where="server")
            if best_off is None or u > best_off[0]:
                best_off = (u, j, r, t_up)

    candidates: list[RoundPlan] = []

    n_w = max(int(np.floor(T / gamma)), 1)
    if best_off is not None:
        _, j0, r0, t_up = best_off
        # Paper Algorithm 2 sizes the local phase to the link-busy frames
        # (n_l); we extend it to the full deadline window so the rate term of
        # a short-upload round is not inflated by a 1-frame horizon — a
        # beyond-paper fix that makes Max-Utility dominate Local per-round
        # (EXPERIMENTS.md §Paper-repro discusses both variants).
        n_l = int(np.floor(t_up / gamma))
        n_plan = max(n_l, n_w - 1)
        dp = local_utility_dp(
            models,
            n_frames=n_plan,
            gamma=gamma,
            deadline=T,
            alpha=alpha,
            npu_free=npu_free,
            first_arrival=gamma,
            window=max(n_plan, 1) * gamma,
        )
        local_dec, npu_last = _local_decisions(
            models, stream, dp, n_frames=n_plan, first_frame_id=1, first_arrival=gamma,
            npu_free=npu_free,
        )
        decisions = [
            Decision(0, Where.SERVER, j0, r0, start=0.0, finish=t_up + net.rtt + models[j0].t_server)
        ] + local_dec
        horizon = n_plan + 1
        candidates.append(
            RoundPlan(
                decisions=decisions,
                horizon=horizon,
                expected_utility=_round_utility(decisions, models, stream, horizon, alpha),
                npu_busy_until=npu_last,
                net_busy_until=t_up,
            )
        )

    # Pure-local candidate over one deadline window.
    dp_l = local_utility_dp(
        models,
        n_frames=n_w,
        gamma=gamma,
        deadline=T,
        alpha=alpha,
        npu_free=npu_free,
        first_arrival=0.0,
        window=n_w * gamma,
    )
    dec_l, npu_last_l = _local_decisions(
        models, stream, dp_l, n_frames=n_w, first_frame_id=0, first_arrival=0.0, npu_free=npu_free
    )
    candidates.append(
        RoundPlan(
            decisions=dec_l,
            horizon=n_w,
            expected_utility=_round_utility(dec_l, models, stream, n_w, alpha),
            npu_busy_until=npu_last_l,
        )
    )

    best = max(candidates, key=lambda p: p.expected_utility)
    if not any(d.is_processed() for d in best.decisions):
        return RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
    return best
