"""Persistent compilation cache + compile-count instrumentation.

The sweep engines' planner programs cost seconds to tens of seconds to
compile and milliseconds to run; at 10^5-point scale the only tolerable
cold start is one that *loads* executables instead of rebuilding them.
:func:`enable_compile_cache` points jax's persistent compilation cache at
a directory (opt-in: ``Session.run_sweep(compile_cache=...)``, the sweep
CLI's ``--compile-cache``, or the ``REPRO_COMPILE_CACHE`` environment
variable), with the size/time thresholds zeroed so every planner program
is cached.  Combined with the bucketing policy (:mod:`.bucketing` — stable
shapes => byte-identical jaxprs => identical cache keys), a re-run of any
sweep on a warm directory skips XLA entirely.

:class:`CompileCounter` counts what actually happened, via
``jax.monitoring`` events:

* ``backend_compiles`` — executable builds the backend was asked for
  (``/jax/core/compile/backend_compile_duration``; fires on real compiles
  AND on persistent-cache loads),
* ``cache_misses`` / ``cache_hits`` — persistent-cache outcomes (these
  events only fire when the cache is enabled).

``compiles`` resolves the authoritative "XLA really ran" count from
whichever signals are live, so benches and tests assert on one number.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import jax
import jax.monitoring
from jax._src import compilation_cache as _compilation_cache
from jax._src import monitoring as _monitoring

_ENV_VAR = "REPRO_COMPILE_CACHE"


def enable_compile_cache(cache_dir: str | os.PathLike) -> str:
    """Enable jax's persistent compilation cache at ``cache_dir``.

    Idempotent; creates the directory.  Thresholds are zeroed so even
    fast-compiling programs persist (the default 1s floor would skip the
    small shape buckets that dominate smoke grids).
    """
    path = os.fspath(cache_dir)
    os.makedirs(path, exist_ok=True)
    changed = jax.config.jax_compilation_cache_dir != path
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    # jax initializes the file cache lazily at the first compile; a compile
    # before this call pins it *disabled* (config updates alone never
    # re-initialize).  Reset so the next compile re-reads the config — the
    # on-disk contents are untouched.
    if changed or getattr(_compilation_cache, "_cache", None) is None:
        _compilation_cache.reset_cache()
    return path


def default_cache_dir() -> str | None:
    """The opt-in cache directory from the environment, if any."""
    return os.environ.get(_ENV_VAR) or None


@dataclass
class CompileCounter:
    """Context manager counting compiles/cache traffic within its scope."""

    backend_compiles: int = 0
    cache_misses: int = 0
    cache_hits: int = 0
    cache_requests: int = 0
    _handles: list = field(default_factory=list, repr=False)

    @property
    def compiles(self) -> int:
        """Executables XLA actually built (not served from the disk cache)."""
        # With the persistent cache live, misses are authoritative (backend
        # builds also fire on disk loads); without it the hit/miss events
        # never fire and every backend build is real.  The request event is
        # NOT a liveness signal — jax emits it even with the cache disabled.
        if self.cache_misses or self.cache_hits:
            return self.cache_misses
        return self.backend_compiles

    def __enter__(self) -> "CompileCounter":
        def on_event(event: str, **kw) -> None:
            if event == "/jax/compilation_cache/cache_misses":
                self.cache_misses += 1
            elif event == "/jax/compilation_cache/cache_hits":
                self.cache_hits += 1
            elif event == "/jax/compilation_cache/compile_requests_use_cache":
                self.cache_requests += 1

        def on_duration(event: str, duration: float, **kw) -> None:
            if event == "/jax/core/compile/backend_compile_duration":
                self.backend_compiles += 1

        jax.monitoring.register_event_listener(on_event)
        jax.monitoring.register_event_duration_secs_listener(on_duration)
        self._handles = [on_event, on_duration]
        return self

    def __exit__(self, *exc) -> None:
        on_event, on_duration = self._handles
        _monitoring._unregister_event_listener_by_callback(on_event)
        _monitoring._unregister_event_duration_listener_by_callback(on_duration)
        self._handles = []
