"""The shape-bucketing policy shared by every batched program.

Both sweep engines (:mod:`repro.core.sim_batch`,
:mod:`repro.core.sim_multi_batch`) compile one executable per *shape
bucket*, not per scenario: every compiled dimension — the planning window
``W``, the DP bin count ``NBINS``, trace-segment and frame-horizon pads —
is first rounded UP through the quantizers below, and scenarios are padded
to the bucket size.  Padding is provably inert (padded windows are gated
off, padded bins are unreachable, padded segments carry ``+inf``
sentinels), so bucketing can only change wall-clock and compile counts,
never results.  The contract every quantizer obeys:

* **never shrinks**: ``quant(n) >= n`` for all ``n >= 1``,
* **monotone**: ``m <= n`` implies ``quant(m) <= quant(n)``, so a bigger
  scenario can never land in a smaller bucket, and
* **idempotent on its own outputs**: ``quant(quant(n)) == quant(n)`` —
  bucket sizes are fixed points, so re-bucketing a padded group is a
  no-op and near-identical sweeps hash to the same executable.

These properties (hypothesis-tested in ``tests/test_bucketing.py``) are
what make the persistent compilation cache effective: two sweeps whose
shapes differ only within a bucket produce byte-identical jaxprs and hit
the same cached executable, in-process (``lru_cache`` program factories)
and on disk (``jax_compilation_cache_dir``).

Why these particular ladders:

* ``quant_w`` — planning windows concentrate in 1..128 (fps x deadline);
  a dense-then-sparse ladder caps in-group padding waste at ~2x while
  keeping the number of distinct compiled ``W`` small and stable.
* ``quant_bins`` — DP bin grids are large (10^2..10^4) and cheap per bin;
  a coarse linear quantum (128 for single-stream, 32 for fleet lanes)
  bounds waste at one quantum.
* ``quant_pow2`` — trace-segment counts and frame horizons are tiny;
  powers of two give log-many buckets.
"""
from __future__ import annotations

import numpy as np

# Dense below 8, then spreading steps: the window ladder shared by every
# planner program's compiled W dimension.
W_LADDER = (1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14, 16, 20, 24, 28, 32, 40, 48, 64, 96, 128)


def quant_w(n: int) -> int:
    """Bucket a planning-window length onto the ladder (pow2 past 128)."""
    for w in W_LADDER:
        if n <= w:
            return w
    return int(2 ** np.ceil(np.log2(n)))


def quant_bins(n: int, q: int = 128) -> int:
    """Round a DP bin count up to a multiple of the quantum ``q``."""
    return int(q * np.ceil(max(n, 1) / q))


def quant_pow2(n: int) -> int:
    """Round up to the next power of two (minimum 1)."""
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)
