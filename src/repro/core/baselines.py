"""Baseline policies from paper §VI.C: Offload, Local, DeepDecision.

Each exposes ``plan_round(models, stream, net, *, npu_free, ...) -> RoundPlan``
with the same round contract as Max-Accuracy/Max-Utility, so the simulator
treats every policy identically.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .max_utility import local_utility_dp
from .profiles import ModelProfile, NetworkState, StreamSpec, best_server_model
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

# alpha is the shared mode switch of every baseline: None = accuracy mode
# (paper Fig. 5-8), a float = utility mode with that weight (paper Fig. 9-11).
_ALPHA = Param.number("alpha", None, nullable=True, doc="None = accuracy mode; float = utility weight")


@register_policy(
    "offload",
    params=(_ALPHA,),
    doc="§VI.C Offload baseline: always ship to the edge, resize to keep up.",
    # The round plan below is closed-form in the granted bandwidth, so
    # sim_multi_batch ships a vectorized *fleet* implementation of it:
    # whole (bandwidth x deadline x n_clients x allocation) grids of
    # interacting clients — shared fluid uplink, EdgeServerScheduler
    # admission, server worker queue — run as one jit+vmap program.
    batched_multi=True,
)
def offload_plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    alpha: float | None = None,
) -> RoundPlan:
    """Offload-only: resize each frame so it uploads before the next arrives
    (S/B <= gamma), then let the server pick its most accurate deadline-feasible
    model.  If even the smallest resolution cannot keep up, the frame is
    dropped — this is what makes Offload collapse below ~1.5 Mbps (Fig. 5b).
    """
    gamma, T = stream.gamma, stream.deadline
    best: tuple[float, int, int, float] | None = None  # (score, j, r, t_up)
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        if t_up > gamma:  # cannot sustain the stream at this resolution
            continue
        budget = T - t_up - net.rtt
        pick = best_server_model(models, r, budget)
        if pick is None:
            continue
        j, a = pick
        score = a if alpha is None else min(1.0 / max(t_up, 1e-9), stream.fps) + alpha * a
        if best is None or score > best[0]:
            best = (score, j, r, t_up)
    if best is None:
        return RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
    _, j, r, t_up = best
    fin = t_up + net.rtt + models[j].t_server
    return RoundPlan(
        decisions=[Decision(0, Where.SERVER, j, r, start=0.0, finish=fin)],
        horizon=1,
        expected_accuracy_sum=models[j].accuracy(r, where="server"),
        npu_busy_until=npu_free,
        net_busy_until=t_up,
    )


@register_policy(
    "local",
    params=(
        _ALPHA,
        Param.integer("window_frames", None, nullable=True, doc="DP window; default floor(T/gamma)"),
    ),
    doc="§VI.C Local baseline: NPU-only schedule via the paper's DP.",
)
def local_plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    alpha: float | None = None,
    window_frames: int | None = None,
) -> RoundPlan:
    """Local-only: the paper's Local baseline ("uses the proposed dynamic
    programming technique to find the optimal schedule decision for local
    processing").  With ``alpha`` set it optimizes utility (skips allowed),
    else accuracy (all frames processed; falls back to best-effort skip of the
    head frame if infeasible)."""
    gamma, T = stream.gamma, stream.deadline
    n = window_frames if window_frames is not None else max(int(np.floor(T / gamma)), 1)
    if alpha is None:
        from .max_accuracy import local_window_plan

        plan = local_window_plan(models, stream, npu_free=npu_free, window_frames=n)
        if plan is None:
            return RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
        return plan
    dp = local_utility_dp(
        models,
        n_frames=n,
        gamma=gamma,
        deadline=T,
        alpha=alpha,
        npu_free=npu_free,
        first_arrival=0.0,
        window=n * gamma,
    )
    chosen = {k: j for k, j in dp.decisions}
    decisions = []
    free = max(npu_free, 0.0)
    npu_last = free
    for k in range(n):
        if k in chosen:
            j = chosen[k]
            start = max(free, k * gamma)
            free = start + models[j].t_npu
            npu_last = free
            decisions.append(Decision(k, Where.NPU, j, stream.r_max, start=start, finish=free))
        else:
            decisions.append(Decision(k, Where.SKIP))
    return RoundPlan(
        decisions=decisions, horizon=n, expected_utility=dp.utility, npu_busy_until=npu_last
    )


@register_policy(
    "deepdecision",
    params=(_ALPHA, Param.number("window_s", 1.0, doc="fixed decision window (s)")),
    doc="§VI.C DeepDecision baseline: one (place, model, resolution) per window.",
)
def deepdecision_plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    alpha: float | None = None,
    window_s: float = 1.0,
) -> RoundPlan:
    """Simplified DeepDecision [Ran et al., INFOCOM'18] per paper §VI.C: pick
    ONE (location, model, resolution) at the start of each fixed window and
    apply it to every frame in the window.  Sustainability gates the choice:
    local needs T_j^npu <= gamma, offload needs S/B <= gamma.  Frames beyond
    the sustainable rate are dropped (hurts accuracy mode, lowers rate in
    utility mode)."""
    gamma, T = stream.gamma, stream.deadline
    n = max(int(round(window_s / gamma)), 1)
    best_plan: RoundPlan | None = None
    best_score = -1e18

    def consider(plan: RoundPlan, score: float) -> None:
        nonlocal best_plan, best_score
        if score > best_score:
            best_plan, best_score = plan, score

    # Local single-model choices.
    for j, m in enumerate(models):
        if not m.runs_local or m.t_npu > T:
            continue
        a = m.accuracy(stream.r_max, where="npu")
        stride = max(int(np.ceil(m.t_npu / gamma)), 1)  # process every stride-th frame
        decisions = []
        free = max(npu_free, 0.0)
        processed = 0
        acc_sum = 0.0
        for k in range(n):
            arrival = k * gamma
            if k % stride == 0 and max(free, arrival) + m.t_npu <= arrival + T + 1e-12:
                start = max(free, arrival)
                free = start + m.t_npu
                decisions.append(Decision(k, Where.NPU, j, stream.r_max, start=start, finish=free))
                processed += 1
                acc_sum += a
            else:
                decisions.append(Decision(k, Where.SKIP))
        if alpha is None:
            score = acc_sum / n
        else:
            score = processed / (n * gamma) + (alpha * acc_sum / processed if processed else 0.0)
        consider(
            RoundPlan(
                decisions=decisions,
                horizon=n,
                expected_accuracy_sum=acc_sum,
                expected_utility=score if alpha is not None else 0.0,
                npu_busy_until=free,
            ),
            score,
        )

    # Offload single-(model, resolution) choices.
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        if t_up > gamma:
            continue
        budget = T - t_up - net.rtt
        pick = best_server_model(models, r, budget)
        if pick is None:
            continue
        j, a = pick
        decisions = []
        for k in range(n):
            arrival = k * gamma
            decisions.append(
                Decision(
                    k, Where.SERVER, j, r, start=arrival, finish=arrival + t_up + net.rtt + models[j].t_server
                )
            )
        acc_sum = a * n
        score = acc_sum / n if alpha is None else n / (n * gamma) + alpha * a
        consider(
            RoundPlan(
                decisions=decisions,
                horizon=n,
                expected_accuracy_sum=acc_sum,
                expected_utility=score if alpha is not None else 0.0,
                npu_busy_until=npu_free,
                net_busy_until=(n - 1) * gamma + t_up,
            ),
            score,
        )

    if best_plan is None:
        best_plan = RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
    return best_plan
