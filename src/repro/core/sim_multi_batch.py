"""Vectorized multi-stream fleet backend: grids of *interacting* clients as
ONE tensor program.

``simulator.simulate_multi`` is the ground truth for every multi-client
figure: N phones share one fluid uplink and one edge server, and the
``EdgeServerScheduler`` admission policy (weighted_fair / priority / fifo)
decides who may offload.  It is also a per-event Python loop — a fleet sweep
pays interpreter cost for every upload completion of every grid point.  This
module executes the same physics for a whole batch of fleet scenarios
(bandwidth × deadline × fps × n_clients × allocation grid points) as a
single jit+vmap program:

  * plan events are **tick-synchronized**: every client of a ``make_fleet``
    fleet shares one frame interval, so all round boundaries land on the
    grid ``k * gamma`` and one ``lax.scan`` over ticks replaces the event
    queue.  Within a tick, clients plan sequentially in the reference's
    ``(-priority, -weight, client_id)`` order (a ``fori_loop`` over a
    host-precomputed permutation), because each grant/lease mutates the
    scheduler state the next client sees;
  * between ticks, the shared link drains under an inner ``while_loop``
    that mirrors the reference event iteration: water-filling rates over
    the per-client **head** uploads (radios are serial), earliest-completion
    selection with the reference's ``_EPS``/``_BITS_EPS`` semantics, and a
    **fixed-point** water-filling iteration (at most N cap-resolution
    rounds) in place of ``edge_server.fluid_rates``'s Python loop;
  * the ``EdgeServerScheduler`` allocation arithmetic — effective weights,
    fair shares, capacity/backlog/priority-reservation gates, serial-radio
    link reservation — is re-rendered as pure f64 array expressions over
    per-client lease counters (see ``edge_server.effective_weight`` /
    ``fair_share`` for the scalar originals);
  * the audit is the reference's: offloads score at *actual* completion
    (fluid upload, then a FIFO worker queue over ``capacity`` slots, then
    the RTT) against ``deadline_abs + 1e-9``, exactly as
    ``simulator.simulate_multi`` does.

Equivalence contract (golden-tested in ``tests/test_sim_multi_batch.py``):
integer stats (frames processed / offloaded / missed, server jobs, grants,
denials) are **exactly equal** to the reference loop, and float stats
(accuracy sums, server busy seconds) agree within :data:`MULTI_TOL`.  The
tolerance — rather than the single-stream backend's bit-identity — exists
because the reference accumulates a few float reductions (fluid total
weights, link-reservation sums, capped-rate subtractions) in *registration*
order while this module accumulates them in client-id order; with the
default equal weights the two orders round identically and the golden grids
come out bit-equal, which the equivalence benchmark records as
``exact_match``.

Only the ``offload`` policy has a fleet planner here: its round plan is
closed-form in the granted bandwidth (no DP), so the whole decision —
per-resolution upload times, feasible-server-model argmax, accuracy vs
utility scoring — vectorizes, while its offload-every-round behaviour
exercises exactly the shared-link/server-queue physics the paper's
multi-user results are about.  The local-only ``batched=True`` policies
(``jax_accuracy`` / ``jax_utility``) never touch the link, so their fleet
grids are served by per-client replication of the single-stream
``sim_batch`` program instead (``Session.run_sweep`` handles the split; see
docs/simulation.md, "Multi-stream fleet grids").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable, Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .profiles import ModelProfile, StreamSpec
from .schedule import StreamStats
from .sim_batch import _trace_bw, segment_arrays
from .simulator import _BITS_EPS, _EPS, MultiStreamStats

__all__ = [
    "EQUIV_INT_FIELDS",
    "FleetScenario",
    "MULTI_TOL",
    "multi_batched_policies",
    "simulate_multi_batch",
]

# The equivalence contract versus the reference event loop, stated once for
# every consumer (tests/test_sim_multi_batch.py asserts it per golden grid,
# benchmarks/multistream_bench.py per ladder cell): the per-stream integer
# fields below must match EXACTLY, float stats (accuracy sums, server busy
# seconds) within the certified absolute tolerance MULTI_TOL.
MULTI_TOL = 1e-9
EQUIV_INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)

_BIG = 1e18  # "never" sentinel for event times (far above any finish time)
_BIG_I32 = np.iinfo(np.int32).max


@dataclass(frozen=True)
class FleetScenario:
    """One fleet grid point as the batched backend sees it: a homogeneous
    fleet (the ``make_fleet`` shape — one stream spec, per-client weights /
    priorities), a shared network, an allocation policy, and the inner
    policy's *resolved* parameter dict.

    The network is ``bw_segments`` — sorted piecewise-constant
    ``(t_start_s, bandwidth_bps)`` segments replayed on device (allocation
    reads bandwidth at each round's start, the fluid link at every event
    boundary, exactly like the reference's ``trace.at``) — or, when that is
    ``None``, the constant ``bandwidth_bps``."""

    stream: StreamSpec = field(default_factory=StreamSpec)
    n_frames: int = 120
    bandwidth_bps: float = 2.5e6
    rtt: float = 0.100
    n_clients: int = 2
    allocation: str = "weighted_fair"
    capacity: int = 4
    backlog_limit: float = 0.0
    weights: tuple[float, ...] | None = None
    priorities: tuple[int, ...] | None = None
    params: Mapping[str, Any] = field(default_factory=dict)
    bw_segments: tuple[tuple[float, float], ...] | None = None


_PLANNERS: dict[str, Callable[..., list[tuple[MultiStreamStats, dict]]]] = {}


def _planner(name: str):
    def deco(fn):
        _PLANNERS[name] = fn
        return fn

    return deco


def multi_batched_policies() -> tuple[str, ...]:
    """Policies with a dedicated fleet planner here (``batched_multi=True``
    minus the local-only replication cases; ``tests/test_sim_multi_batch.py``
    asserts registry and table stay in sync)."""
    return tuple(sorted(_PLANNERS))


def simulate_multi_batch(
    policy: str,
    models: Sequence[ModelProfile],
    scenarios: Sequence[FleetScenario],
    *,
    strict: bool = True,
) -> list[tuple[MultiStreamStats, dict]]:
    """Run ``policy`` fleets over every scenario in one compiled program.

    Returns one ``(MultiStreamStats, meta)`` pair per scenario, in order —
    ``meta`` carries the scheduler's grant/denial counters, mirroring what
    ``Session.run_multi`` reports.  Raises ``ValueError`` for policies
    without a fleet planner; ``Session.run_sweep`` is the front door that
    logs a fallback instead.

    ``strict`` is accepted for signature parity with the reference but has
    no observable effect for the registered fleet policies: their plans
    contain no NPU decisions, so the strict-mode plan audit has an empty
    bad set either way, and offload deadline misses are audited at actual
    completion regardless of ``strict`` — exactly as in the reference.
    """
    del strict
    fn = _PLANNERS.get(policy)
    if fn is None:
        raise ValueError(
            f"policy {policy!r} has no batched fleet backend; "
            f"available: {multi_batched_policies()}"
        )
    if not scenarios:
        return []
    return fn(list(models), list(scenarios))


# ---------------------------------------------------------------------------
# Fixed-shape fleet state.  One scenario = one lane of the vmap; every array
# below is that lane's state.  Upload queues are per-client append-only
# logs of length F (at most one offload per client per tick), so the three
# monotone cursors need no ring arithmetic:
#
#     [0 .. srv-released) .. [.. updone) .. [.. tail)
#      lease popped           at server      upload in flight
#
# A lease exists for every entry in [released, tail); its link share is
# active for entries in [updone, tail) — the serial radio transmits only
# the entry AT updone.  "released" is not a stored cursor: a lease leaves
# the server when its recorded finish time passes, so the count is derived
# from q_srvfin <= t (mirroring the reference's pending_releases queue).
# ---------------------------------------------------------------------------


class _Fleet(NamedTuple):
    now: Any  # [] f64 current simulation time
    q_bits: Any  # [N, F] f64 residual upload bits
    q_cap: Any  # [N, F] f64 scheduler-granted rate cap (inf under fifo)
    q_ddl: Any  # [N, F] f64 absolute deadline
    q_acc: Any  # [N, F] f64 server accuracy credited on an on-time finish
    q_tsrv: Any  # [N, F] f64 server-side service time
    q_bps: Any  # [N, F] f64 leased bandwidth (link reservation while active)
    q_seq: Any  # [N, F] i32 global registration order (tick * N + plan rank)
    q_srvfin: Any  # [N, F] f64 server-job finish time (BIG until assigned)
    tail: Any  # [N] i32 uploads ever registered
    updone: Any  # [N] i32 uploads fully drained off the link
    worker_free: Any  # [KW] f64 per-worker busy-until
    sbu: Any  # [] f64 scheduler backlog estimate (server_busy_until)
    grants: Any  # [] i32
    denials: Any  # [] i32
    sjobs: Any  # [] i32 jobs the server executed
    sbusy: Any  # [] f64 server busy seconds
    accs: Any  # [N] f64 per-client accuracy sums
    proc: Any  # [N] i32 per-client frames processed (== offloaded here)
    miss: Any  # [N] i32 per-client deadline misses


def _seq_sum(values):
    """Strictly sequential f64 sum in index order — the reference computes
    its weight/reservation totals with Python's left-to-right ``sum``, and
    an XLA tree reduction would round differently.  Unrolled: the client
    axis is tiny and static, and a ``fori_loop`` of one add costs more in
    loop plumbing than the adds themselves."""
    acc = jnp.float64(0.0)
    for i in range(values.shape[0]):
        acc = acc + values[i]
    return acc


@lru_cache(maxsize=None)
def _fleet_program(alloc: str, N: int, K: int, F: int, J: int, R: int, S: int):
    """Compile one (allocation policy, fleet size, capacity, frame count)
    shape group.  J/R are the model/resolution table sizes; S is the padded
    bandwidth-segment count (sentinel segments at t_start=+inf are inert —
    see ``sim_batch._trace_bw``)."""
    fifo = alloc == "fifo"
    prio_pol = alloc == "priority"
    KW = max(K, 1)  # worker count (the reference's max(int(capacity), 1))
    MAXEV = N * F + N + 4  # completion events are bounded by registrations

    def one(bw_t, bw_v, gamma, T, rtt, fps, L, alpha, is_util, w_fluid, w_eff,
            tot_w, prio, order, bits_r, acc_sv, t_srv):
        cids = jnp.arange(N, dtype=jnp.int32)

        def bw_at(t):
            # The reference's trace.at(t).bandwidth_bps: piecewise-constant
            # step lookup (constant traces are a single t=0 segment).
            return _trace_bw(bw_t, bw_v, t)

        # -- fluid link: rates over the per-client head uploads ------------
        def heads(st):
            idx = jnp.clip(st.updone, 0, F - 1)
            active = st.updone < st.tail
            hbits = jnp.where(active, st.q_bits[cids, idx], 0.0)
            hcap = jnp.where(active, st.q_cap[cids, idx], _BIG)
            hseq = jnp.where(active, st.q_seq[cids, idx], _BIG_I32)
            return active, hbits, hcap, hseq

        def waterfill(B, active, caps):
            # Fixed-point rendering of edge_server.fluid_rates: each round
            # either freezes >= 1 capped transfer or assigns final shares,
            # so N (static, tiny) rounds always suffice — unrolled.
            rates = jnp.zeros((N,), jnp.float64)
            remaining = jnp.maximum(B, 0.0)
            act = active
            done = ~jnp.any(active)
            for _ in range(N):
                total_w = _seq_sum(jnp.where(act, w_fluid, 0.0))
                total_w = jnp.where(total_w == 0.0, 1.0, total_w)
                share = remaining * w_fluid / total_w
                live = act & (remaining > _EPS) & ~done
                capped = live & (caps <= share + _EPS)
                none_capped = ~jnp.any(capped)
                # No cap binds: everyone still active takes its share, done.
                rates = jnp.where(live & none_capped, share, rates)
                # Caps bind: freeze them, return leftovers to the pool in
                # client-id order (the reference subtracts sequentially).
                rates = jnp.where(capped, caps, rates)
                sub = remaining
                for i in range(N):
                    sub = sub - jnp.where(capped[i], caps[i], 0.0)
                remaining = jnp.where(jnp.any(capped), jnp.maximum(sub, 0.0), remaining)
                act = act & ~capped & ~none_capped
                done = done | jnp.any(live & none_capped) | ~jnp.any(live)
            return rates

        def link_state(st):
            active, hbits, hcap, hseq = heads(st)
            # Rates re-evaluate at every event boundary against the trace's
            # bandwidth at the CURRENT time — the reference's
            # _fluid_rates(trace.at(now).bandwidth_bps, active).
            rates = waterfill(bw_at(st.now), active, hcap)
            finish = jnp.where(
                active & (rates > _EPS), st.now + hbits / rates, _BIG
            )
            return active, hbits, hseq, rates, finish

        # -- a batch of upload completions: worker queue + deadline audit --
        # At most one upload per client (its head) can be due at once, so
        # the per-client stat updates batch into one scatter per field;
        # only the worker assignment walks the due set sequentially — the
        # reference pops jobs in registration order against a mutating
        # worker pool, and the server-busy accumulator must also grow one
        # job at a time to reproduce the loop's f64 rounding.
        def complete_batch(st, due):
            idx = jnp.clip(st.updone, 0, F - 1)
            tsv = jnp.where(due, st.q_tsrv[cids, idx], 0.0)
            ddl = st.q_ddl[cids, idx]
            acc = st.q_acc[cids, idx]
            _, _, _, hseq = heads(st)
            seqs = jnp.where(due, hseq, _BIG_I32)

            def assign(i, carry):
                wf, jfin, sbusy, left = carry
                c = jnp.argmin(jnp.where(left, seqs, _BIG_I32)).astype(jnp.int32)
                go = left[c]
                wi = jnp.argmin(wf).astype(jnp.int32)
                fin = jnp.maximum(st.now, wf[wi]) + tsv[c]
                wf = wf.at[wi].set(jnp.where(go, fin, wf[wi]))
                jfin = jfin.at[c].set(jnp.where(go, fin, jfin[c]))
                sbusy = sbusy + jnp.where(go, tsv[c], 0.0)
                return wf, jfin, sbusy, left.at[c].set(False)

            wf, jfin, sbusy, _ = jax.lax.fori_loop(
                0, N, assign,
                (st.worker_free, jnp.full((N,), _BIG, jnp.float64), st.sbusy, due),
            )
            ontime = due & (jfin + rtt <= ddl + _EPS)
            return st._replace(
                worker_free=wf,
                q_srvfin=st.q_srvfin.at[cids, idx].set(
                    jnp.where(due, jfin, st.q_srvfin[cids, idx])
                ),
                updone=st.updone + due.astype(jnp.int32),
                sjobs=st.sjobs + jnp.sum(due.astype(jnp.int32), dtype=jnp.int32),
                sbusy=sbusy,
                accs=st.accs + jnp.where(ontime, acc, 0.0),
                proc=st.proc + ontime.astype(jnp.int32),
                miss=st.miss + (due & ~ontime).astype(jnp.int32),
            )

        def mop_up(st):
            # Residual-bits mop-up at a boundary advance: the reference's
            # drain pass completes any head below _BITS_EPS regardless of
            # its rate ("transfers that cross zero during an advance").
            active, hbits, _, _ = heads(st)
            return complete_batch(st, active & (hbits <= _BITS_EPS))

        # -- drain the link toward a target time ---------------------------
        # The water-filling state is carried across the while boundary so
        # each event iteration evaluates it exactly once (the cond reuses
        # the body's rates — identical values, half the arithmetic).
        def drain(st, t_target, *, advance_to_target: bool):
            ls0 = link_state(st)

            def cond(carry):
                _, budget, ls = carry
                t_done = jnp.min(ls[4])
                # t_done == _BIG means "no completion will ever happen";
                # without the guard a drain-to-_BIG would spin on it.  Heads
                # at/below _BITS_EPS never enter a drain: the boundary
                # mop-up below (and the reference's own drain pass) clears
                # them before the next event is selected.
                due_soon = (t_done <= t_target + _EPS) & (t_done < _BIG * 0.5)
                return due_soon & (budget > 0)

            def body(carry):
                st, budget, ls = carry
                active, hbits, _, rates, finish = ls
                t_done = jnp.min(finish)
                t_next = jnp.minimum(jnp.minimum(t_done, t_target), _BIG)
                dt = jnp.maximum(t_next - st.now, 0.0)
                idx = jnp.clip(st.updone, 0, F - 1)
                newbits = jnp.maximum(0.0, hbits - rates * dt)
                due = active & (
                    ((finish <= t_done + _EPS) & (t_done <= t_next + _EPS))
                    | (newbits <= _BITS_EPS)
                )
                st = st._replace(
                    now=jnp.maximum(st.now, t_next),
                    q_bits=st.q_bits.at[cids, idx].set(
                        jnp.where(active, jnp.where(due, 0.0, newbits), st.q_bits[cids, idx])
                    ),
                )
                st = complete_batch(st, due)
                return st, budget - 1, link_state(st)

            st, _, ls = jax.lax.while_loop(cond, body, (st, jnp.int32(MAXEV), ls0))
            if advance_to_target:
                # Partial advance to the tick boundary (rates re-evaluated,
                # exactly the reference's piecewise-constant approximation).
                active, hbits, _, rates, _ = ls
                dt = jnp.maximum(t_target - st.now, 0.0)
                idx = jnp.clip(st.updone, 0, F - 1)
                newbits = jnp.maximum(0.0, hbits - rates * dt)
                st = st._replace(
                    now=jnp.maximum(st.now, t_target),
                    q_bits=st.q_bits.at[cids, idx].set(
                        jnp.where(active, newbits, st.q_bits[cids, idx])
                    ),
                )
                st = mop_up(st)
            return st

        # Serial radios: a client's many leases reserve max(bps) over its
        # link-active entries [updone, tail).  Recomputed from the queues
        # once per tick; plan events then maintain it incrementally (a new
        # lease can only raise its own client's max).
        def active_link_bps(st):
            pos = jnp.arange(F, dtype=jnp.int32)
            act_mask = (pos[None, :] >= st.updone[:, None]) & (
                pos[None, :] < jnp.clip(st.tail, 0, F)[:, None]
            )
            return jnp.max(jnp.where(act_mask, st.q_bps, 0.0), axis=1)  # [N]

        # -- one client's plan event: allocate -> plan -> register ---------
        def plan_one(rank, carry):
            st, k, t0, released, act_bps = carry
            c = order[rank]
            lease_len = st.tail - released  # [N]
            total = jnp.sum(lease_len)
            B0 = bw_at(t0)  # the reference plans against trace.at(t0)

            if fifo:
                grant = B0
                denied = jnp.bool_(False)
            else:
                own = lease_len[c]
                effective = total - jnp.minimum(own, 1)
                backlogged = st.sbu - t0 > L
                if prio_pol:
                    free = K - total
                    higher_waiting = jnp.sum(
                        ((prio > prio[c]) & (lease_len == 0)).astype(jnp.int32)
                    )
                    reserved = free <= higher_waiting
                else:
                    reserved = jnp.bool_(False)
                gated = (effective >= K) | backlogged | reserved
                used = _seq_sum(jnp.where(cids != c, act_bps, 0.0))
                available = jnp.maximum(B0 - used, 0.0)
                share = B0 * w_eff[c] / tot_w
                grant = jnp.minimum(share, available)
                denied = gated | (grant <= 0.0)
                grant = jnp.where(denied, 0.0, grant)

            st = st._replace(
                grants=st.grants + jnp.where(denied, 0, 1),
                denials=st.denials + jnp.where(denied, 1, 0),
            )

            # Closed-form offload round against the granted bandwidth: the
            # reference's per-resolution loop as one [R] expression.
            t_up = bits_r / grant  # inf when grant == 0, like upload_time
            budget = T - t_up - rtt  # [R]
            fits = t_srv[:, None] <= budget[None, :]  # [J, R]
            a_mask = jnp.where(fits, acc_sv, -jnp.inf)
            j_best = jnp.argmax(a_mask, axis=0).astype(jnp.int32)  # first max
            a_best = jnp.max(a_mask, axis=0)
            feasible = (t_up <= gamma) & jnp.any(fits, axis=0)
            util_score = (
                jnp.minimum(1.0 / jnp.maximum(t_up, 1e-9), fps) + alpha * a_best
            )
            score = jnp.where(is_util, util_score, a_best)
            score = jnp.where(feasible, score, -jnp.inf)
            offload = jnp.any(feasible)
            r_pick = jnp.argmax(score).astype(jnp.int32)  # first max wins ties
            j_pick = j_best[r_pick]

            e = jnp.clip(st.tail[c], 0, F - 1)
            tsv = t_srv[j_pick]
            cap = jnp.float64(np.inf) if fifo else grant

            def put(q, val):
                return q.at[c, e].set(jnp.where(offload, val, q[c, e]))

            sbu = st.sbu
            if not fifo:
                # The reference divides by max(capacity, 1), even at K == 0.
                sbu = jnp.where(
                    offload, jnp.maximum(st.sbu, t0) + tsv / KW, st.sbu
                )
            st = st._replace(
                q_bits=put(st.q_bits, bits_r[r_pick]),
                q_cap=put(st.q_cap, cap),
                q_ddl=put(st.q_ddl, t0 + T),
                q_acc=put(st.q_acc, acc_sv[j_pick, r_pick]),
                q_tsrv=put(st.q_tsrv, tsv),
                q_bps=put(st.q_bps, grant),
                q_seq=put(st.q_seq, k * N + rank),
                tail=st.tail.at[c].add(jnp.where(offload, 1, 0)),
                sbu=sbu,
            )
            act_bps = act_bps.at[c].set(
                jnp.where(offload, jnp.maximum(act_bps[c], grant), act_bps[c])
            )
            return st, k, t0, released, act_bps

        # -- the tick scan --------------------------------------------------
        def tick(st, k):
            t0 = k.astype(jnp.float64) * gamma
            st = drain(st, t0, advance_to_target=True)
            # Server slots whose jobs have finished by t0 free their leases.
            released = jnp.sum(
                (st.q_srvfin <= t0 + _EPS).astype(jnp.int32), axis=1
            )
            st, _, _, _, _ = jax.lax.fori_loop(
                0, N, plan_one,
                (st, k.astype(jnp.int32), t0, released, active_link_bps(st)),
            )
            return st, None

        st0 = _Fleet(
            now=jnp.float64(0.0),
            q_bits=jnp.zeros((N, F), jnp.float64),
            q_cap=jnp.full((N, F), _BIG, jnp.float64),
            q_ddl=jnp.zeros((N, F), jnp.float64),
            q_acc=jnp.zeros((N, F), jnp.float64),
            q_tsrv=jnp.zeros((N, F), jnp.float64),
            q_bps=jnp.zeros((N, F), jnp.float64),
            q_seq=jnp.full((N, F), _BIG_I32, jnp.int32),
            q_srvfin=jnp.full((N, F), _BIG, jnp.float64),
            tail=jnp.zeros((N,), jnp.int32),
            updone=jnp.zeros((N,), jnp.int32),
            worker_free=jnp.zeros((KW,), jnp.float64),
            sbu=jnp.float64(0.0),
            grants=jnp.int32(0),
            denials=jnp.int32(0),
            sjobs=jnp.int32(0),
            sbusy=jnp.float64(0.0),
            accs=jnp.zeros((N,), jnp.float64),
            proc=jnp.zeros((N,), jnp.int32),
            miss=jnp.zeros((N,), jnp.int32),
        )
        st, _ = jax.lax.scan(tick, st0, jnp.arange(F, dtype=jnp.int32))
        # Post-stream drain: in-flight uploads finish (and audit) after the
        # last round boundary, exactly as the reference keeps its event loop
        # alive until the link empties.
        st = drain(st, jnp.float64(_BIG), advance_to_target=False)
        # Anything still queued could not drain (the event budget tripped,
        # or a dead link): every stranded upload is a deadline miss.
        st = st._replace(miss=st.miss + (st.tail - st.updone))
        return st.accs, st.proc, st.miss, st.grants, st.denials, st.sjobs, st.sbusy

    return jax.jit(
        jax.vmap(one, in_axes=(0,) * 14 + (None,) * 3)
    )


# ---------------------------------------------------------------------------
# The offload-policy fleet planner: host-side f64 precomputation mirrors the
# reference expression by expression (frame bits, accuracy tables, effective
# weights, plan-event ordering), then one compiled program per shape group.
# ---------------------------------------------------------------------------


def _stitch(scenarios, key_fn, run_group) -> list[tuple[MultiStreamStats, dict]]:
    groups: dict[Any, list[int]] = {}
    for i, s in enumerate(scenarios):
        groups.setdefault(key_fn(s), []).append(i)
    out: list[tuple[MultiStreamStats, dict] | None] = [None] * len(scenarios)
    for key in sorted(groups, key=repr):
        idx = groups[key]
        for i, st in zip(idx, run_group(key, [scenarios[i] for i in idx])):
            out[i] = st
    return out  # type: ignore[return-value]


@_planner("offload")
def _run_offload(models, scenarios):
    t_srv = np.array([m.t_server for m in models], np.float64)

    def run_group(key, group):
        alloc, N, K, F, resolutions, png_ratio = key
        B_ = len(group)
        R = len(resolutions)
        # Frame payloads: frame_bytes(r) * 8.0, the value the reference
        # feeds both upload_time and _Upload.bits_left.
        bits_r = np.array(
            [group[0].stream.frame_bytes(r) * 8.0 for r in resolutions], np.float64
        )
        acc_sv = np.array(
            [[m.accuracy(r, where="server") for r in resolutions] for m in models],
            np.float64,
        )
        # Bandwidth trace segments in the shared on-device layout (sorting,
        # power-of-two padding, inert t_start=+inf sentinels — one
        # definition in sim_batch, read back by _trace_bw).
        bw_t, bw_v, S = segment_arrays(
            [s.bw_segments or ((0.0, s.bandwidth_bps),) for s in group]
        )
        gamma = np.array([s.stream.gamma for s in group], np.float64)
        T = np.array([s.stream.deadline for s in group], np.float64)
        rtt = np.array([s.rtt for s in group], np.float64)
        fps = np.array([s.stream.fps for s in group], np.float64)
        L = np.array([s.backlog_limit for s in group], np.float64)
        alpha_raw = [s.params.get("alpha") for s in group]
        alpha = np.array([a if a is not None else 0.0 for a in alpha_raw], np.float64)
        is_util = np.array([a is not None for a in alpha_raw], bool)
        w = np.array(
            [s.weights if s.weights is not None else (1.0,) * N for s in group],
            np.float64,
        )
        prio = np.array(
            [s.priorities if s.priorities is not None else (0,) * N for s in group],
            np.int32,
        )
        # Fluid weights floor at _EPS (the reference's max(weight, _EPS));
        # effective weights and their total use the scheduler's own scalar
        # arithmetic so shares match the reference to the bit.
        w_fluid = np.maximum(w, _EPS)
        if alloc == "priority":
            w_eff = np.array(
                [[wi * (2.0 ** int(pi)) for wi, pi in zip(wr, pr)]
                 for wr, pr in zip(w, prio)],
                np.float64,
            )
        else:
            w_eff = w.copy()
        tot_w = np.array([sum(row) or 1.0 for row in w_eff], np.float64)
        # Plan-event order inside a tick: the reference's event key is
        # (t, -priority, -weight, client_id).
        order = np.stack(
            [np.lexsort((np.arange(N), -wr, -pr)) for wr, pr in zip(w, prio)]
        ).astype(np.int32)

        program = _fleet_program(alloc, N, K, F, len(models), R, S)
        t0 = time.perf_counter()
        with enable_x64():
            out = program(
                bw_t, bw_v, gamma, T, rtt, fps, L, alpha, is_util, w_fluid,
                w_eff, tot_w, prio, order, bits_r, acc_sv, t_srv,
            )
            accs, proc, miss, grants, denials, sjobs, sbusy = (
                np.asarray(a) for a in out
            )
        wall = time.perf_counter() - t0

        results = []
        for b, s in enumerate(group):
            elapsed = s.n_frames * s.stream.gamma
            per_client = [
                StreamStats(
                    frames_total=s.n_frames,
                    frames_processed=int(proc[b, c]),
                    frames_missed_deadline=int(miss[b, c]),
                    frames_offloaded=int(proc[b, c]),  # offload-only plans
                    accuracy_sum=float(accs[b, c]),
                    elapsed=elapsed,
                    schedule_calls=F,
                    # One device program schedules the whole group; report
                    # the amortized per-round cost (as sim_batch does).
                    schedule_time=wall * F / max(B_ * N * F, 1),
                    npu_busy_s=0.0,
                )
                for c in range(N)
            ]
            ms = MultiStreamStats(
                per_client=per_client,
                server_jobs=int(sjobs[b]),
                server_busy_s=float(sbusy[b]),
                elapsed=elapsed,
            )
            results.append(
                (ms, {"grants": int(grants[b]), "denials": int(denials[b])})
            )
        return results

    def key_fn(s: FleetScenario) -> tuple:
        return (
            s.allocation,
            int(s.n_clients),
            int(s.capacity),
            int(s.n_frames),
            tuple(s.stream.resolutions),
            float(s.stream.png_ratio),
        )

    return _stitch(scenarios, key_fn, run_group)
