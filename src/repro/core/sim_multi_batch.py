"""Vectorized multi-stream fleet backend: grids of *interacting* clients as
ONE tensor program.

``simulator.simulate_multi`` is the ground truth for every multi-client
figure: N phones share one fluid uplink and one edge server, and the
``EdgeServerScheduler`` admission policy (weighted_fair / priority / fifo)
decides who may offload.  It is also a per-event Python loop — a fleet sweep
pays interpreter cost for every upload completion of every grid point.  This
module executes the same physics for a whole batch of fleet scenarios
(bandwidth × deadline × fps × n_clients × allocation grid points) as a
single jit+vmap program:

  * plan events are **tick-synchronized**: every client of a ``make_fleet``
    fleet shares one frame interval, so all round boundaries land on the
    grid ``k * gamma`` and one ``lax.scan`` over ticks replaces the event
    queue.  Within a tick, clients plan sequentially in the reference's
    ``(-priority, -weight, client_id)`` order (a ``fori_loop`` over a
    host-precomputed permutation), because each grant/lease mutates the
    scheduler state the next client sees;
  * between ticks, the shared link drains under an inner ``while_loop``
    that mirrors the reference event iteration: water-filling rates over
    the per-client **head** uploads (radios are serial), earliest-completion
    selection with the reference's ``_EPS``/``_BITS_EPS`` semantics, and a
    **fixed-point** water-filling iteration (at most N cap-resolution
    rounds) in place of ``edge_server.fluid_rates``'s Python loop;
  * the ``EdgeServerScheduler`` allocation arithmetic — effective weights,
    fair shares, capacity/backlog/priority-reservation gates, serial-radio
    link reservation — is re-rendered as pure f64 array expressions over
    per-client lease counters (see ``edge_server.effective_weight`` /
    ``fair_share`` for the scalar originals);
  * the audit is the reference's: offloads score at *actual* completion
    (fluid upload, then a FIFO worker queue over ``capacity`` slots, then
    the RTT) against ``deadline_abs + 1e-9``, exactly as
    ``simulator.simulate_multi`` does.

Equivalence contract (golden-tested in ``tests/test_sim_multi_batch.py``,
property-tested in ``tests/test_sim_multi_batch_properties.py``): integer
stats (frames processed / offloaded / missed, server jobs, grants, denials)
are **exactly equal** to the reference loop, and float stats (accuracy
sums, server busy seconds) agree within :data:`MULTI_TOL`.  The tolerance —
rather than the single-stream backend's bit-identity — exists because the
reference accumulates a few float reductions (fluid total weights,
link-reservation sums, capped-rate subtractions) in *registration* order
while this module accumulates them in client-id order; with the default
equal weights the two orders round identically and the golden grids come
out bit-equal, which the equivalence benchmark records as ``exact_match``.

Five policies have fleet planners here, sharing one set of link/scheduler
closures (:func:`_fleet_physics`):

  * ``offload`` — its round plan is closed-form in the granted bandwidth
    (no DP), so the whole decision vectorizes, while its
    offload-every-round behaviour exercises exactly the shared-link /
    server-queue physics the paper's multi-user results are about;
  * ``max_accuracy`` / ``max_utility`` — the paper's own planners: each
    client's round is the ``sim_batch`` rendering of the reference
    ``plan_round`` (per-resolution upload times against the *granted*
    bandwidth, feasible-server-model argmax, the f64 DP twins of
    :mod:`repro.core.jax_sched` with the ``_no_fma`` tie-break guard,
    normalized-score candidate selection), except the head-frame offload is
    not audited at plan time: it registers an upload on the shared link and
    scores at actual completion, exactly like the reference's
    ``on_offload`` callback.  Clients plan only at their own round
    boundaries (``head[c] == k``), and ``max_utility`` keeps the
    width-64 fast pass + width-256 overflow-rerun protocol;
  * ``jax_accuracy`` / ``jax_utility`` — local-only plans that never
    consult the grant, so every client of a homogeneous fleet follows the
    *identical* trajectory: one lane per scenario runs the single-stream
    program body (bit-identical stats, replicated per client) extended
    with the scheduler's grant/denial counters (every plan event still
    calls ``allocate`` once per client in the reference; the gate outcome
    for a leaseless fleet is a static per-client predicate plus the
    trace's bandwidth sign and the backlog clock).

``Session.run_sweep`` routes fleet grids of all five policies here; see
docs/simulation.md ("Fleet planners") for the capability matrix and the
remaining fallback combinations.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from functools import lru_cache
from types import SimpleNamespace
from typing import Any, Callable, Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .jax_sched import (
    NEG,
    _accuracy_dp,
    _accuracy_dp64,
    _no_fma,
    _utility_dp,
    _utility_dp64,
)
from .bucketing import quant_bins as _quant_bins
from .bucketing import quant_w as _quant_w
from .profiles import ModelProfile, StreamSpec
from .registry import get_policy
from .schedule import StreamStats
from .sim_batch import (
    _UTIL_CAP,
    _UTIL_FAST_WIDTH,
    BatchScenario,
    _audit_scan,
    _collect,
    _common,
    _trace_bw,
    _window_frames,
    segment_arrays,
)
from .sweep_shard import LaneProgram
from .simulator import _BITS_EPS, _EPS, MultiStreamStats
from .tracking import WorkloadSpec, interval_means, retention, retention_powers

__all__ = [
    "EQUIV_INT_FIELDS",
    "FleetScenario",
    "MULTI_TOL",
    "multi_batched_policies",
    "simulate_multi_batch",
]

# The equivalence contract versus the reference event loop, stated once for
# every consumer (tests/test_sim_multi_batch.py asserts it per golden grid,
# benchmarks/multistream_bench.py per ladder cell): the per-stream integer
# fields below must match EXACTLY, float stats (accuracy sums, server busy
# seconds) within the certified absolute tolerance MULTI_TOL.
MULTI_TOL = 1e-9
EQUIV_INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)

_BIG = 1e18  # "never" sentinel for event times (far above any finish time)
_BIG_I32 = np.iinfo(np.int32).max


@dataclass(frozen=True)
class FleetScenario:
    """One fleet grid point as the batched backend sees it: a homogeneous
    fleet (the ``make_fleet`` shape — one stream spec, per-client weights /
    priorities), a shared network, an allocation policy, and the inner
    policy's *resolved* parameter dict.

    The network is ``bw_segments`` — sorted piecewise-constant
    ``(t_start_s, bandwidth_bps)`` segments replayed on device (allocation
    reads bandwidth at each round's start, the fluid link at every event
    boundary, exactly like the reference's ``trace.at``) — or, when that is
    ``None``, the constant ``bandwidth_bps``.

    ``workload`` is the fleet's world truth (``tracking.WorkloadSpec``):
    the ``track_*`` planners require ``kind="track"`` (detections contend
    on the shared uplink, tracker-carried frames do not), the classification
    planners the default ``kind="classify"``."""

    stream: StreamSpec = field(default_factory=StreamSpec)
    n_frames: int = 120
    bandwidth_bps: float = 2.5e6
    rtt: float = 0.100
    n_clients: int = 2
    allocation: str = "weighted_fair"
    capacity: int = 4
    backlog_limit: float = 0.0
    weights: tuple[float, ...] | None = None
    priorities: tuple[int, ...] | None = None
    params: Mapping[str, Any] = field(default_factory=dict)
    bw_segments: tuple[tuple[float, float], ...] | None = None
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)


_PLANNERS: dict[str, Callable[..., list[tuple[MultiStreamStats, dict]]]] = {}


def _planner(name: str):
    def deco(fn):
        _PLANNERS[name] = fn
        return fn

    return deco


def multi_batched_policies() -> tuple[str, ...]:
    """Policies with a dedicated fleet planner here (exactly the registry's
    ``batched_multi=True`` set; ``tests/test_sim_multi_batch.py`` asserts
    registry and table stay in sync)."""
    return tuple(sorted(_PLANNERS))


def simulate_multi_batch(
    policy: str,
    models: Sequence[ModelProfile],
    scenarios: Sequence[FleetScenario],
    *,
    strict: bool = True,
) -> list[tuple[MultiStreamStats, dict]]:
    """Run ``policy`` fleets over every scenario in one compiled program.

    Returns one ``(MultiStreamStats, meta)`` pair per scenario, in order —
    ``meta`` carries the scheduler's grant/denial counters, mirroring what
    ``Session.run_multi`` reports.  Raises ``ValueError`` for policies
    without a fleet planner; ``Session.run_sweep`` is the front door that
    logs a fallback instead.

    ``strict`` follows the reference exactly: it gates the plan-time audit
    of NPU decisions (``audit_round(..., npu_only=True)`` in
    ``simulate_multi``), while offload deadline misses are always audited
    at actual completion regardless of ``strict``.  The ``offload``
    planner's plans contain no NPU decisions, so it ignores the flag.
    """
    fn = _PLANNERS.get(policy)
    if fn is None:
        raise ValueError(
            f"policy {policy!r} has no batched fleet backend; "
            f"available: {multi_batched_policies()}"
        )
    entry = get_policy(policy)
    for s in scenarios:
        if s.workload.kind not in entry.workloads:
            raise ValueError(
                f"policy {policy!r} plans {'/'.join(entry.workloads)} workloads, "
                f"not {s.workload.kind!r}"
            )
    if not scenarios:
        return []
    return fn(list(models), list(scenarios), bool(strict))


# ---------------------------------------------------------------------------
# Fixed-shape fleet state.  One scenario = one lane of the vmap; every array
# below is that lane's state.  Upload queues are per-client append-only
# logs of length F (at most one offload per client per tick), so the three
# monotone cursors need no ring arithmetic:
#
#     [0 .. srv-released) .. [.. updone) .. [.. tail)
#      lease popped           at server      upload in flight
#
# A lease exists for every entry in [released, tail); its link share is
# active for entries in [updone, tail) — the serial radio transmits only
# the entry AT updone.  "released" is not a stored cursor: a lease leaves
# the server when its recorded finish time passes, so the count is derived
# from q_srvfin <= t (mirroring the reference's pending_releases queue).
# ---------------------------------------------------------------------------


class _Fleet(NamedTuple):
    now: Any  # [] f64 current simulation time
    q_bits: Any  # [N, F] f64 residual upload bits
    q_cap: Any  # [N, F] f64 scheduler-granted rate cap (inf under fifo)
    q_ddl: Any  # [N, F] f64 absolute deadline
    q_acc: Any  # [N, F] f64 server accuracy credited on an on-time finish
    q_tsrv: Any  # [N, F] f64 server-side service time
    q_bps: Any  # [N, F] f64 leased bandwidth (link reservation while active)
    q_seq: Any  # [N, F] i32 global registration order (tick * N + plan rank)
    q_srvfin: Any  # [N, F] f64 server-job finish time (BIG until assigned)
    tail: Any  # [N] i32 uploads ever registered
    updone: Any  # [N] i32 uploads fully drained off the link
    worker_free: Any  # [KW] f64 per-worker busy-until
    sbu: Any  # [] f64 scheduler backlog estimate (server_busy_until)
    grants: Any  # [] i32
    denials: Any  # [] i32
    sjobs: Any  # [] i32 jobs the server executed
    sbusy: Any  # [] f64 server busy seconds
    accs: Any  # [N] f64 per-client accuracy sums
    proc: Any  # [N] i32 per-client frames processed
    miss: Any  # [N] i32 per-client deadline misses
    offl: Any  # [N] i32 per-client on-time server completions
    head: Any  # [N] i32 next frame each client plans (round boundary)
    busy: Any  # [N] f64 per-client absolute NPU busy-until
    rounds: Any  # [N] i32 per-client plan rounds executed
    npus: Any  # [N] f64 per-client NPU busy seconds (planned occupancy)


def _seq_sum(values):
    """Strictly sequential f64 sum in index order — the reference computes
    its weight/reservation totals with Python's left-to-right ``sum``, and
    an XLA tree reduction would round differently.  Unrolled: the client
    axis is tiny and static, and a ``fori_loop`` of one add costs more in
    loop plumbing than the adds themselves."""
    acc = jnp.float64(0.0)
    for i in range(values.shape[0]):
        acc = acc + values[i]
    return acc


def _fleet_physics(alloc: str, N: int, K: int, F: int, *, bw_t, bw_v, rtt, L,
                   w_fluid, w_eff, tot_w, prio):
    """The shared fleet physics, bound to one lane's arrays: the fluid
    uplink (water-filling rates, event-by-event drain), the completion /
    audit machinery, and the ``EdgeServerScheduler`` allocation + lease
    arithmetic.  Every fleet planner composes these closures with its own
    round rendering, so the link a DP planner contends on is *the same
    code* the golden-tested ``offload`` planner runs."""
    fifo = alloc == "fifo"
    prio_pol = alloc == "priority"
    KW = max(K, 1)  # worker count (the reference's max(int(capacity), 1))
    MAXEV = N * F + N + 4  # completion events are bounded by registrations
    cids = jnp.arange(N, dtype=jnp.int32)

    def bw_at(t):
        # The reference's trace.at(t).bandwidth_bps: piecewise-constant
        # step lookup (constant traces are a single t=0 segment).
        return _trace_bw(bw_t, bw_v, t)

    # -- fluid link: rates over the per-client head uploads ----------------
    def heads(st):
        idx = jnp.clip(st.updone, 0, F - 1)
        active = st.updone < st.tail
        hbits = jnp.where(active, st.q_bits[cids, idx], 0.0)
        hcap = jnp.where(active, st.q_cap[cids, idx], _BIG)
        hseq = jnp.where(active, st.q_seq[cids, idx], _BIG_I32)
        return active, hbits, hcap, hseq

    def waterfill(B, active, caps):
        # Fixed-point rendering of edge_server.fluid_rates: each round
        # either freezes >= 1 capped transfer or assigns final shares,
        # so N (static, tiny) rounds always suffice — unrolled.
        rates = jnp.zeros((N,), jnp.float64)
        remaining = jnp.maximum(B, 0.0)
        act = active
        done = ~jnp.any(active)
        for _ in range(N):
            total_w = _seq_sum(jnp.where(act, w_fluid, 0.0))
            total_w = jnp.where(total_w == 0.0, 1.0, total_w)
            share = remaining * w_fluid / total_w
            live = act & (remaining > _EPS) & ~done
            capped = live & (caps <= share + _EPS)
            none_capped = ~jnp.any(capped)
            # No cap binds: everyone still active takes its share, done.
            rates = jnp.where(live & none_capped, share, rates)
            # Caps bind: freeze them, return leftovers to the pool in
            # client-id order (the reference subtracts sequentially).
            rates = jnp.where(capped, caps, rates)
            sub = remaining
            for i in range(N):
                sub = sub - jnp.where(capped[i], caps[i], 0.0)
            remaining = jnp.where(jnp.any(capped), jnp.maximum(sub, 0.0), remaining)
            act = act & ~capped & ~none_capped
            done = done | jnp.any(live & none_capped) | ~jnp.any(live)
        return rates

    def link_state(st):
        active, hbits, hcap, hseq = heads(st)
        # Rates re-evaluate at every event boundary against the trace's
        # bandwidth at the CURRENT time — the reference's
        # _fluid_rates(trace.at(now).bandwidth_bps, active).
        rates = waterfill(bw_at(st.now), active, hcap)
        finish = jnp.where(
            active & (rates > _EPS), st.now + hbits / rates, _BIG
        )
        return active, hbits, hseq, rates, finish

    # -- a batch of upload completions: worker queue + deadline audit ------
    # At most one upload per client (its head) can be due at once, so
    # the per-client stat updates batch into one scatter per field;
    # only the worker assignment walks the due set sequentially — the
    # reference pops jobs in registration order against a mutating
    # worker pool, and the server-busy accumulator must also grow one
    # job at a time to reproduce the loop's f64 rounding.
    def complete_batch(st, due):
        idx = jnp.clip(st.updone, 0, F - 1)
        tsv = jnp.where(due, st.q_tsrv[cids, idx], 0.0)
        ddl = st.q_ddl[cids, idx]
        acc = st.q_acc[cids, idx]
        _, _, _, hseq = heads(st)
        seqs = jnp.where(due, hseq, _BIG_I32)

        def assign(i, carry):
            wf, jfin, sbusy, left = carry
            c = jnp.argmin(jnp.where(left, seqs, _BIG_I32)).astype(jnp.int32)
            go = left[c]
            wi = jnp.argmin(wf).astype(jnp.int32)
            fin = jnp.maximum(st.now, wf[wi]) + tsv[c]
            wf = wf.at[wi].set(jnp.where(go, fin, wf[wi]))
            jfin = jfin.at[c].set(jnp.where(go, fin, jfin[c]))
            sbusy = sbusy + jnp.where(go, tsv[c], 0.0)
            return wf, jfin, sbusy, left.at[c].set(False)

        wf, jfin, sbusy, _ = jax.lax.fori_loop(
            0, N, assign,
            (st.worker_free, jnp.full((N,), _BIG, jnp.float64), st.sbusy, due),
        )
        ontime = due & (jfin + rtt <= ddl + _EPS)
        return st._replace(
            worker_free=wf,
            q_srvfin=st.q_srvfin.at[cids, idx].set(
                jnp.where(due, jfin, st.q_srvfin[cids, idx])
            ),
            updone=st.updone + due.astype(jnp.int32),
            sjobs=st.sjobs + jnp.sum(due.astype(jnp.int32), dtype=jnp.int32),
            sbusy=sbusy,
            accs=st.accs + jnp.where(ontime, acc, 0.0),
            proc=st.proc + ontime.astype(jnp.int32),
            miss=st.miss + (due & ~ontime).astype(jnp.int32),
            offl=st.offl + ontime.astype(jnp.int32),
        )

    def mop_up(st):
        # Residual-bits mop-up at a boundary advance: the reference's
        # drain pass completes any head below _BITS_EPS regardless of
        # its rate ("transfers that cross zero during an advance").
        active, hbits, _, _ = heads(st)
        return complete_batch(st, active & (hbits <= _BITS_EPS))

    # -- drain the link toward a target time -------------------------------
    # The water-filling state is carried across the while boundary so
    # each event iteration evaluates it exactly once (the cond reuses
    # the body's rates — identical values, half the arithmetic).
    def drain(st, t_target, *, advance_to_target: bool):
        ls0 = link_state(st)

        def cond(carry):
            _, budget, ls = carry
            t_done = jnp.min(ls[4])
            # t_done == _BIG means "no completion will ever happen";
            # without the guard a drain-to-_BIG would spin on it.  Heads
            # at/below _BITS_EPS never enter a drain: the boundary
            # mop-up below (and the reference's own drain pass) clears
            # them before the next event is selected.
            due_soon = (t_done <= t_target + _EPS) & (t_done < _BIG * 0.5)
            return due_soon & (budget > 0)

        def body(carry):
            st, budget, ls = carry
            active, hbits, _, rates, finish = ls
            t_done = jnp.min(finish)
            t_next = jnp.minimum(jnp.minimum(t_done, t_target), _BIG)
            dt = jnp.maximum(t_next - st.now, 0.0)
            idx = jnp.clip(st.updone, 0, F - 1)
            newbits = jnp.maximum(0.0, hbits - rates * dt)
            due = active & (
                ((finish <= t_done + _EPS) & (t_done <= t_next + _EPS))
                | (newbits <= _BITS_EPS)
            )
            st = st._replace(
                now=jnp.maximum(st.now, t_next),
                q_bits=st.q_bits.at[cids, idx].set(
                    jnp.where(active, jnp.where(due, 0.0, newbits), st.q_bits[cids, idx])
                ),
            )
            st = complete_batch(st, due)
            return st, budget - 1, link_state(st)

        st, _, ls = jax.lax.while_loop(cond, body, (st, jnp.int32(MAXEV), ls0))
        if advance_to_target:
            # Partial advance to the tick boundary (rates re-evaluated,
            # exactly the reference's piecewise-constant approximation).
            active, hbits, _, rates, _ = ls
            dt = jnp.maximum(t_target - st.now, 0.0)
            idx = jnp.clip(st.updone, 0, F - 1)
            newbits = jnp.maximum(0.0, hbits - rates * dt)
            st = st._replace(
                now=jnp.maximum(st.now, t_target),
                q_bits=st.q_bits.at[cids, idx].set(
                    jnp.where(active, newbits, st.q_bits[cids, idx])
                ),
            )
            st = mop_up(st)
        return st

    # Serial radios: a client's many leases reserve max(bps) over its
    # link-active entries [updone, tail).  Recomputed from the queues
    # once per tick; plan events then maintain it incrementally (a new
    # lease can only raise its own client's max).
    def active_link_bps(st):
        pos = jnp.arange(F, dtype=jnp.int32)
        act_mask = (pos[None, :] >= st.updone[:, None]) & (
            pos[None, :] < jnp.clip(st.tail, 0, F)[:, None]
        )
        return jnp.max(jnp.where(act_mask, st.q_bps, 0.0), axis=1)  # [N]

    # -- the EdgeServerScheduler allocation gate (one client's allocate) ---
    def allocate(st, c, t0, released, act_bps):
        lease_len = st.tail - released  # [N]
        total = jnp.sum(lease_len)
        B0 = bw_at(t0)  # the reference plans against trace.at(t0)
        if fifo:
            return B0, jnp.bool_(False)
        own = lease_len[c]
        effective = total - jnp.minimum(own, 1)
        backlogged = st.sbu - t0 > L
        if prio_pol:
            free = K - total
            higher_waiting = jnp.sum(
                ((prio > prio[c]) & (lease_len == 0)).astype(jnp.int32)
            )
            reserved = free <= higher_waiting
        else:
            reserved = jnp.bool_(False)
        gated = (effective >= K) | backlogged | reserved
        used = _seq_sum(jnp.where(cids != c, act_bps, 0.0))
        available = jnp.maximum(B0 - used, 0.0)
        share = B0 * w_eff[c] / tot_w
        grant = jnp.minimum(share, available)
        denied = gated | (grant <= 0.0)
        grant = jnp.where(denied, 0.0, grant)
        return grant, denied

    # -- register one head-frame offload on the link + server lease --------
    def register(st, act_bps, c, *, on, t0, seq, grant, bits, ddl, acc, tsv):
        e = jnp.clip(st.tail[c], 0, F - 1)
        cap = jnp.float64(np.inf) if fifo else grant

        def put(q, val):
            return q.at[c, e].set(jnp.where(on, val, q[c, e]))

        sbu = st.sbu
        if not fifo:
            # The reference divides by max(capacity, 1), even at K == 0.
            sbu = jnp.where(on, jnp.maximum(st.sbu, t0) + tsv / KW, st.sbu)
        st = st._replace(
            q_bits=put(st.q_bits, bits),
            q_cap=put(st.q_cap, cap),
            q_ddl=put(st.q_ddl, ddl),
            q_acc=put(st.q_acc, acc),
            q_tsrv=put(st.q_tsrv, tsv),
            q_bps=put(st.q_bps, grant),
            q_seq=put(st.q_seq, seq),
            tail=st.tail.at[c].add(jnp.where(on, 1, 0)),
            sbu=sbu,
        )
        act_bps = act_bps.at[c].set(
            jnp.where(on, jnp.maximum(act_bps[c], grant), act_bps[c])
        )
        return st, act_bps

    def init_state():
        return _Fleet(
            now=jnp.float64(0.0),
            q_bits=jnp.zeros((N, F), jnp.float64),
            q_cap=jnp.full((N, F), _BIG, jnp.float64),
            q_ddl=jnp.zeros((N, F), jnp.float64),
            q_acc=jnp.zeros((N, F), jnp.float64),
            q_tsrv=jnp.zeros((N, F), jnp.float64),
            q_bps=jnp.zeros((N, F), jnp.float64),
            q_seq=jnp.full((N, F), _BIG_I32, jnp.int32),
            q_srvfin=jnp.full((N, F), _BIG, jnp.float64),
            tail=jnp.zeros((N,), jnp.int32),
            updone=jnp.zeros((N,), jnp.int32),
            worker_free=jnp.zeros((KW,), jnp.float64),
            sbu=jnp.float64(0.0),
            grants=jnp.int32(0),
            denials=jnp.int32(0),
            sjobs=jnp.int32(0),
            sbusy=jnp.float64(0.0),
            accs=jnp.zeros((N,), jnp.float64),
            proc=jnp.zeros((N,), jnp.int32),
            miss=jnp.zeros((N,), jnp.int32),
            offl=jnp.zeros((N,), jnp.int32),
            head=jnp.zeros((N,), jnp.int32),
            busy=jnp.zeros((N,), jnp.float64),
            rounds=jnp.zeros((N,), jnp.int32),
            npus=jnp.zeros((N,), jnp.float64),
        )

    def finish(st):
        # Post-stream drain: in-flight uploads finish (and audit) after the
        # last round boundary, exactly as the reference keeps its event loop
        # alive until the link empties.  Anything still queued could not
        # drain (the event budget tripped, or a dead link): every stranded
        # upload is a deadline miss.
        st = drain(st, jnp.float64(_BIG), advance_to_target=False)
        return st._replace(miss=st.miss + (st.tail - st.updone))

    return SimpleNamespace(
        bw_at=bw_at, heads=heads, waterfill=waterfill, link_state=link_state,
        complete_batch=complete_batch, mop_up=mop_up, drain=drain,
        active_link_bps=active_link_bps, allocate=allocate, register=register,
        init_state=init_state, finish=finish,
    )


@lru_cache(maxsize=None)
def _fleet_program(alloc: str, N: int, K: int, F: int, J: int, R: int, S: int):
    """Compile one (allocation policy, fleet size, capacity, frame count)
    shape group of the ``offload`` planner.  J/R are the model/resolution
    table sizes; S is the padded bandwidth-segment count (sentinel segments
    at t_start=+inf are inert — see ``sim_batch._trace_bw``)."""
    fifo = alloc == "fifo"

    def one(bw_t, bw_v, gamma, T, rtt, fps, L, alpha, is_util, w_fluid, w_eff,
            tot_w, prio, order, bits_r, acc_sv, t_srv):
        phys = _fleet_physics(
            alloc, N, K, F, bw_t=bw_t, bw_v=bw_v, rtt=rtt, L=L,
            w_fluid=w_fluid, w_eff=w_eff, tot_w=tot_w, prio=prio,
        )

        # -- one client's plan event: allocate -> plan -> register ---------
        def plan_one(rank, carry):
            st, k, t0, released, act_bps = carry
            c = order[rank]
            grant, denied = phys.allocate(st, c, t0, released, act_bps)
            st = st._replace(
                grants=st.grants + jnp.where(denied, 0, 1),
                denials=st.denials + jnp.where(denied, 1, 0),
            )

            # Closed-form offload round against the granted bandwidth: the
            # reference's per-resolution loop as one [R] expression.
            t_up = bits_r / grant  # inf when grant == 0, like upload_time
            budget = T - t_up - rtt  # [R]
            fits = t_srv[:, None] <= budget[None, :]  # [J, R]
            a_mask = jnp.where(fits, acc_sv, -jnp.inf)
            j_best = jnp.argmax(a_mask, axis=0).astype(jnp.int32)  # first max
            a_best = jnp.max(a_mask, axis=0)
            feasible = (t_up <= gamma) & jnp.any(fits, axis=0)
            util_score = (
                jnp.minimum(1.0 / jnp.maximum(t_up, 1e-9), fps) + alpha * a_best
            )
            score = jnp.where(is_util, util_score, a_best)
            score = jnp.where(feasible, score, -jnp.inf)
            offload = jnp.any(feasible)
            r_pick = jnp.argmax(score).astype(jnp.int32)  # first max wins ties
            j_pick = j_best[r_pick]

            st, act_bps = phys.register(
                st, act_bps, c, on=offload, t0=t0, seq=k * N + rank,
                grant=grant, bits=bits_r[r_pick], ddl=t0 + T,
                acc=acc_sv[j_pick, r_pick], tsv=t_srv[j_pick],
            )
            return st, k, t0, released, act_bps

        # -- the tick scan --------------------------------------------------
        def tick(st, k):
            t0 = k.astype(jnp.float64) * gamma
            st = phys.drain(st, t0, advance_to_target=True)
            # Server slots whose jobs have finished by t0 free their leases.
            released = jnp.sum(
                (st.q_srvfin <= t0 + _EPS).astype(jnp.int32), axis=1
            )
            st, _, _, _, _ = jax.lax.fori_loop(
                0, N, plan_one,
                (st, k.astype(jnp.int32), t0, released, phys.active_link_bps(st)),
            )
            return st, None

        st, _ = jax.lax.scan(tick, phys.init_state(), jnp.arange(F, dtype=jnp.int32))
        st = phys.finish(st)
        return st.accs, st.proc, st.miss, st.grants, st.denials, st.sjobs, st.sbusy

    return LaneProgram(one, (0,) * 14 + (None,) * 3)


# ---------------------------------------------------------------------------
# The DP planner fleet programs: max_accuracy / max_utility.  Each client's
# round is the sim_batch rendering of the reference plan_round — but planned
# against the GRANTED bandwidth, with the head-frame offload registered on
# the shared link (audited at actual completion, like the reference's
# on_offload callback) instead of scored at plan time.  Clients plan only at
# their own round boundaries (head[c] == k); the inter-tick drain runs only
# when somebody plans, so the rate re-evaluation points are exactly the
# reference's event set (plan events + completion events).
# ---------------------------------------------------------------------------


def _dp_backtrack(W: int, NBINS: int):
    """Backtrack an _accuracy_dp64 table on [W] vectors (a second cheap
    scan beats materializing a [W, NBINS] select of the winner's tables)."""

    def backtrack(cho, par, b0, upto):
        def bt(b, k):
            on = k < upto  # prefix records: frames past upto not ours
            bc = jnp.clip(b, 0, NBINS - 1)
            pick = jnp.where(on, cho[k, bc], -1)
            return jnp.where(on & (pick >= 0), par[k, bc], b), pick

        _, picks_rev = jax.lax.scan(
            bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
        )
        return picks_rev[::-1]

    return backtrack


@lru_cache(maxsize=None)
def _acc_fleet_program(alloc: str, N: int, K: int, F: int, W: int, NBINS: int,
                       S: int, J: int, R: int, strict: bool):
    def one(bw_t, bw_v, gamma, deadline, rtt, grid, L, n_active,
            arr0, dl0, arr1, dl1, dur, arrivals, acc_stat,
            w_fluid, w_eff, tot_w, prio, order,
            bits_r, acc_sv, t_srv, acc_dp, t_npu64):
        phys = _fleet_physics(
            alloc, N, K, F, bw_t=bw_t, bw_v=bw_v, rtt=rtt, L=L,
            w_fluid=w_fluid, w_eff=w_eff, tot_w=tot_w, prio=prio,
        )
        ks = jnp.arange(W, dtype=jnp.int32)
        rounded = n_active > 0  # traced, always true: _no_fma's gate
        backtrack = _dp_backtrack(W, NBINS)

        # Both DP variants depend on the shared round state only through the
        # client's own NPU horizon (start_bin): a client's ``busy`` is
        # written by nobody but its own plan, and each client plans at most
        # once per round — so the expensive DP tables for all N clients
        # batch into one vmap OUTSIDE the sequential allocate/register
        # chain, which then runs on cheap scalars.
        def dp_tables(st, t0):
            start_bins = jnp.ceil(
                jnp.maximum(jnp.maximum(0.0, st.busy - t0), 0.0) / grid
            ).astype(jnp.int32)  # [N]
            # One fused vmap over 2N (client x {offload,local}) seeds: the
            # offload (arr1/dl1) and pure-local (arr0/dl0) tables share one
            # scan, halving the sequential DP step count per round.
            arr_b = jnp.concatenate(
                [jnp.broadcast_to(arr1, (N, W)), jnp.broadcast_to(arr0, (N, W))]
            )
            dl_b = jnp.concatenate(
                [jnp.broadcast_to(dl1, (N, W)), jnp.broadcast_to(dl0, (N, W))]
            )
            res = jax.vmap(
                lambda a, d, sb: _accuracy_dp64(
                    dur, acc_dp, a, d, sb, n_frames=W, nbins=NBINS
                )
            )(arr_b, dl_b, jnp.concatenate([start_bins, start_bins]))
            dp1 = tuple(r[:N] for r in res)
            dp0 = tuple(r[N:] for r in res)
            return start_bins, dp1, dp0

        def make_plan_one(k, t0, released, start_bins, dp1, dp0):
            def plan_one(rank, carry):
                (st, act_bps, planning_v, use_off_v, use_loc_v, nn_v,
                 npu_free_v, b0_off_v, b0_loc_v) = carry
                c = order[rank]
                planning = st.head[c] == k
                grant, denied = phys.allocate(st, c, t0, released, act_bps)
                st = st._replace(
                    grants=st.grants + jnp.where(planning & ~denied, 1, 0),
                    denials=st.denials + jnp.where(planning & denied, 1, 0),
                )

                npu_free = jnp.maximum(0.0, st.busy[c] - t0)
                start_bin = start_bins[c]
                # The reference plans against NetworkState(grant, rtt).
                t_up = jnp.where(grant > 0.0, bits_r / grant, jnp.inf)  # [R]
                budget = deadline - t_up - rtt  # [R]
                fits = t_srv[:, None] <= budget[None, :]  # [J, R]
                a_cand = jnp.where(fits, acc_sv, -jnp.inf)
                j_best = jnp.argmax(a_cand, axis=0).astype(jnp.int32)  # first max
                a_best = jnp.max(a_cand, axis=0)
                r_ok = (budget > 0.0) & jnp.any(fits, axis=0)
                n_l = jnp.floor(jnp.where(r_ok, t_up, 0.0) / gamma)
                n_l = jnp.clip(n_l, 0, W).astype(jnp.int32)  # [R]
                _, _, mh1, ab1, alive1 = (a[c] for a in dp1)
                nlm1 = jnp.clip(n_l - 1, 0, W - 1)
                # The reference sizes each DP instance at ceil(horizon/grid)+2
                # bins and declares start_bin >= nbins infeasible; rebuild
                # that per-candidate bound from the shared prefix scan.
                nb1 = jnp.ceil(
                    (gamma + _no_fma((n_l.astype(jnp.float64) - 1.0) * gamma, rounded)
                     + deadline) / grid
                ).astype(jnp.int32) + 2
                dp_ok = jnp.where(n_l == 0, True, alive1[nlm1] & (start_bin < nb1))
                dp_tot = jnp.where(n_l == 0, 0.0, mh1[nlm1])
                feas = r_ok & dp_ok
                norm = jnp.where(feas, (a_best + dp_tot) / (n_l + 1).astype(jnp.float64), NEG)
                r_star = jnp.argmax(norm).astype(jnp.int32)  # first max = lowest r
                off_exists = feas[r_star]
                off_norm = norm[r_star]

                _, _, mh0, ab0, alive0 = (a[c] for a in dp0)
                # local_window_plan tries nn = n..1 and keeps the first feasible;
                # aliveness is prefix-monotone, so that is the leading-alive
                # count (and the start_bin bound only loosens as nn grows).
                A = jnp.sum((alive0 & (ks < n_active)).astype(jnp.int32), dtype=jnp.int32)
                nb0 = jnp.ceil(
                    (_no_fma((A.astype(jnp.float64) - 1.0) * gamma, rounded) + deadline)
                    / grid
                ).astype(jnp.int32) + 2
                loc_exists = (A >= 1) & (start_bin < nb0)
                loc_norm = jnp.where(
                    loc_exists, mh0[jnp.clip(A - 1, 0, W - 1)] / A.astype(jnp.float64), NEG
                )
                use_loc = loc_exists & (loc_norm > jnp.where(off_exists, off_norm, NEG))
                use_off = off_exists & ~use_loc

                nn = jnp.where(use_off, n_l[r_star], jnp.where(use_loc, A, 0))

                # Head-frame offload: register on the shared link (the audit
                # happens at actual completion in complete_batch — the
                # reference's on_offload path, NOT a plan-time score).
                j_star = j_best[r_star]
                st, act_bps = phys.register(
                    st, act_bps, c, on=planning & use_off, t0=t0, seq=k * N + rank,
                    grant=grant, bits=bits_r[r_star], ddl=t0 + deadline,
                    acc=acc_sv[j_star, r_star], tsv=t_srv[j_star],
                )

                horizon = jnp.where(
                    use_off, n_l[r_star] + 1, jnp.where(use_loc, A, 1)
                ).astype(jnp.int32)
                st = st._replace(
                    head=st.head.at[c].add(jnp.where(planning, horizon, 0)),
                    rounds=st.rounds.at[c].add(jnp.where(planning, 1, 0)),
                )
                return (st, act_bps,
                        planning_v.at[c].set(planning),
                        use_off_v.at[c].set(use_off),
                        use_loc_v.at[c].set(use_loc),
                        nn_v.at[c].set(nn),
                        npu_free_v.at[c].set(npu_free),
                        b0_off_v.at[c].set(ab1[nlm1[r_star]]),
                        b0_loc_v.at[c].set(ab0[jnp.clip(A - 1, 0, W - 1)]))

            return plan_one

        # Event-driven rounds, not frame ticks: the laggard client's head IS
        # the next plan event (heads advance by the full DP horizon, so most
        # ticks host no event at all), and a tick nobody plans at is not in
        # the reference's event set — draining there would add fluid-rate
        # re-evaluation points.  Visiting min(head) each iteration replays
        # plan events in exact time order; clients sharing the tick plan in
        # scheduler ``order`` inside plan_one.  Under vmap the while_loop
        # costs the batch-max round count — ~F/W iterations instead of F.
        def round_cond(st):
            return jnp.min(st.head) < F

        def round_body(st):
            k = jnp.min(st.head)
            t0 = _no_fma(k.astype(jnp.float64) * gamma, rounded)
            st = phys.drain(st, t0, advance_to_target=True)
            released = jnp.sum(
                (st.q_srvfin <= t0 + _EPS).astype(jnp.int32), axis=1
            )
            start_bins, dp1, dp0 = dp_tables(st, t0)
            zi = jnp.zeros((N,), jnp.int32)
            zb = jnp.zeros((N,), bool)
            zf = jnp.zeros((N,), jnp.float64)
            (st, _, planning, use_off, use_loc, nn, npu_free,
             b0_off, b0_loc) = jax.lax.fori_loop(
                0, N, make_plan_one(k, t0, released, start_bins, dp1, dp0),
                (st, phys.active_link_bps(st), zb, zb, zb, zi, zf, zi, zi),
            )

            # Picks backtracking and the frame audit depend only on the
            # client's own plan decision (``busy`` feeds nothing until the
            # next round's start_bin), so the heavy scans batch over clients
            # OUTSIDE the sequential allocate/register chain — mirroring the
            # dp_tables hoist on the way in.
            def finalize(c, off_c, loc_c, nn_c, free_c, b0_off_c, b0_loc_c,
                         on_c):
                picks_off = backtrack(dp1[0][c], dp1[1][c], b0_off_c,
                                      jnp.where(off_c, nn_c, 0))
                picks_loc = backtrack(dp0[0][c], dp0[1][c], b0_loc_c,
                                      jnp.where(loc_c, nn_c, 0))
                picks = jnp.where(off_c, picks_off, picks_loc)
                fa = jnp.where(off_c, gamma, 0.0)
                gate = on_c & (picks >= 0) & (ks < nn_c)
                free_end, acc_c, proc_c, miss_c, npu_c = _audit_scan(
                    head=k, frame_offset=jnp.where(off_c, 1, 0),
                    n_frames=F, n_active=n_active, arrivals=fa + arrivals,
                    deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                    picks=picks, gate=gate, free0=jnp.maximum(free_c, 0.0),
                    acc_sum=st.accs[c], proc=st.proc[c], miss=st.miss[c],
                    npu_s=st.npus[c], W=W, J=J, strict=strict,
                )
                busy_until = jnp.where(off_c | loc_c, free_end, free_c)
                return acc_c, proc_c, miss_c, npu_c, busy_until

            acc_v, proc_v, miss_v, npu_v, busy_v = jax.vmap(finalize)(
                jnp.arange(N, dtype=jnp.int32), use_off, use_loc, nn,
                npu_free, b0_off, b0_loc, planning,
            )
            return st._replace(
                accs=jnp.where(planning, acc_v, st.accs),
                proc=jnp.where(planning, proc_v, st.proc),
                miss=jnp.where(planning, miss_v, st.miss),
                npus=jnp.where(planning, npu_v, st.npus),
                busy=jnp.where(planning, t0 + busy_v, st.busy),
            )

        st = jax.lax.while_loop(round_cond, round_body, phys.init_state())
        st = phys.finish(st)
        return (st.accs, st.proc, st.miss, st.offl, st.rounds, st.npus,
                st.grants, st.denials, st.sjobs, st.sbusy)

    return LaneProgram(one, (0,) * 20 + (None,) * 5)


@lru_cache(maxsize=None)
def _util_fleet_program(alloc: str, N: int, K: int, F: int, W: int, S: int,
                        J: int, R: int, strict: bool, width: int):
    def one(bw_t, bw_v, gamma, deadline, rtt, alpha, fps, L, n_w,
            arrivals, acc_stat, w_fluid, w_eff, tot_w, prio, order,
            bits_r, acc_sv, t_srv, acc_dp, t_npu64):
        phys = _fleet_physics(
            alloc, N, K, F, bw_t=bw_t, bw_v=bw_v, rtt=rtt, L=L,
            w_fluid=w_fluid, w_eff=w_eff, tot_w=tot_w, prio=prio,
        )
        ks = jnp.arange(W, dtype=jnp.int32)
        rounded = n_w > 0  # traced, always true: _no_fma's gate

        def backtrack(u_final, parents, actions):
            slot0 = jnp.argmax(u_final).astype(jnp.int32)  # first max = front order

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            return picks_rev[::-1]

        def cand_stats(picks, acc0):
            # _round_utility's decision-order f64 fold; the head offload's
            # server accuracy seeds acc0 so the summation order matches.
            def f(carry, pick):
                n, a = carry
                takes = pick >= 0
                j = jnp.clip(pick, 0, J - 1)
                return (
                    n + takes.astype(jnp.int32),
                    a + jnp.where(takes, acc_stat[j], 0.0),
                ), None

            (n, a), _ = jax.lax.scan(f, (jnp.int32(0), acc0), picks)
            return n, a

        def plan_one(rank, carry):
            st, k, t0, released, act_bps, ovf = carry
            c = order[rank]
            planning = st.head[c] == k
            grant, denied = phys.allocate(st, c, t0, released, act_bps)
            st = st._replace(
                grants=st.grants + jnp.where(planning & ~denied, 1, 0),
                denials=st.denials + jnp.where(planning & denied, 1, 0),
            )

            npu_free = jnp.maximum(0.0, st.busy[c] - t0)
            t_up = jnp.where(grant > 0.0, bits_r / grant, jnp.inf)  # [R]
            # Offload phase: argmax_{j,r} capped-rate + alpha * a(j, r); the
            # reference iterates r-outer/j-inner with strict >, so the first
            # maximum over the r-major flattening wins ties identically.
            feas = (t_up[:, None] + t_srv[None, :] + rtt) <= deadline  # [R, J]
            rate = jnp.minimum(1.0 / jnp.maximum(t_up, 1e-9), fps)
            score = rate[:, None] + _no_fma(
                alpha * jnp.swapaxes(acc_sv, 0, 1), rounded
            )  # [R, J]
            flat = jnp.where(feas, score, -jnp.inf).reshape(-1)
            off_exists = jnp.any(feas)
            pick_rj = jnp.argmax(flat).astype(jnp.int32)
            r0 = pick_rj // J
            j0 = pick_rj - r0 * J
            t_up0 = jnp.where(off_exists, t_up[r0], 0.0)
            n_l = jnp.clip(jnp.floor(t_up0 / gamma), 0, W).astype(jnp.int32)
            n_plan = jnp.maximum(n_l, n_w - 1)
            win1 = jnp.maximum(jnp.maximum(n_plan, 1).astype(jnp.float64) * gamma, gamma)
            (_, u1, _, _), par1, act1, ov1 = _utility_dp64(
                t_npu64, acc_dp, n_plan, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=gamma, window=win1,
            )
            win2 = jnp.maximum(n_w.astype(jnp.float64) * gamma, gamma)
            (_, u2, _, _), par2, act2, ov2 = _utility_dp64(
                t_npu64, acc_dp, n_w, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=jnp.float64(0.0), window=win2,
            )
            ovf = ovf | (planning & (ov1 | ov2))
            picks1 = backtrack(u1, par1, act1)
            picks2 = backtrack(u2, par2, act2)
            srv_acc = acc_sv[j0, r0]
            n1, a_off = cand_stats(picks1, srv_acc)  # server acc accumulates first
            n2, a_loc = cand_stats(picks2, jnp.float64(0.0))
            # The true round objective (_round_utility) for both candidates.
            p_off = (n1 + 1).astype(jnp.float64)
            h_off = jnp.maximum(n_plan + 1, 1).astype(jnp.float64)
            u_off = jnp.where(
                off_exists, p_off / (h_off * gamma) + alpha * a_off / p_off, NEG
            )
            u_loc = jnp.where(
                n2 > 0,
                n2.astype(jnp.float64) / (n_w.astype(jnp.float64) * gamma)
                + alpha * a_loc / n2.astype(jnp.float64),
                0.0,
            )
            use_off = off_exists & (u_off >= u_loc)  # first candidate wins ties
            use_loc = ~use_off & (n2 > 0)

            nn = jnp.where(use_off, n_plan, jnp.where(use_loc, n_w, 0))
            picks = jnp.where(use_off, picks1, picks2)

            # Head-frame offload: register on the shared link (audited at
            # actual completion — the reference's on_offload path).
            j0c = jnp.clip(j0, 0, J - 1)
            st, act_bps = phys.register(
                st, act_bps, c, on=planning & use_off, t0=t0, seq=k * N + rank,
                grant=grant, bits=bits_r[jnp.clip(r0, 0, R - 1)],
                ddl=t0 + deadline, acc=srv_acc, tsv=t_srv[j0c],
            )

            fa = jnp.where(use_off, gamma, 0.0)
            gate = planning & (picks >= 0) & (ks < nn)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_c, proc_c, miss_c, npu_c = _audit_scan(
                head=st.head[c], frame_offset=jnp.where(use_off, 1, 0),
                n_frames=F, n_active=n_w, arrivals=fa + arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                picks=picks, gate=gate, free0=free0, acc_sum=st.accs[c],
                proc=st.proc[c], miss=st.miss[c], npu_s=st.npus[c],
                W=W, J=J, strict=strict,
            )
            busy_until = jnp.where(use_off | use_loc, free_end, npu_free)
            horizon = jnp.where(
                use_off, n_plan + 1, jnp.where(use_loc, n_w, 1)
            ).astype(jnp.int32)
            st = st._replace(
                accs=st.accs.at[c].set(acc_c),
                proc=st.proc.at[c].set(proc_c),
                miss=st.miss.at[c].set(miss_c),
                npus=st.npus.at[c].set(npu_c),
                head=st.head.at[c].add(jnp.where(planning, horizon, 0)),
                busy=st.busy.at[c].set(jnp.where(planning, t0 + busy_until, st.busy[c])),
                rounds=st.rounds.at[c].add(jnp.where(planning, 1, 0)),
            )
            return st, k, t0, released, act_bps, ovf

        # Event-driven rounds over min(head) — see _acc_fleet_program; the
        # overflow flag rides the carry so a too-narrow Pareto front in ANY
        # round marks the lane for the capped rerun.
        def round_cond(carry):
            st, _ = carry
            return jnp.min(st.head) < F

        def round_body(carry):
            st, ovf = carry
            k = jnp.min(st.head)
            t0 = _no_fma(k.astype(jnp.float64) * gamma, rounded)
            st = phys.drain(st, t0, advance_to_target=True)
            released = jnp.sum(
                (st.q_srvfin <= t0 + _EPS).astype(jnp.int32), axis=1
            )
            st, _, _, _, _, ovf = jax.lax.fori_loop(
                0, N, plan_one,
                (st, k, t0, released, phys.active_link_bps(st), ovf),
            )
            return st, ovf

        st, ovf = jax.lax.while_loop(
            round_cond, round_body, (phys.init_state(), jnp.zeros((), bool))
        )
        st = phys.finish(st)
        return (st.accs, st.proc, st.miss, st.offl, st.rounds, st.npus,
                st.grants, st.denials, st.sjobs, st.sbusy, ovf)

    return LaneProgram(one, (0,) * 16 + (None,) * 5)


# ---------------------------------------------------------------------------
# The local-only planner fleet programs: jax_accuracy / jax_utility.  Their
# plans never read the grant, so every client of a homogeneous fleet follows
# the identical trajectory — one lane per scenario reuses the single-stream
# sim_batch body verbatim (bit-identical per-client stats) and adds the
# scheduler's grant/denial bookkeeping: the reference still calls
# ``allocate`` once per client per plan event, and for a fleet that never
# takes a lease the gate outcome factors into a static per-client predicate
# (capacity <= 0, priority reservation, non-positive effective weight —
# ``den0`` clients) plus two time-varying shared terms (trace bandwidth
# non-positive, backlog clock past the limit) that deny everyone at once.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _jax_acc_fleet_program(W: int, NBINS: int, S: int, J: int, strict: bool):
    def one(gamma, deadline, grid, n_active, nbins_real, n_frames,
            arr_bins, dl_bins, dur, arrivals, acc_stat,
            n_clients, den0, gated, L, bw_t, bw_v, t_npu64, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s, grants, denials = c
            active = head < n_frames  # lane gating under vmap-of-while
            t0 = head.astype(jnp.float64) * gamma
            # Fleet bookkeeping: one allocate() per client per plan event.
            shared_den = gated & (
                (0.0 - t0 > L) | (_trace_bw(bw_t, bw_v, t0) <= 0.0)
            )
            den_n = jnp.where(shared_den, n_clients, den0)
            grants = grants + jnp.where(active, n_clients - den_n, 0)
            denials = denials + jnp.where(active, den_n, 0)
            npu_free = jnp.maximum(0.0, busy - t0)
            # Reference: int(np.ceil(max(npu_free, 0.0) / grid)), clipped to
            # the scenario's REAL bin count (not the padded one) — the clip
            # target is observable when npu_free overruns the horizon.
            start_bin = jnp.ceil(jnp.maximum(npu_free, 0.0) / grid).astype(jnp.int32)
            start_bin = jnp.clip(start_bin, 0, nbins_real - 1)
            H, choices, parents = _accuracy_dp(
                dur, acc_dp32, arr_bins, dl_bins, start_bin, n_active,
                n_frames=W, nbins=NBINS,
            )
            feasible = jnp.max(H) > NEG / 2
            b0 = jnp.argmax(H).astype(jnp.int32)

            def bt(b, k):
                bc = jnp.clip(b, 0, NBINS - 1)
                pick = choices[k, bc]
                return jnp.where(pick >= 0, parents[k, bc], b), pick

            _, picks_rev = jax.lax.scan(
                bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & feasible & (jnp.arange(W, dtype=jnp.int32) < n_active)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            # Infeasible window: the reference emits a horizon-1 SKIP round
            # that leaves the NPU carry untouched.
            busy_until = jnp.where(feasible, free_end, npu_free)
            horizon = jnp.where(feasible, n_active, 1)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s, grants, denials

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6], out[7], out[8]

    return LaneProgram(one, (0,) * 17 + (None,) * 2)


@lru_cache(maxsize=None)
def _jax_util_fleet_program(W: int, width: int, S: int, J: int, strict: bool):
    def one(gamma, deadline, n_active, n_frames, g32, d32, a32, w32,
            arrivals, acc_stat, n_clients, den0, gated, L, bw_t, bw_v,
            t_npu64, t_npu32, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s, grants, denials = c
            active = head < n_frames
            t0 = head.astype(jnp.float64) * gamma
            shared_den = gated & (
                (0.0 - t0 > L) | (_trace_bw(bw_t, bw_v, t0) <= 0.0)
            )
            den_n = jnp.where(shared_den, n_clients, den0)
            grants = grants + jnp.where(active, n_clients - den_n, 0)
            denials = denials + jnp.where(active, den_n, 0)
            npu_free = jnp.maximum(0.0, busy - t0)
            (_, u, _, _), parents, actions, _ = _utility_dp(
                t_npu32, acc_dp32, n_active,
                n_frames=W, width=width, gamma=g32, deadline=d32, alpha=a32,
                npu_free=npu_free.astype(jnp.float32),
                first_arrival=jnp.float32(0.0), window=w32,
            )
            slot0 = jnp.argmax(u).astype(jnp.int32)

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & (picks >= 0)  # only picked frames execute; rest SKIP
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            head = jnp.where(active, head + n_active, head)  # horizon is always n
            busy = jnp.where(active, t0 + free_end, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s, grants, denials

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6], out[7], out[8]

    return LaneProgram(one, (0,) * 16 + (None,) * 3)


# ---------------------------------------------------------------------------
# Host drivers: f64 precomputation mirrors the reference expression by
# expression (frame bits, accuracy tables, bin edges, effective weights,
# plan-event ordering), then one compiled program per shape group.
# ---------------------------------------------------------------------------


def _stitch(scenarios, key_fn, run_group) -> list[tuple[MultiStreamStats, dict]]:
    groups: dict[Any, list[int]] = {}
    for i, s in enumerate(scenarios):
        groups.setdefault(key_fn(s), []).append(i)
    out: list[tuple[MultiStreamStats, dict] | None] = [None] * len(scenarios)
    for key in sorted(groups, key=repr):
        idx = groups[key]
        for i, st in zip(idx, run_group(key, [scenarios[i] for i in idx])):
            out[i] = st
    return out  # type: ignore[return-value]


def _segments(group: list[FleetScenario]):
    return segment_arrays(
        [s.bw_segments or ((0.0, s.bandwidth_bps),) for s in group]
    )


def _fleet_host_arrays(group: list[FleetScenario], N: int, alloc: str):
    """Per-lane scheduler tensors, the scalar reference arithmetic verbatim:
    fluid weights floor at ``_EPS`` (the reference's ``max(weight, _EPS)``),
    effective weights and their total use the scheduler's own expressions so
    shares match to the bit, and the plan-event order inside a tick is the
    reference's event key ``(t, -priority, -weight, client_id)``."""
    w = np.array(
        [s.weights if s.weights is not None else (1.0,) * N for s in group],
        np.float64,
    )
    prio = np.array(
        [s.priorities if s.priorities is not None else (0,) * N for s in group],
        np.int32,
    )
    w_fluid = np.maximum(w, _EPS)
    if alloc == "priority":
        w_eff = np.array(
            [[wi * (2.0 ** int(pi)) for wi, pi in zip(wr, pr)]
             for wr, pr in zip(w, prio)],
            np.float64,
        )
    else:
        w_eff = w.copy()
    tot_w = np.array([sum(row) or 1.0 for row in w_eff], np.float64)
    order = np.stack(
        [np.lexsort((np.arange(N), -wr, -pr)) for wr, pr in zip(w, prio)]
    ).astype(np.int32)
    return w_fluid, w_eff, tot_w, prio, order


def _shims(group: list[FleetScenario]) -> list[BatchScenario]:
    """Reuse sim_batch's per-scenario precomputation (``_common``) by
    presenting each fleet point as a single-stream scenario shape."""
    return [
        BatchScenario(stream=s.stream, n_frames=s.n_frames, params=s.params)
        for s in group
    ]


def _fleet_results(group, out, wall):
    """Per-client StreamStats + meta for the DP planner fleet drivers."""
    accs, proc, miss, offl, rounds, npus, grants, denials, sjobs, sbusy = out
    total_rounds = max(int(rounds.sum()), 1)
    results = []
    for b, s in enumerate(group):
        elapsed = s.n_frames * s.stream.gamma
        per_client = [
            StreamStats(
                frames_total=s.n_frames,
                frames_processed=int(proc[b, c]),
                frames_missed_deadline=int(miss[b, c]),
                frames_offloaded=int(offl[b, c]),
                accuracy_sum=float(accs[b, c]),
                elapsed=elapsed,
                schedule_calls=int(rounds[b, c]),
                # One device program schedules the whole group; report the
                # amortized per-round cost (as sim_batch does).
                schedule_time=wall * float(rounds[b, c]) / total_rounds,
                npu_busy_s=float(npus[b, c]),
            )
            for c in range(s.n_clients)
        ]
        ms = MultiStreamStats(
            per_client=per_client,
            server_jobs=int(sjobs[b]),
            server_busy_s=float(sbusy[b]),
            elapsed=elapsed,
        )
        results.append(
            (ms, {"grants": int(grants[b]), "denials": int(denials[b])})
        )
    return results


def _planner_group_key(s: FleetScenario) -> tuple:
    """Shape statics for the DP planner fleet programs: allocation / fleet
    size / capacity / frame count fix the link arrays; resolutions and
    png_ratio fix the (group-shared) payload and server-accuracy tables;
    the quantized window fixes the DP shapes."""
    return (
        s.allocation,
        int(s.n_clients),
        int(s.capacity),
        int(s.n_frames),
        tuple(s.stream.resolutions),
        float(s.stream.png_ratio),
        _quant_w(_window_frames(s.stream, s.params)),
    )


@_planner("offload")
def _run_offload(models, scenarios, strict):
    # ``strict`` has no observable effect here: offload plans contain no NPU
    # decisions, so the plan-time audit's bad set is empty either way.
    del strict
    t_srv = np.array([m.t_server for m in models], np.float64)

    def run_group(key, group):
        alloc, N, K, F, resolutions, png_ratio = key
        B_ = len(group)
        R = len(resolutions)
        # Frame payloads: frame_bytes(r) * 8.0, the value the reference
        # feeds both upload_time and _Upload.bits_left.
        bits_r = np.array(
            [group[0].stream.frame_bytes(r) * 8.0 for r in resolutions], np.float64
        )
        acc_sv = np.array(
            [[m.accuracy(r, where="server") for r in resolutions] for m in models],
            np.float64,
        )
        # Bandwidth trace segments in the shared on-device layout (sorting,
        # power-of-two padding, inert t_start=+inf sentinels — one
        # definition in sim_batch, read back by _trace_bw).
        bw_t, bw_v, S = _segments(group)
        gamma = np.array([s.stream.gamma for s in group], np.float64)
        T = np.array([s.stream.deadline for s in group], np.float64)
        rtt = np.array([s.rtt for s in group], np.float64)
        fps = np.array([s.stream.fps for s in group], np.float64)
        L = np.array([s.backlog_limit for s in group], np.float64)
        alpha_raw = [s.params.get("alpha") for s in group]
        alpha = np.array([a if a is not None else 0.0 for a in alpha_raw], np.float64)
        is_util = np.array([a is not None for a in alpha_raw], bool)
        w_fluid, w_eff, tot_w, prio, order = _fleet_host_arrays(group, N, alloc)

        program = _fleet_program(alloc, N, K, F, len(models), R, S)
        t0 = time.perf_counter()
        with enable_x64():
            out = program(
                bw_t, bw_v, gamma, T, rtt, fps, L, alpha, is_util, w_fluid,
                w_eff, tot_w, prio, order, bits_r, acc_sv, t_srv,
            )
            accs, proc, miss, grants, denials, sjobs, sbusy = (
                np.asarray(a) for a in out
            )
        wall = time.perf_counter() - t0

        results = []
        for b, s in enumerate(group):
            elapsed = s.n_frames * s.stream.gamma
            per_client = [
                StreamStats(
                    frames_total=s.n_frames,
                    frames_processed=int(proc[b, c]),
                    frames_missed_deadline=int(miss[b, c]),
                    frames_offloaded=int(proc[b, c]),  # offload-only plans
                    accuracy_sum=float(accs[b, c]),
                    elapsed=elapsed,
                    schedule_calls=F,
                    # One device program schedules the whole group; report
                    # the amortized per-round cost (as sim_batch does).
                    schedule_time=wall * F / max(B_ * N * F, 1),
                    npu_busy_s=0.0,
                )
                for c in range(N)
            ]
            ms = MultiStreamStats(
                per_client=per_client,
                server_jobs=int(sjobs[b]),
                server_busy_s=float(sbusy[b]),
                elapsed=elapsed,
            )
            results.append(
                (ms, {"grants": int(grants[b]), "denials": int(denials[b])})
            )
        return results

    def key_fn(s: FleetScenario) -> tuple:
        return (
            s.allocation,
            int(s.n_clients),
            int(s.capacity),
            int(s.n_frames),
            tuple(s.stream.resolutions),
            float(s.stream.png_ratio),
        )

    return _stitch(scenarios, key_fn, run_group)


@_planner("max_accuracy")
def _run_max_accuracy_fleet(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        alloc, N, K, F, resolutions, png_ratio, W = key
        c = _common(models, _shims(group), W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        # Bin arithmetic in f64 on the host — the same numpy expressions as
        # max_accuracy.local_dp, for both first_arrival values (0: the pure
        # local window; gamma: the frames buffered behind an offload).
        arr0 = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl0 = np.floor((c.arrivals + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        arrivals1 = c.gamma[:, None] + c.arrivals
        arr1 = np.ceil(arrivals1 / grid[:, None]).astype(np.int32)
        dl1 = np.floor((arrivals1 + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        horizon_t = c.gamma + (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        # Tight padding quantum: NBINS is derived per shape group (it is not
        # part of the group key), so a finer quantum costs no extra jit
        # compiles — and the fleet DP pays NBINS x rounds x N per lane,
        # where the single-stream planner pays it only once per window.
        NBINS = _quant_bins(int((np.ceil(horizon_t / grid) + 2).max()), q=32)
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        bits_r = np.array(
            [group[0].stream.frame_bytes(r) * 8.0 for r in resolutions], np.float64
        )
        acc_sv = np.array(
            [[m.accuracy(r, where="server") for r in resolutions] for m in models],
            np.float64,
        )
        bw_t, bw_v, S = _segments(group)
        rtt = np.array([s.rtt for s in group], np.float64)
        L = np.array([s.backlog_limit for s in group], np.float64)
        w_fluid, w_eff, tot_w, prio, order = _fleet_host_arrays(group, N, alloc)

        program = _acc_fleet_program(alloc, N, K, F, c.W, NBINS, S, c.J,
                                     len(resolutions), strict)
        t0 = time.perf_counter()
        with enable_x64():
            out = program(
                bw_t, bw_v, c.gamma, c.deadline, rtt, grid, L, c.n_active,
                arr0, dl0, arr1, dl1, dur, c.arrivals, c.acc_stat64,
                w_fluid, w_eff, tot_w, prio, order,
                bits_r, acc_sv, t_srv, acc_dp, c.t_npu64,
            )
            out = [np.asarray(a) for a in out]
        return _fleet_results(group, out, time.perf_counter() - t0)

    return _stitch(scenarios, _planner_group_key, run_group)


@_planner("max_utility")
def _run_max_utility_fleet(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        alloc, N, K, F, resolutions, png_ratio, W = key
        c = _common(models, _shims(group), W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        fps = np.array([s.stream.fps for s in group], np.float64)
        bits_r = np.array(
            [group[0].stream.frame_bytes(r) * 8.0 for r in resolutions], np.float64
        )
        acc_sv = np.array(
            [[m.accuracy(r, where="server") for r in resolutions] for m in models],
            np.float64,
        )
        bw_t, bw_v, S = _segments(group)
        rtt = np.array([s.rtt for s in group], np.float64)
        L = np.array([s.backlog_limit for s in group], np.float64)
        w_fluid, w_eff, tot_w, prio, order = _fleet_host_arrays(group, N, alloc)
        lane_args = (bw_t, bw_v, c.gamma, c.deadline, rtt, alpha, fps, L,
                     c.n_active, c.arrivals, c.acc_stat64,
                     w_fluid, w_eff, tot_w, prio, order)
        shared = (bits_r, acc_sv, t_srv, acc_dp, c.t_npu64)

        t0 = time.perf_counter()
        with enable_x64():
            out = _util_fleet_program(
                alloc, N, K, F, c.W, S, c.J, len(resolutions), strict,
                _UTIL_FAST_WIDTH,
            )(*lane_args, *shared)
            out = [np.array(a) for a in out]
            overflowed = np.nonzero(out[10])[0]
            if overflowed.size:
                # A Pareto front outgrew the fast width somewhere in these
                # lanes: rerun just them at the reference prune cap (exact
                # for any front size) and splice the results back in.
                sub = _util_fleet_program(
                    alloc, N, K, F, c.W, S, c.J, len(resolutions), strict,
                    _UTIL_CAP,
                )(*(a[overflowed] for a in lane_args), *shared)
                for dst, src in zip(out[:10], sub[:10]):
                    dst[overflowed] = np.asarray(src)
        return _fleet_results(group, out[:10], time.perf_counter() - t0)

    return _stitch(scenarios, _planner_group_key, run_group)


def _jax_fleet_lane_arrays(group: list[FleetScenario]):
    """Host mirrors of the allocation gates that are *static* for local-only
    plans: no lease is ever taken, so every ``allocate`` call sees the same
    scheduler state and only the trace bandwidth and the backlog clock vary.
    ``den0`` counts clients denied by the static gates (capacity <= 0,
    priority reservation over an empty lease table, non-positive effective
    weight or weight total); ``gated`` marks non-fifo lanes (fifo always
    grants)."""
    n_clients = np.array([s.n_clients for s in group], np.int32)
    den0 = np.zeros(len(group), np.int32)
    gated = np.zeros(len(group), bool)
    L = np.array([s.backlog_limit for s in group], np.float64)
    for i, s in enumerate(group):
        if s.allocation == "fifo":
            continue
        gated[i] = True
        N = s.n_clients
        w = np.array(
            s.weights if s.weights is not None else (1.0,) * N, np.float64
        )
        pr = np.array(
            s.priorities if s.priorities is not None else (0,) * N, np.int64
        )
        if s.allocation == "priority":
            w_eff = np.array(
                [wi * (2.0 ** int(pi)) for wi, pi in zip(w, pr)], np.float64
            )
            reserved = np.array(
                [s.capacity <= int(np.sum(pr > pr[ci])) for ci in range(N)], bool
            )
        else:
            w_eff = w
            reserved = np.zeros(N, bool)
        tot = float(sum(w_eff)) or 1.0
        d0 = (s.capacity <= 0) | reserved | (w_eff <= 0.0) | (tot <= 0.0)
        den0[i] = int(d0.sum())
    bw_t, bw_v, S = _segments(group)
    return n_clients, den0, gated, L, bw_t, bw_v, S


def _replicated_results(group, base, grants, denials):
    """Fleet reports for the local-only planners: every client of a
    homogeneous fleet follows the identical trajectory, so the per-lane
    single-stream stats replicate per client; the server never runs a job
    (no offloads), matching the reference's zero counters."""
    results = []
    for b, (s, st) in enumerate(zip(group, base)):
        per_client = [replace(st) for _ in range(s.n_clients)]
        ms = MultiStreamStats(
            per_client=per_client,
            server_jobs=0,
            server_busy_s=0.0,
            elapsed=st.elapsed,
        )
        results.append(
            (ms, {"grants": int(grants[b]), "denials": int(denials[b])})
        )
    return results


@_planner("jax_accuracy")
def _run_jax_accuracy_fleet(models, scenarios, strict):
    def run_group(W, group):
        c = _common(models, _shims(group), W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        # Bin arithmetic in f64 on the host — the same numpy expressions as
        # sim_batch._run_accuracy (and local_accuracy_dp_jax before it).
        arr_bins = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl_bins = np.floor(
            (c.arrivals + c.deadline[:, None]) / grid[:, None]
        ).astype(np.int32)
        horizon_t = (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        nbins_real = (np.ceil(horizon_t / grid) + 2).astype(np.int32)
        NBINS = _quant_bins(int(nbins_real.max()))
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        ncl, den0, gated, L, bw_t, bw_v, S = _jax_fleet_lane_arrays(group)
        t0 = time.perf_counter()
        with enable_x64():
            out = _jax_acc_fleet_program(c.W, NBINS, S, c.J, strict)(
                c.gamma, c.deadline, grid, c.n_active, nbins_real, c.n_frames,
                arr_bins, dl_bins, dur, c.arrivals, c.acc_stat64,
                ncl, den0, gated, L, bw_t, bw_v, c.t_npu64, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        base = _collect(c, out[:5], time.perf_counter() - t0)
        return _replicated_results(group, base, out[5], out[6])

    return _stitch(
        scenarios, lambda s: _quant_w(_window_frames(s.stream, s.params)), run_group
    )


@_planner("jax_utility")
def _run_jax_utility_fleet(models, scenarios, strict):
    def run_group(key, group):
        W, width = key
        c = _common(models, _shims(group), W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        # The f32 casts the reference wrapper performs, precomputed in bulk.
        g32 = c.gamma.astype(np.float32)
        d32 = c.deadline.astype(np.float32)
        a32 = alpha.astype(np.float32)
        window = np.maximum(c.n_active.astype(np.float64) * c.gamma, c.gamma)
        w32 = window.astype(np.float32)
        t_npu32 = c.t_npu64.astype(np.float32)
        ncl, den0, gated, L, bw_t, bw_v, S = _jax_fleet_lane_arrays(group)
        t0 = time.perf_counter()
        with enable_x64():
            out = _jax_util_fleet_program(c.W, width, S, c.J, strict)(
                c.gamma, c.deadline, c.n_active, c.n_frames,
                g32, d32, a32, w32, c.arrivals, c.acc_stat64,
                ncl, den0, gated, L, bw_t, bw_v,
                c.t_npu64, t_npu32, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        base = _collect(c, out[:5], time.perf_counter() - t0)
        return _replicated_results(group, base, out[5], out[6])

    return _stitch(
        scenarios,
        lambda s: (_quant_w(_window_frames(s.stream, s.params)), int(s.params["width"])),
        run_group,
    )


# ---------------------------------------------------------------------------
# Detect+track fleet planners: the sim_batch closed-form round (interval-mean
# candidate scoring, no bin DP) composed with the shared fleet physics.
# Detections contend — an offloaded detection registers on the fluid uplink
# and is audited (and installed into the client's detection state) at actual
# on-time completion, the reference's on_offload path — while tracker-carried
# frames are free local work that scores at the plan event against the state
# current there.  The detection state is the max-det_frame merge of plan-time
# NPU refreshes and completed on-time offloads, recomputed from the upload
# logs after every link drain (NPU refreshes always carry the newest frame at
# their plan event, and completion installs are recency-guarded in the
# reference, so the merge reproduces the event-ordered updates exactly).
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _track_fleet_program(alloc: str, N: int, K: int, F: int, KQ: int, S: int,
                         J: int, R: int, fixed: bool):
    A = F + 1  # retention-table width: ages reach F with the -1 initial state

    def one(bw_t, bw_v, gamma, deadline, rtt, L, k_lim, im, ret_pow, acc_stat,
            w_fluid, w_eff, tot_w, prio, order, bits_r, acc_sv, t_srv, t_npu64):
        phys = _fleet_physics(
            alloc, N, K, F, bw_t=bw_t, bw_v=bw_v, rtt=rtt, L=L,
            w_fluid=w_fluid, w_eff=w_eff, tot_w=tot_w, prio=prio,
        )
        cids = jnp.arange(N, dtype=jnp.int32)
        rounded = k_lim > 0  # traced, always true: _no_fma's gate
        # NPU candidates are round-invariant: j ascending, npu_interval.
        local = jnp.isfinite(t_npu64)
        kf = jnp.where(local, jnp.ceil(t_npu64 / gamma), 0.0)
        k_npu = jnp.maximum(kf.astype(jnp.int32), 1)  # [J]

        def make_plan_one(k, t0, released):
            def plan_one(rank, pc):
                (st, act_bps, det_acc, det_frm, q_detfrm,
                 planning_v, off0_v, hor_v) = pc
                c = order[rank]
                planning = st.head[c] == k
                grant, denied = phys.allocate(st, c, t0, released, act_bps)
                st = st._replace(
                    grants=st.grants + jnp.where(planning & ~denied, 1, 0),
                    denials=st.denials + jnp.where(planning & denied, 1, 0),
                )
                npu_free = jnp.maximum(0.0, st.busy[c] - t0)
                feas_npu = local & (npu_free + t_npu64 <= deadline) & (k_npu <= k_lim)
                # The reference plans against NetworkState(grant, rtt).
                t_up = jnp.where(grant > 0.0, bits_r / grant, jnp.inf)  # [R]
                budget = deadline - t_up - rtt  # [R]
                fits = t_srv[:, None] <= budget[None, :]  # [J, R]
                a_cand = jnp.where(fits, acc_sv, -jnp.inf)
                j_best = jnp.argmax(a_cand, axis=0).astype(jnp.int32)  # first max
                a_best = jnp.max(a_cand, axis=0)
                r_ok = (budget > 0.0) & jnp.any(fits, axis=0)
                k_srv = jnp.floor(
                    jnp.where(r_ok, t_up, 0.0) / gamma
                ).astype(jnp.int32) + 1
                feas_srv = r_ok & (k_srv <= k_lim)
                if fixed:
                    s_npu = jnp.where(feas_npu, acc_stat, -jnp.inf)
                    s_srv = jnp.where(feas_srv, a_best, -jnp.inf)
                else:
                    s_npu = jnp.where(
                        feas_npu,
                        acc_stat * im[jnp.clip(k_npu - 1, 0, KQ - 1)], -jnp.inf,
                    )
                    s_srv = jnp.where(
                        feas_srv,
                        a_best * im[jnp.clip(k_srv - 1, 0, KQ - 1)], -jnp.inf,
                    )
                # NPU-then-server candidate order with strict > first-wins ==
                # first-maximum argmax over the concatenation (sim_batch's
                # rendering of the reference planners).
                scores = jnp.concatenate([s_npu, s_srv])
                idx = jnp.argmax(scores).astype(jnp.int32)
                exists = scores[idx] > -jnp.inf
                is_npu = exists & (idx < J)
                is_srv = exists & ~is_npu
                j_pick = jnp.clip(idx, 0, J - 1)
                r_pick = jnp.clip(idx - J, 0, R - 1)
                k_det = jnp.where(is_npu, k_npu[j_pick], k_srv[r_pick])
                if fixed:
                    horizon = k_lim  # the interval is consumed even on SKIP
                else:
                    horizon = jnp.where(exists, k_det, 1)
                # NPU detection: scored and state-refreshed at the plan event.
                npu_take = planning & is_npu
                acc_j = acc_stat[j_pick]
                st = st._replace(
                    accs=st.accs.at[c].add(jnp.where(npu_take, acc_j, 0.0)),
                    proc=st.proc.at[c].add(jnp.where(npu_take, 1, 0)),
                    npus=st.npus.at[c].add(
                        jnp.where(npu_take, t_npu64[j_pick], 0.0)
                    ),
                )
                det_acc = det_acc.at[c].set(jnp.where(npu_take, acc_j, det_acc[c]))
                det_frm = det_frm.at[c].set(jnp.where(npu_take, k, det_frm[c]))
                # Offloaded detection: register on the shared link (audited
                # and installed at actual completion); state stays stale for
                # this round's tracked frames, exactly like on_offload.
                on_srv = planning & is_srv
                j_star = j_best[r_pick]
                e = jnp.clip(st.tail[c], 0, F - 1)
                q_detfrm = q_detfrm.at[c, e].set(
                    jnp.where(on_srv, k, q_detfrm[c, e])
                )
                st, act_bps = phys.register(
                    st, act_bps, c, on=on_srv, t0=t0, seq=k * N + rank,
                    grant=grant, bits=bits_r[r_pick], ddl=t0 + deadline,
                    acc=acc_sv[j_star, r_pick], tsv=t_srv[j_star],
                )
                busy_until = jnp.where(is_npu, npu_free + t_npu64[j_pick], npu_free)
                st = st._replace(
                    busy=st.busy.at[c].set(
                        jnp.where(planning, t0 + busy_until, st.busy[c])
                    ),
                    head=st.head.at[c].add(jnp.where(planning, horizon, 0)),
                    rounds=st.rounds.at[c].add(jnp.where(planning, 1, 0)),
                )
                return (st, act_bps, det_acc, det_frm, q_detfrm,
                        planning_v.at[c].set(planning),
                        off0_v.at[c].set(jnp.where(exists, 1, 0)),
                        hor_v.at[c].set(horizon))

            return plan_one

        def round_cond(carry):
            return jnp.min(carry[0].head) < F

        def round_body(carry):
            st, det_acc, det_frm, q_detfrm = carry
            k = jnp.min(st.head)
            t0 = _no_fma(k.astype(jnp.float64) * gamma, rounded)
            st = phys.drain(st, t0, advance_to_target=True)
            released = jnp.sum(
                (st.q_srvfin <= t0 + _EPS).astype(jnp.int32), axis=1
            )
            # Install completed on-time offloaded detections: recency-merge
            # the newest (max det_frame) against the plan-time NPU state.
            done = st.q_srvfin + rtt <= st.q_ddl + _EPS
            m_frm = jnp.where(done, q_detfrm, -1)
            bi = jnp.argmax(m_frm, axis=1).astype(jnp.int32)
            srv_frm = m_frm[cids, bi]
            newer = srv_frm > det_frm
            det_frm = jnp.where(newer, srv_frm, det_frm)
            det_acc = jnp.where(newer, st.q_acc[cids, bi], det_acc)

            zb = jnp.zeros((N,), bool)
            zi = jnp.zeros((N,), jnp.int32)
            (st, _, det_acc, det_frm, q_detfrm,
             planning, off0, hor) = jax.lax.fori_loop(
                0, N, make_plan_one(k, t0, released),
                (st, phys.active_link_bps(st), det_acc, det_frm, q_detfrm,
                 zb, zi, zi),
            )

            # Tracked frames depend only on the client's own post-plan state,
            # so the sequential fold batches over clients OUTSIDE the
            # allocate/register chain (ascending frame order per client —
            # the apply_track_round accumulation order).
            def finalize(c, on_c, off0_c, hor_c):
                def tr(o, a_pr):
                    a_s, pr = a_pr
                    on = on_c & (o >= off0_c) & (o < hor_c) & (k + o < F)
                    age = jnp.clip(k + o - det_frm[c], 0, A - 1)
                    v = _no_fma(det_acc[c] * ret_pow[age], rounded)
                    return a_s + jnp.where(on, v, 0.0), pr + on.astype(jnp.int32)

                return jax.lax.fori_loop(0, KQ, tr, (st.accs[c], st.proc[c]))

            acc_v, proc_v = jax.vmap(finalize)(cids, planning, off0, hor)
            st = st._replace(
                accs=jnp.where(planning, acc_v, st.accs),
                proc=jnp.where(planning, proc_v, st.proc),
            )
            return st, det_acc, det_frm, q_detfrm

        init = (
            phys.init_state(),
            jnp.zeros((N,), jnp.float64),
            jnp.full((N,), -1, jnp.int32),
            jnp.full((N, F), -1, jnp.int32),
        )
        st = phys.finish(jax.lax.while_loop(round_cond, round_body, init)[0])
        return (st.accs, st.proc, st.miss, st.offl, st.rounds, st.npus,
                st.grants, st.denials, st.sjobs, st.sbusy)

    return LaneProgram(one, (0,) * 15 + (None,) * 4)


def _run_track_fleet(models, scenarios, strict, *, fixed: bool):
    # ``strict`` has no observable effect: the plan-time audit is NPU-only
    # here (offloads audit at completion), and the track planners only emit
    # deadline-feasible NPU detections.
    del strict
    t_srv = np.array([m.t_server for m in models], np.float64)
    kname = "k" if fixed else "k_max"

    def key_fn(s: FleetScenario) -> tuple:
        return (
            s.allocation,
            int(s.n_clients),
            int(s.capacity),
            int(s.n_frames),
            tuple(s.stream.resolutions),
            float(s.stream.png_ratio),
            _quant_w(int(s.params[kname])),
        )

    def run_group(key, group):
        alloc, N, K, F, resolutions, png_ratio, KQ = key
        R = len(resolutions)
        c = _common(models, _shims(group), 1)  # windows are a classify concept
        B_ = len(group)
        k_lim = np.array([int(s.params[kname]) for s in group], np.int32)
        im = np.zeros((B_, KQ), np.float64)
        if not fixed:
            # interval_means is prefix-stable: padding KQ past a lane's k_max
            # cannot change any entry the planner may select.
            for i, s in enumerate(group):
                ret_b = retention(float(s.params["decay"]), float(s.params["density"]))
                im[i, :] = interval_means(ret_b, KQ)
        ret_pow = np.empty((B_, F + 1), np.float64)
        for i, s in enumerate(group):
            ret_pow[i, :] = retention_powers(s.workload.retention, F + 1)
        bits_r = np.array(
            [group[0].stream.frame_bytes(r) * 8.0 for r in resolutions], np.float64
        )
        acc_sv = np.array(
            [[m.accuracy(r, where="server") for r in resolutions] for m in models],
            np.float64,
        )
        bw_t, bw_v, S = _segments(group)
        rtt = np.array([s.rtt for s in group], np.float64)
        L = np.array([s.backlog_limit for s in group], np.float64)
        w_fluid, w_eff, tot_w, prio, order = _fleet_host_arrays(group, N, alloc)

        program = _track_fleet_program(alloc, N, K, F, KQ, S, c.J, R, fixed)
        t0 = time.perf_counter()
        with enable_x64():
            out = program(
                bw_t, bw_v, c.gamma, c.deadline, rtt, L, k_lim, im, ret_pow,
                c.acc_stat64, w_fluid, w_eff, tot_w, prio, order,
                bits_r, acc_sv, t_srv, c.t_npu64,
            )
            out = [np.asarray(a) for a in out]
        return _fleet_results(group, out, time.perf_counter() - t0)

    return _stitch(scenarios, key_fn, run_group)


@_planner("track_accuracy")
def _run_track_accuracy_fleet(models, scenarios, strict):
    return _run_track_fleet(models, scenarios, strict, fixed=False)


@_planner("track_fixed")
def _run_track_fixed_fleet(models, scenarios, strict):
    return _run_track_fleet(models, scenarios, strict, fixed=True)
