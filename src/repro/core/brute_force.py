"""Offline Optimal oracle (paper §VI.C "Optimal").

The paper replays the trace and searches all schedules offline.  Exhaustive
enumeration is O((n_c * n_r)^n); we provide

  * ``exhaustive_best`` — the literal search, exact in continuous time, for
    tiny instances (property-test oracle);
  * ``optimal_accuracy`` / ``optimal_utility`` — an equivalent *joint-resource
    dynamic program* over (frame, NPU-free offset, link-free offset[, count])
    on a discretized grid: exact up to the grid, tractable for whole traces.

The two contended resources are the NPU (serial) and the uplink (serial);
the edge server is parallel, as in the paper.  Durations are ceil'd to the
grid and deadlines floor'd, so the DP value is a *feasible* (lower-bound)
optimum; with grid -> 0 it converges to the true optimum from below.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .profiles import ModelProfile, NetworkState, StreamSpec
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

NEG = -1e18


@dataclass(frozen=True)
class Action:
    kind: str  # "npu" | "net"
    dur: float  # serial occupancy of the resource
    budget: float  # latest resource-free offset (vs arrival) that still meets T
    acc: float


def enumerate_actions(
    models: Sequence[ModelProfile], stream: StreamSpec, net: NetworkState
) -> list[Action]:
    T = stream.deadline
    acts: list[Action] = []
    for m in models:
        if m.runs_local and m.t_npu <= T:
            acts.append(Action("npu", m.t_npu, T - m.t_npu, m.accuracy(stream.r_max, where="npu")))
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        for m in models:
            if not m.runs_server:
                continue
            slack = T - t_up - net.rtt - m.t_server
            if slack < 0:
                continue
            acts.append(Action("net", t_up, slack, m.accuracy(r, where="server")))
    return acts


# ---------------------------------------------------------------------------
# Exact exhaustive search (tiny n) — the test oracle.
# ---------------------------------------------------------------------------


def exhaustive_best(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    n_frames: int,
    *,
    alpha: float | None = None,
) -> float:
    """Exact optimum by trying every (skip | action) per frame.

    Returns mean accuracy over all frames (alpha=None) or utility.
    Exponential — keep n_frames <= ~6 in tests.
    """
    gamma = stream.gamma
    acts = enumerate_actions(models, stream, net)
    best = {"v": 0.0}

    def rec(i: int, npu_free: float, net_free: float, acc_sum: float, m: int) -> None:
        if i == n_frames:
            if alpha is None:
                best["v"] = max(best["v"], acc_sum / n_frames)
            elif m > 0:
                best["v"] = max(best["v"], m / (n_frames * gamma) + alpha * acc_sum / m)
            return
        arrival = i * gamma
        rec(i + 1, npu_free, net_free, acc_sum, m)  # skip
        for a in acts:
            free = npu_free if a.kind == "npu" else net_free
            start = max(free, arrival)
            if start - arrival > a.budget + 1e-12:
                continue
            if a.kind == "npu":
                rec(i + 1, start + a.dur, net_free, acc_sum + a.acc, m + 1)
            else:
                rec(i + 1, npu_free, start + a.dur, acc_sum + a.acc, m + 1)

    rec(0, 0.0, 0.0, 0.0, 0)
    return best["v"]


# ---------------------------------------------------------------------------
# Grid DP — whole-trace Optimal.
# ---------------------------------------------------------------------------


def _dp_tables(acts: list[Action], grid: float, nb: int):
    table = []
    for a in acts:
        d = max(int(np.ceil(a.dur / grid - 1e-12)), 0)
        bmax = int(np.floor((a.budget + 1e-12) / grid))
        table.append((a.kind, d, min(bmax, nb - 1), a.acc))
    return table


def _decay(V: np.ndarray, k: int) -> np.ndarray:
    """Advance one frame: both resource offsets shrink by k bins (clamp at 0).

    V's last two axes are (npu_off, net_off); leading axes pass through.
    """
    if k == 0:
        return V
    nb = V.shape[-1]
    out = np.full_like(V, NEG)
    kk = min(k, nb)
    if kk < nb:
        out[..., : nb - kk, : nb - kk] = V[..., kk:, kk:]
        out[..., 0, : nb - kk] = np.maximum(
            out[..., 0, : nb - kk], V[..., :kk, kk:].max(axis=-2)
        )
        out[..., : nb - kk, 0] = np.maximum(
            out[..., : nb - kk, 0], V[..., kk:, :kk].max(axis=-1)
        )
    out[..., 0, 0] = np.maximum(out[..., 0, 0], V[..., :kk, :kk].max(axis=(-2, -1)))
    return out


def optimal_accuracy(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    n_frames: int,
    *,
    grid: float = 2e-3,
) -> float:
    """Mean accuracy of the (grid-)optimal offline schedule."""
    gamma, T = stream.gamma, stream.deadline
    nb = int(np.floor(T / grid)) + 1
    acts = enumerate_actions(models, stream, net)
    if not acts:
        return 0.0
    table = _dp_tables(acts, grid, nb)
    k = int(np.floor(gamma / grid))

    V = np.full((nb, nb), NEG)
    V[0, 0] = 0.0
    for _ in range(n_frames):
        Vn = V.copy()  # skip
        for kind, d, bmax, acc in table:
            if kind == "npu":
                for b in range(bmax + 1):
                    tgt = min(b + d, nb - 1)
                    Vn[tgt, :] = np.maximum(Vn[tgt, :], V[b, :] + acc)
            else:
                for b in range(bmax + 1):
                    tgt = min(b + d, nb - 1)
                    Vn[:, tgt] = np.maximum(Vn[:, tgt], V[:, b] + acc)
        V = _decay(Vn, k)
    return float(V.max()) / n_frames


def optimal_utility(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    n_frames: int,
    *,
    alpha: float,
    grid: float = 5e-3,
) -> float:
    """Optimal offline utility: rate + alpha * mean accuracy over processed."""
    gamma, T = stream.gamma, stream.deadline
    nb = int(np.floor(T / grid)) + 1
    acts = enumerate_actions(models, stream, net)
    if not acts:
        return 0.0
    table = _dp_tables(acts, grid, nb)
    k = int(np.floor(gamma / grid))

    V = np.full((n_frames + 1, nb, nb), NEG)  # [processed count m, npu, net]
    V[0, 0, 0] = 0.0
    for _ in range(n_frames):
        Vn = V.copy()  # skip
        for kind, d, bmax, acc in table:
            if kind == "npu":
                for b in range(bmax + 1):
                    tgt = min(b + d, nb - 1)
                    Vn[1:, tgt, :] = np.maximum(Vn[1:, tgt, :], V[:-1, b, :] + acc)
            else:
                for b in range(bmax + 1):
                    tgt = min(b + d, nb - 1)
                    Vn[1:, :, tgt] = np.maximum(Vn[1:, :, tgt], V[:-1, :, b] + acc)
        V = _decay(Vn, k)

    best = 0.0
    elapsed = n_frames * gamma
    for m in range(1, n_frames + 1):
        s = float(V[m].max())
        if s <= NEG / 2:
            continue
        best = max(best, m / elapsed + alpha * s / m)
    return best


# ---------------------------------------------------------------------------
# Oracle as a *policy*: a windowed grid DP with path recovery, so the oracle
# can be swept through the registry / Session front door like any heuristic.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _PlanAction:
    """An action with identity (model, resolution), unlike :class:`Action`."""

    kind: str  # "npu" | "net"
    model: int
    resolution: int
    dur: float  # serial occupancy of the resource (t_npu or t_up), seconds
    tail: float  # post-occupancy latency: 0 for npu, rtt + t_server for net
    acc: float


def _window_actions(
    models: Sequence[ModelProfile], stream: StreamSpec, net: NetworkState
) -> list[_PlanAction]:
    T = stream.deadline
    acts: list[_PlanAction] = []
    for j, m in enumerate(models):
        if m.runs_local and m.t_npu <= T:
            acts.append(
                _PlanAction("npu", j, stream.r_max, m.t_npu, 0.0,
                            m.accuracy(stream.r_max, where="npu"))
            )
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        for j, m in enumerate(models):
            if not m.runs_server or T - t_up - net.rtt - m.t_server < 0:
                continue
            acts.append(
                _PlanAction("net", j, r, t_up, net.rtt + m.t_server,
                            m.accuracy(r, where="server"))
            )
    return acts


@register_policy(
    "brute_force",
    params=(
        Param.number("alpha", None, nullable=True, doc="None = accuracy mode; float = utility weight"),
        Param.integer("window_frames", None, nullable=True, doc="DP window; default floor(T/gamma)"),
        Param.number("grid", 5e-3, doc="DP time grid (s); finer = closer to the true optimum"),
    ),
    doc="§VI.C Optimal oracle as a policy: windowed joint-resource grid DP.",
)
def plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    alpha: float | None = None,
    window_frames: int | None = None,
    grid: float = 5e-3,
) -> RoundPlan:
    """One oracle round: grid-optimal (skip | npu | offload) per window frame.

    Same discretization contract as :func:`optimal_accuracy` — durations are
    ceil'd to the grid and budgets floor'd, so any extracted schedule is
    feasible in continuous time; Decision timestamps are recomputed exactly
    during extraction.  State is (frame, npu-free offset, link-free offset)
    with per-count accuracy vectors so one DP serves both objectives.
    """
    gamma, T = stream.gamma, stream.deadline
    n = window_frames if window_frames is not None else max(int(np.floor(T / gamma)), 1)
    acts = _window_actions(models, stream, net)
    if not acts:
        return RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)

    nb = int(np.floor(T / grid)) + 1
    kdec = int(np.floor(gamma / grid))
    table = []  # (action, dur_bins, latest-start bin)
    for a in acts:
        d = max(int(np.ceil(a.dur / grid - 1e-12)), 0)
        bmax = int(np.floor((T - a.dur - a.tail + 1e-12) / grid))
        table.append((a, d, min(bmax, nb - 1)))

    memo: dict[tuple[int, int, int], tuple[np.ndarray, list[int]]] = {}

    def dec(b: int) -> int:
        return max(b - kdec, 0)

    def solve(k: int, bn: int, bl: int) -> tuple[np.ndarray, list[int]]:
        """vals[m] = best accuracy sum processing exactly m of frames k..n-1;
        choice[m] = action index taken at frame k on that path (-1 = skip)."""
        if k == n:
            base = np.full(1, 0.0)
            return base, []
        key = (k, bn, bl)
        hit = memo.get(key)
        if hit is not None:
            return hit
        rem = n - k
        vals = np.full(rem + 1, NEG)
        choice = [-1] * (rem + 1)
        sub, _ = solve(k + 1, dec(bn), dec(bl))
        vals[: len(sub)] = sub  # skip frame k
        for ai, (a, d, bmax) in enumerate(table):
            b = bn if a.kind == "npu" else bl
            if b > bmax:
                continue
            tgt = min(b + d, nb - 1)
            nbn, nbl = (tgt, bl) if a.kind == "npu" else (bn, tgt)
            sub, _ = solve(k + 1, dec(nbn), dec(nbl))
            for m in range(1, len(sub) + 1):
                if sub[m - 1] <= NEG / 2:
                    continue
                v = sub[m - 1] + a.acc
                if v > vals[m]:
                    vals[m] = v
                    choice[m] = ai
        memo[key] = (vals, choice)
        return vals, choice

    bn0 = min(max(int(np.ceil(max(npu_free, 0.0) / grid - 1e-12)), 0), nb - 1)
    vals, _ = solve(0, bn0, 0)
    window = n * gamma
    if alpha is None:
        m_star = int(np.argmax(vals))
    else:
        m_star, best_u = 0, 0.0
        for m in range(1, len(vals)):
            if vals[m] <= NEG / 2:
                continue
            u = m / window + alpha * vals[m] / m
            if u > best_u:
                m_star, best_u = m, u

    # Walk the chosen path, recomputing exact continuous-time stamps.
    decisions: list[Decision] = []
    bn, bl, m_left = bn0, 0, m_star
    npu_t, net_t = max(npu_free, 0.0), 0.0
    acc_sum, processed = 0.0, 0
    for k in range(n):
        arrival = k * gamma
        _, choice = solve(k, bn, bl)
        ai = choice[m_left] if m_left < len(choice) else -1
        if ai < 0:
            decisions.append(Decision(k, Where.SKIP))
            bn, bl = dec(bn), dec(bl)
            continue
        a, d, _ = table[ai]
        if a.kind == "npu":
            start = max(npu_t, arrival)
            finish = start + a.dur
            npu_t = finish
            where = Where.NPU
            tgt = min(bn + d, nb - 1)
            bn, bl = dec(tgt), dec(bl)
        else:
            start = max(net_t, arrival)
            finish = start + a.dur + a.tail
            net_t = start + a.dur
            where = Where.SERVER
            tgt = min(bl + d, nb - 1)
            bn, bl = dec(bn), dec(tgt)
        decisions.append(
            Decision(k, where, a.model, a.resolution, start=start, finish=finish)
        )
        acc_sum += a.acc
        processed += 1
        m_left -= 1
    utility = processed / window + (alpha * acc_sum / processed if processed else 0.0) if alpha is not None else 0.0
    return RoundPlan(
        decisions=decisions,
        horizon=n,
        expected_accuracy_sum=acc_sum,
        expected_utility=utility,
        npu_busy_until=npu_t,
        net_busy_until=net_t,
    )
