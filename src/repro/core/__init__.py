"""FastVA core: the paper's contribution — deadline-constrained scheduling of
video-analytics requests across a fast/low-precision local path ("NPU") and an
accurate/network-bound edge path.

Public surface:
  profiles    ModelProfile / StreamSpec / NetworkState / paper Table II presets
  registry    PolicySpec / register_policy — every policy, by name (front door)
  max_accuracy.plan_round     — §IV Algorithm 1
  max_utility.plan_round      — §V Algorithm 2
  baselines                   — Offload / Local / DeepDecision (§VI.C)
  brute_force                 — Optimal oracle (exhaustive + grid DP + policy)
  audit                       — backend-neutral plan-audit contract
  tracking                    — detect+track workload class (WorkloadSpec,
                                track_accuracy / track_fixed planners, oracle)
  simulator.simulate          — audited stream replay (reference loop)
  simulator.simulate_multi    — N streams, shared fluid uplink + server queue
  sim_batch.simulate_batch    — vectorized jit+vmap sweep backend
  sim_multi_batch.simulate_multi_batch — vectorized *fleet* backend
                                (interacting clients on device)
  edge_server                 — multi-tenant admission/bandwidth scheduler
  jax_sched                   — jitted lax implementations of both DPs
  controller.OnlineController — streaming controller w/ bandwidth estimation

Declarative scenario running (ScenarioSpec/Session) lives one level up in
``repro.session``.
"""
from . import (  # noqa: F401
    audit,
    baselines,
    brute_force,
    controller,
    edge_server,
    jax_sched,
    max_accuracy,
    max_utility,
    profiles,
    registry,
    schedule,
    sim_batch,
    sim_multi_batch,
    simulator,
    tracking,
)
from .sim_batch import BatchScenario, simulate_batch  # noqa: F401
from .sim_multi_batch import FleetScenario, simulate_multi_batch  # noqa: F401
from .controller import BandwidthEstimator, OnlineController  # noqa: F401
from .registry import (  # noqa: F401
    Param,
    PolicySpec,
    available_policies,
    get_policy,
    register_policy,
)
from .edge_server import EdgeClient, EdgeServerScheduler, make_fleet  # noqa: F401
from .profiles import (  # noqa: F401
    PAPER_MODELS,
    PAPER_STREAM,
    RESNET50,
    SQUEEZENET,
    ModelProfile,
    NetworkState,
    StreamSpec,
    network_mbps,
    profile_ms,
)
from .schedule import Decision, RoundPlan, StreamStats, Where  # noqa: F401
from .tracking import WorkloadSpec, exhaustive_track_best  # noqa: F401
from .simulator import (  # noqa: F401
    MultiStreamStats,
    Trace,
    make_policy,
    simulate,
    simulate_multi,
)
