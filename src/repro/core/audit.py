"""Backend-neutral plan-audit semantics — ONE definition of "what counts".

Every execution engine in this repo (the per-frame reference loops in
``simulator.py`` / ``session.Session.run_online`` and the vectorized
``sim_batch`` backend) must account a round plan identically, or the figures
stop being comparable across engines.  The contract, extracted verbatim from
the original ``simulate`` loop:

  1. ``horizon = max(plan.horizon, 1)`` frames are consumed per round.
  2. When ``strict``, the plan is validated (:func:`schedule.validate_plan`)
     with tolerance :data:`AUDIT_TOL`; each violating frame lands in the
     round's *bad set* (single-stream engines validate every decision,
     shared-link engines validate the NPU subset only — offloads are audited
     at actual completion instead).
  3. A processed decision contributes stats only when its frame is inside
     the plan horizon AND inside the stream (``head + frame < n_frames``)
     AND not in the bad set; NPU decisions score ``accuracy(r_max)``,
     server decisions ``accuracy(r)`` at the offloaded resolution.
  4. ``frames_missed_deadline`` grows by the bad-set size of every round —
     even for frames beyond the end of the stream (the plan was still
     infeasible there; a policy does not get audit amnesty for overrunning).
  5. Accuracy accumulates in decision order, round by round, in float64 —
     the batched backend reproduces this exact summation order so its stats
     are bit-identical, not approximately equal.

``sim_batch`` implements 1-5 as a fixed-shape tensor program; the golden
test in ``tests/test_sim_batch.py`` pins the two implementations together.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

from .profiles import ModelProfile, StreamSpec
from .schedule import RoundPlan, StreamStats, Where, validate_plan

__all__ = [
    "AUDIT_TOL",
    "TrackState",
    "apply_round",
    "apply_track_round",
    "audit_round",
]

# Feasibility tolerance (seconds) shared by every engine, batched included.
AUDIT_TOL = 1e-9


def audit_round(
    plan: RoundPlan,
    *,
    gamma: float,
    deadline: float,
    strict: bool = True,
    npu_only: bool = False,
) -> tuple[int, set[int]]:
    """Validate one round plan; return ``(horizon, bad_frames)``.

    ``npu_only=True`` restricts validation to NPU decisions — the
    shared-link engines (``simulate_multi``, ``run_online``) audit offloads
    at *actual* completion time instead of against the plan's own estimate.
    """
    horizon = max(plan.horizon, 1)
    if not strict:
        return horizon, set()
    audited = plan
    if npu_only:
        audited = RoundPlan(
            decisions=[d for d in plan.decisions if d.where is Where.NPU],
            horizon=horizon,
        )
    errors = validate_plan(audited, gamma=gamma, deadline=deadline, tol=AUDIT_TOL)
    return horizon, {e.frame for e in errors}


def apply_round(
    stats: StreamStats,
    plan: RoundPlan,
    *,
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    head: int,
    n_frames: int,
    horizon: int,
    bad_frames: set[int],
    on_offload: Callable[..., None] | None = None,
) -> None:
    """Account one audited round into ``stats`` (contract points 3-5 above).

    ``on_offload(decision, model)`` diverts SERVER decisions to the caller
    (shared-link engines hand them to the fluid uplink / true-trace replay);
    when it is ``None`` the offload is credited from the plan directly, as
    the single-stream reference simulator does.
    """
    for d in plan.decisions:
        if d.frame >= horizon or head + d.frame >= n_frames:
            continue
        if not d.is_processed():
            continue
        m = models[d.model]
        if d.where is Where.NPU:
            if d.frame in bad_frames:
                continue
            stats.frames_processed += 1
            stats.accuracy_sum += m.accuracy(stream.r_max, where="npu")
        elif on_offload is not None:
            on_offload(d, m)
        else:
            if d.frame in bad_frames:
                continue
            stats.frames_processed += 1
            stats.frames_offloaded += 1
            stats.accuracy_sum += m.accuracy(d.resolution, where="server")
    stats.frames_missed_deadline += len(bad_frames)


class TrackState(NamedTuple):
    """Detection-age state carried across rounds by the tracking workload.

    ``det_acc`` is the accuracy of the last successful detection and
    ``det_frame`` its absolute frame index (-1 before any detection, so a
    frame-0 detection is strictly newer than the initial state).  The zero
    initial accuracy makes pre-detection tracked frames score 0 with no
    special-casing (any age times ``det_acc = 0`` is 0).
    """

    det_acc: float = 0.0
    det_frame: int = -1


def apply_track_round(
    stats: StreamStats,
    plan: RoundPlan,
    *,
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    state: TrackState,
    head: int,
    n_frames: int,
    horizon: int,
    bad_frames: set[int],
    retention: float,
    on_offload: Callable[..., None] | None = None,
) -> TrackState:
    """Account one audited *tracking* round; return the new detection state.

    Tracking extension of the audit contract: a round carries at most one
    detection (the frame-0 decision) plus ``horizon`` tracker-carried
    frames.  Accounting order is detection first, then tracked frames in
    ascending frame order (the batched engines reproduce this summation
    order):

      * good detection — scores its fresh accuracy (processed, +offloaded
        for SERVER) and refreshes the state to ``(accuracy, head)``; the
        remaining ``horizon - 1`` frames track the *new* state;
      * bad detection (in the bad set) — counts in
        ``frames_missed_deadline`` via the bad set, the state is
        unchanged, and the head frame is neither scored nor tracked;
      * no detection (SKIP round) — every frame of the horizon, the head
        included, coasts on the stale state;
      * tracked frame ``f`` — always processed (the tracker is a cheap
        local op that cannot miss), scoring
        ``det_acc * retention ** (f - det_frame)``.

    ``on_offload(decision, model)`` diverts a SERVER detection to the
    shared-link engines; they score it — and refresh the state, guarded by
    detection recency — at *actual* upload completion, so this helper
    leaves the state untouched for that case.
    """
    det = next((d for d in plan.decisions if d.is_processed()), None)
    track_from = head + 1
    if det is None:
        track_from = head  # SKIP round: the tracker carries the head too
    elif det.frame in bad_frames:
        pass  # audited infeasible: missed via the bad set, state unchanged
    else:
        m = models[det.model]
        if det.where is Where.NPU:
            acc = m.accuracy(stream.r_max, where="npu")
            stats.frames_processed += 1
            stats.accuracy_sum += acc
            state = TrackState(acc, head)
        elif on_offload is not None:
            on_offload(det, m)  # scored + state-refreshed at completion
        else:
            acc = m.accuracy(det.resolution, where="server")
            stats.frames_processed += 1
            stats.frames_offloaded += 1
            stats.accuracy_sum += acc
            state = TrackState(acc, head)
    for f in range(track_from, min(head + horizon, n_frames)):
        stats.frames_processed += 1
        stats.accuracy_sum += state.det_acc * retention ** (f - state.det_frame)
    stats.frames_missed_deadline += len(bad_frames)
    return state
