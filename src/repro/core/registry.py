"""Policy registry: every scheduling policy is a first-class, named object.

The paper's two solvers (Max-Accuracy §IV, Max-Utility §V), the three §VI.C
baselines, the brute-force oracle, and the jitted ``jax_sched`` DPs all
register here with a declared parameter schema; callers construct them by
name through :class:`PolicySpec` instead of hand-wiring closures:

    spec = PolicySpec("max_utility", {"alpha": 200.0})
    policy = spec.build()          # simulator-ready plan_round callable
    spec2 = PolicySpec.from_json(spec.to_json())   # reproducible experiments

Parameter validation is strict by design: an unknown parameter, a missing
required one, or a wrong type raises ``ValueError`` at spec-construction
time — *before* any simulation runs — instead of being silently swallowed
the way the old ``make_policy(**kw)`` if-chain did.

Registration happens via decorators in the policy modules themselves::

    @register_policy("max_accuracy", params=(Param.number("grid", 1e-3),))
    def plan_round(models, stream, net, *, npu_free=0.0, grid=1e-3): ...

This module deliberately imports no policy module at top level (they import
us for the decorator); ``_ensure_builtins`` pulls them in lazily on first
lookup so the registry is always fully populated for by-name access.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

__all__ = [
    "Param",
    "PolicyEntry",
    "PolicySpec",
    "available_policies",
    "get_policy",
    "register_policy",
]

_REQUIRED = object()  # sentinel: parameter has no default and must be given


@dataclass(frozen=True)
class Param:
    """One declared policy parameter: name, accepted types, default.

    ``default is _REQUIRED`` marks the parameter mandatory.  ``nullable``
    parameters accept ``None`` (the baselines' mode switch: ``alpha=None``
    means accuracy mode, a float means utility mode).
    """

    name: str
    types: tuple[type, ...]
    default: Any = _REQUIRED
    nullable: bool = False
    doc: str = ""
    lo: Any = None  # inclusive lower bound (numeric params only)
    hi: Any = None  # inclusive upper bound (numeric params only)

    @property
    def required(self) -> bool:
        return self.default is _REQUIRED

    # -- constructors used at registration sites ---------------------------
    @staticmethod
    def number(
        name: str,
        default: Any = _REQUIRED,
        *,
        nullable: bool = False,
        doc: str = "",
        lo: Any = None,
        hi: Any = None,
    ) -> "Param":
        return Param(name, (float, int), default, nullable, doc, lo, hi)

    @staticmethod
    def integer(
        name: str,
        default: Any = _REQUIRED,
        *,
        nullable: bool = False,
        doc: str = "",
        lo: Any = None,
        hi: Any = None,
    ) -> "Param":
        return Param(name, (int,), default, nullable, doc, lo, hi)

    def check(self, policy: str, value: Any) -> Any:
        if value is None:
            if self.nullable:
                return None
            raise ValueError(
                f"policy {policy!r}: parameter {self.name!r} must not be None"
            )
        if not isinstance(value, self.types) or isinstance(value, bool):
            want = "/".join(t.__name__ for t in self.types)
            raise ValueError(
                f"policy {policy!r}: parameter {self.name!r} expects {want}, "
                f"got {type(value).__name__} ({value!r})"
            )
        if (self.lo is not None and value < self.lo) or (
            self.hi is not None and value > self.hi
        ):
            lo = "-inf" if self.lo is None else repr(self.lo)
            hi = "+inf" if self.hi is None else repr(self.hi)
            raise ValueError(
                f"policy {policy!r}: parameter {self.name!r} must be in "
                f"[{lo}, {hi}], got {value!r}"
            )
        return value


@dataclass(frozen=True)
class PolicyEntry:
    """A registered policy: the plan_round callable plus its parameter schema.

    ``batched=True`` declares that :mod:`repro.core.sim_batch` ships a
    vectorized (jit+vmap) implementation of this policy's round semantics,
    so ``Session.run_sweep`` may execute whole scenario grids on device.
    ``batched_multi=True`` declares the *multi-stream* capability: the
    policy's rounds can be executed for whole fleets of interacting clients
    (shared fluid uplink + edge-server queue) by a dedicated fleet planner
    in :mod:`repro.core.sim_multi_batch`.  Offloading planners (``offload``,
    ``max_accuracy``, ``max_utility``) vmap per-client planning over granted
    bandwidth and compose it with the water-filled shared link; local-only
    planners (``jax_accuracy``, ``jax_utility``) run one lane per scenario
    and replicate the identical client trajectory while counting the
    allocation gates exactly.  Policies without either flag always run
    through the reference Python loops.
    """

    name: str
    fn: Callable[..., Any]
    params: tuple[Param, ...] = ()
    doc: str = ""
    batched: bool = False
    batched_multi: bool = False
    #: ``batched_online=True`` promises an observe->replan->execute backend in
    #: :mod:`repro.core.sim_online_batch`: the EWMA estimator state is carried
    #: on device and re-planning happens against the *believed* network while
    #: execution is audited against the true trace, exactly like
    #: ``Session.run_online``.
    batched_online: bool = False
    #: workload kinds this policy can plan for.  Classification policies
    #: see independent frames; tracking policies (``workloads=("track",)``)
    #: plan a detector placement *and* a detector interval per round.
    workloads: tuple[str, ...] = ("classify",)

    def param(self, name: str) -> Param | None:
        for p in self.params:
            if p.name == name:
                return p
        return None

    def validate(self, given: Mapping[str, Any]) -> dict[str, Any]:
        """Return the full resolved kwargs dict, or raise ``ValueError``."""
        allowed = tuple(p.name for p in self.params)
        for k in given:
            if self.param(k) is None:
                raise ValueError(
                    f"policy {self.name!r} accepts no parameter {k!r}; "
                    f"allowed: {allowed or '(none)'}"
                )
        out: dict[str, Any] = {}
        for p in self.params:
            if p.name in given:
                out[p.name] = p.check(self.name, given[p.name])
            elif p.required:
                raise ValueError(
                    f"policy {self.name!r} requires parameter {p.name!r}"
                )
            else:
                out[p.name] = p.default
        return out


_REGISTRY: dict[str, PolicyEntry] = {}
_BUILTINS_LOADED = False


def register_policy(
    name: str,
    *,
    params: Sequence[Param] = (),
    doc: str = "",
    batched: bool = False,
    batched_multi: bool = False,
    batched_online: bool = False,
    workloads: Sequence[str] = ("classify",),
) -> Callable:
    """Decorator: register ``fn`` as policy ``name`` with a parameter schema.

    ``fn`` must follow the plan-round contract:
    ``fn(models, stream, net, *, npu_free, **params) -> RoundPlan``.
    ``batched=True`` additionally promises a matching vectorized backend in
    :mod:`repro.core.sim_batch`; ``batched_multi=True`` promises a fleet
    backend in :mod:`repro.core.sim_multi_batch` (both golden-tested
    against this ``fn`` through the reference simulators).
    """

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and _REGISTRY[name].fn is not fn:
            raise ValueError(f"policy {name!r} already registered")
        _REGISTRY[name] = PolicyEntry(
            name=name,
            fn=fn,
            params=tuple(params),
            doc=doc or (fn.__doc__ or "").strip(),
            batched=batched,
            batched_multi=batched_multi,
            batched_online=batched_online,
            workloads=tuple(workloads),
        )
        return fn

    return deco


def _ensure_builtins() -> None:
    """Import every module that registers built-in policies (idempotent)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from . import (  # noqa: F401
        baselines,
        brute_force,
        jax_sched,
        max_accuracy,
        max_utility,
        tracking,
    )


def get_policy(name: str) -> PolicyEntry:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: {available_policies()}"
        ) from None


def available_policies() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


@dataclass(frozen=True)
class PolicySpec:
    """A named policy plus validated parameters — serializable and buildable.

    Construction validates eagerly: ``PolicySpec("max_utility")`` raises
    (alpha is required), as does ``PolicySpec("max_accuracy", {"alpha": 1})``
    (max_accuracy declares no alpha).  ``resolved`` holds the full parameter
    dict with defaults filled in, so two specs that mean the same schedule
    compare equal even if one spelled out the defaults.
    """

    name: str
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        entry = get_policy(self.name)
        object.__setattr__(self, "params", dict(entry.validate(self.params)))

    def __hash__(self) -> int:  # params is a dict; hash its canonical items
        return hash((self.name, tuple(sorted(self.params.items()))))

    @property
    def resolved(self) -> dict[str, Any]:
        return dict(self.params)

    @staticmethod
    def coerce(
        policy: "PolicySpec | str | None",
        *,
        policy_name: str = "max_accuracy",
        alpha: float | None = None,
    ) -> "PolicySpec":
        """Normalize the constructor surface shared by every entry point:
        a ready spec passes through, a bare name becomes a spec, and ``None``
        folds the legacy ``policy_name``/``alpha`` pair into one."""
        if policy is None:
            params = {"alpha": alpha} if alpha is not None else {}
            return PolicySpec(policy_name, params)
        if isinstance(policy, str):
            return PolicySpec(policy)
        return policy

    def build(self):
        """Return a simulator-ready policy callable (the round closure)."""
        entry = get_policy(self.name)
        kw = dict(self.params)

        def policy(models, stream, net, *, npu_free: float = 0.0):
            return entry.fn(models, stream, net, npu_free=npu_free, **kw)

        policy.spec = self  # type: ignore[attr-defined]  # for introspection
        return policy

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict[str, Any]:
        return {"name": self.name, "params": dict(self.params)}

    @staticmethod
    def from_json(data: Mapping[str, Any] | str) -> "PolicySpec":
        if isinstance(data, str):
            data = json.loads(data)
        if not isinstance(data, Mapping) or "name" not in data:
            raise ValueError(f"not a PolicySpec payload: {data!r}")
        return PolicySpec(str(data["name"]), dict(data.get("params") or {}))
