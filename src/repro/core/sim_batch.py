"""Vectorized fleet-sweep backend: whole scenario grids as ONE tensor program.

The reference simulator (``simulator.simulate``) replays one stream at a
time in a Python event loop — every figure sweep pays interpreter cost per
frame per grid point.  This module executes and audits the same round plans
for a *batch* of scenarios (bandwidth × deadline × fps × policy-param grid
points) as a single jit+vmap program: per scenario, a ``lax.while_loop``
over scheduling rounds whose body (a) runs the policy's jitted DP
(:mod:`repro.core.jax_sched`), (b) backtracks the argmax schedule, and
(c) applies the shared audit contract of :mod:`repro.core.audit` — all on
device, returning per-scenario :class:`~repro.core.schedule.StreamStats`
tensors (accuracy sum, processed/missed counts, NPU occupancy).

Exactness contract (golden-tested in ``tests/test_sim_batch.py``): for every
scenario in the batch, the returned stats are **bit-identical** to
``simulate(PolicySpec(name, params).build(), ...)`` — same bin
discretization, same f32 DP recurrences, same f64 audit arithmetic in the
same order.  Three mechanisms make that possible:

  * every host-side quantity the reference computes in float64 (bin edges,
    arrival times, windows, f32 casts of policy params) is precomputed here
    with the identical numpy expressions;
  * the only round-coupled quantity, ``npu_free``, is carried on device in
    float64 — the module runs its programs inside ``jax.experimental
    .enable_x64`` and the DP kernels pin their own dtypes so the f32
    recurrences do not silently widen;
  * fixed shapes come from *padding*, never truncation: windows pad to the
    batch-max frame count ``W`` (padded frames are identity no-ops in the
    kernels) and the Max-Accuracy time grid pads to the batch-max bin count
    (padded bins provably stay ``NEG`` and cannot enter any argmax).

Policies registered with ``batched=True`` have a planner here; ``Session
.run_sweep`` falls back to the reference loop for everything else.  Two
planner families exist:

  * the local-plan jitted DPs ``jax_accuracy`` / ``jax_utility`` — their
    plans never offload, so ``frames_offloaded`` is always 0 and no network
    state is consulted;
  * the paper's own ``max_accuracy`` / ``max_utility`` heuristics — these
    are *network-aware*: each scenario carries an on-device network model
    (``BatchScenario.rtt`` plus piecewise-constant bandwidth segments),
    every round looks the bandwidth up at its start time exactly as the
    reference calls ``trace.at(t0)``, and the round program renders the
    offload phase (per-resolution upload times, feasible-server-model
    argmax, normalized-score candidate selection) as array expressions
    around the f64 DP twins of :mod:`repro.core.jax_sched`.  Segment
    arrays pad to the batch maximum with ``t_start = +inf`` sentinels,
    which a right-bisecting step lookup can provably never select.

Their equivalence scope differs: the jax_* planners are bit-identical to
the reference by construction (same f32 kernels), while the network-aware
planners replay float64 Python references — the certified contract is
integer stats exact and accuracy sums within :data:`~repro.core.audit
.AUDIT_TOL` (in practice the golden grids come out bit-equal too; see
docs/simulation.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .audit import AUDIT_TOL
from .bucketing import quant_bins as _quant_bins
from .bucketing import quant_pow2 as _quant_pow2
from .bucketing import quant_w as _quant_w
from .jax_sched import (
    NEG,
    _accuracy_dp,
    _accuracy_dp64,
    _no_fma,
    _utility_dp,
    _utility_dp64,
)
from .profiles import ModelProfile, StreamSpec
from .registry import get_policy
from .schedule import StreamStats
from .sweep_shard import LaneProgram
from .tracking import WorkloadSpec, interval_means, retention, retention_powers

__all__ = ["BatchScenario", "batched_policies", "simulate_batch"]


@dataclass(frozen=True)
class BatchScenario:
    """One grid point as the batched backend sees it: a stream shape, a frame
    budget, the policy's *resolved* parameter dict (defaults filled in, e.g.
    ``PolicySpec(...).resolved``), and the on-device network model.

    ``bw_segments`` is the piecewise-constant bandwidth trace as sorted
    ``(t_start_s, bandwidth_bps)`` segments — a constant trace is a single
    segment at ``t_start = 0``; before the first segment's start the first
    value applies (``simulator.Trace.piecewise`` semantics).  The local-only
    ``jax_*`` planners never consult the network; the network-aware
    ``max_accuracy`` / ``max_utility`` planners look bandwidth up at every
    round's start time.

    ``workload`` is the executor's world truth (``tracking.WorkloadSpec``):
    the ``track_*`` planners require ``kind="track"`` and score tracked
    frames with its decay curve; the classification planners require the
    default ``kind="classify"``."""

    stream: StreamSpec = field(default_factory=StreamSpec)
    n_frames: int = 120
    params: Mapping[str, Any] = field(default_factory=dict)
    rtt: float = 0.100
    bw_segments: tuple[tuple[float, float], ...] = ((0.0, 2.5e6),)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)


_PLANNERS: dict[str, Callable[..., list[StreamStats]]] = {}


def _planner(name: str):
    def deco(fn):
        _PLANNERS[name] = fn
        return fn

    return deco


def batched_policies() -> tuple[str, ...]:
    """Policy names this backend can execute (mirrors ``batched=True`` in the
    registry; ``tests/test_sim_batch.py`` asserts the two stay in sync)."""
    return tuple(sorted(_PLANNERS))


def simulate_batch(
    policy: str,
    models: Sequence[ModelProfile],
    scenarios: Sequence[BatchScenario],
    *,
    strict: bool = True,
) -> list[StreamStats]:
    """Run ``policy`` over every scenario in one compiled program.

    Returns one audited :class:`StreamStats` per scenario, in order,
    bit-identical to the reference ``simulate`` loop.  Raises ``ValueError``
    for policies without a batched planner — callers that want a silent
    fallback should route through ``Session.run_sweep`` instead.
    """
    fn = _PLANNERS.get(policy)
    if fn is None:
        raise ValueError(
            f"policy {policy!r} has no batched backend; available: {batched_policies()}"
        )
    entry = get_policy(policy)
    for s in scenarios:
        if s.workload.kind not in entry.workloads:
            raise ValueError(
                f"policy {policy!r} plans {'/'.join(entry.workloads)} workloads, "
                f"not {s.workload.kind!r}"
            )
    if not scenarios:
        return []
    return fn(list(models), list(scenarios), bool(strict))


# ---------------------------------------------------------------------------
# Shared host-side precomputation (float64 numpy — mirrors the reference
# wrappers in jax_sched expression by expression).
# ---------------------------------------------------------------------------


def _window_frames(stream: StreamSpec, params: Mapping[str, Any]) -> int:
    """Mirror of the plan-round wrappers' window choice."""
    wf = params.get("window_frames")
    if wf is not None:
        return int(wf)
    return max(int(np.floor(stream.deadline / stream.gamma)), 1)


# Scenario grouping: one monolithic batch would force every lane to pay the
# batch-max window, bin count, AND round count (a vmapped while_loop runs
# until the deepest lane finishes).  Scenarios are instead partitioned into
# shape-homogeneous groups keyed on *quantized* shapes — the shared
# bucketing policy lives in :mod:`repro.core.bucketing` (window ladder, bin
# quanta, pow2 pads; never-shrink/monotone/idempotent, hypothesis-tested) —
# which bounds in-group padding waste by ~2x while keeping the jit cache
# small and stable across sweeps AND making repeated sweeps hit the
# persistent compilation cache (see repro.core.compile_cache).  Padding is
# provably inert (see module docstring), so the partition cannot change any
# result — only wall-clock.


def _stitch(scenarios, key_fn, run_group) -> list[StreamStats]:
    """Partition ``scenarios`` by ``key_fn``, run each group, reassemble in
    the original order."""
    groups: dict[Any, list[int]] = {}
    for i, s in enumerate(scenarios):
        groups.setdefault(key_fn(s), []).append(i)
    stats: list[StreamStats | None] = [None] * len(scenarios)
    for key in sorted(groups):
        idx = groups[key]
        for i, st in zip(idx, run_group(key, [scenarios[i] for i in idx])):
            stats[i] = st
    return stats  # type: ignore[return-value]


@dataclass
class _Common:
    """Per-group arrays shared by both planners."""

    B: int
    J: int
    W: int  # padded window (quantized group maximum)
    n_active: np.ndarray  # [B] i32 real window per scenario
    gamma: np.ndarray  # [B] f64
    deadline: np.ndarray  # [B] f64
    n_frames: np.ndarray  # [B] i32
    arrivals: np.ndarray  # [B, W] f64, k * gamma
    t_npu64: np.ndarray  # [J] f64 (inf for server-only models)
    acc_dp32: np.ndarray  # [J] f32 — the DP's accuracy table (raw max key)
    acc_stat64: np.ndarray  # [B, J] f64 — audit accuracy at the stream's r_max


def _common(
    models: list[ModelProfile], scenarios: list[BatchScenario], W: int | None = None
) -> _Common:
    B, J = len(scenarios), len(models)
    n_active = np.array([_window_frames(s.stream, s.params) for s in scenarios], np.int32)
    W = int(n_active.max()) if W is None else int(W)
    gamma = np.array([s.stream.gamma for s in scenarios], np.float64)
    deadline = np.array([s.stream.deadline for s in scenarios], np.float64)
    n_frames = np.array([s.n_frames for s in scenarios], np.int32)
    arrivals = np.arange(W, dtype=np.float64)[None, :] * gamma[:, None]
    t_npu64 = np.array([m.t_npu for m in models], np.float64)
    acc_dp32 = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float32
    )
    acc_stat64 = np.array(
        [[m.accuracy(s.stream.r_max, where="npu") for m in models] for s in scenarios],
        np.float64,
    )
    return _Common(B, J, W, n_active, gamma, deadline, n_frames, arrivals,
                   t_npu64, acc_dp32, acc_stat64)


def _collect(
    c: _Common, out, wall_s: float, offloaded: np.ndarray | None = None
) -> list[StreamStats]:
    acc_sum, proc, miss, rounds, npu_busy = (np.asarray(a) for a in out)
    if offloaded is None:
        offloaded = np.zeros(c.B, np.int32)  # local-only planners never offload
    # The whole group schedules in one device program; apportion its wall
    # time by round count so schedule_time/schedule_calls stays the honest
    # amortized per-round cost (what figure rows report as us_per_call).
    total_rounds = max(int(rounds.sum()), 1)
    return [
        StreamStats(
            frames_total=int(c.n_frames[b]),
            frames_processed=int(proc[b]),
            frames_missed_deadline=int(miss[b]),
            frames_offloaded=int(offloaded[b]),
            accuracy_sum=float(acc_sum[b]),
            elapsed=float(c.n_frames[b] * c.gamma[b]),
            schedule_calls=int(rounds[b]),
            schedule_time=wall_s * float(rounds[b]) / total_rounds,
            npu_busy_s=float(npu_busy[b]),
        )
        for b in range(c.B)
    ]


def _audit_scan(*, head, n_frames, n_active, arrivals, deadline, t_npu64, acc_stat,
                picks, gate, free0, acc_sum, proc, miss, npu_s, W, J, strict,
                frame_offset=0):
    """On-device rendering of the :mod:`repro.core.audit` contract for the
    NPU frames of a round: sequential f64 fold over the (padded) window in
    frame order, so accuracy accumulates exactly as the reference loop's
    repeated ``+=``.  ``gate[k]`` says whether frame ``k`` really executes;
    ``frame_offset`` is the plan-frame id of DP frame 0 (1 when the round's
    head frame offloaded — the offload phase accounts it before this scan,
    preserving decision order)."""

    def au(carry, xs):
        free, a_s, pr, ms, nb = carry
        k, pick, act = xs
        j = jnp.clip(pick, 0, J - 1)
        arr_k = arrivals[k]
        start = jnp.maximum(free, arr_k)
        finish = start + t_npu64[j]
        if strict:
            bad = act & (finish > (arr_k + deadline) + AUDIT_TOL)
        else:
            bad = jnp.zeros_like(act)
        in_range = (head + frame_offset + k) < n_frames
        take = act & (~bad) & in_range
        a_s = a_s + jnp.where(take, acc_stat[j], 0.0)
        pr = pr + take.astype(jnp.int32)
        ms = ms + bad.astype(jnp.int32)  # missed counts even past-stream frames
        nb = nb + jnp.where(act, t_npu64[j], 0.0)
        free = jnp.where(act, finish, free)
        return (free, a_s, pr, ms, nb), None

    ks = jnp.arange(W, dtype=jnp.int32)
    carry, _ = jax.lax.scan(au, (free0, acc_sum, proc, miss, npu_s), (ks, picks, gate))
    return carry


# ---------------------------------------------------------------------------
# jax_accuracy: Max-Accuracy local DP over a (padded) time-bin grid.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _accuracy_program(W: int, NBINS: int, J: int, strict: bool):
    def one(gamma, deadline, grid, n_active, nbins_real, n_frames,
            arr_bins, dl_bins, dur, arrivals, acc_stat, t_npu64, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s = c
            active = head < n_frames  # lane gating under vmap-of-while
            t0 = head.astype(jnp.float64) * gamma
            npu_free = jnp.maximum(0.0, busy - t0)
            # Reference: int(np.ceil(max(npu_free, 0.0) / grid)), clipped to
            # the scenario's REAL bin count (not the padded one) — the clip
            # target is observable when npu_free overruns the horizon.
            start_bin = jnp.ceil(jnp.maximum(npu_free, 0.0) / grid).astype(jnp.int32)
            start_bin = jnp.clip(start_bin, 0, nbins_real - 1)
            H, choices, parents = _accuracy_dp(
                dur, acc_dp32, arr_bins, dl_bins, start_bin, n_active,
                n_frames=W, nbins=NBINS,
            )
            feasible = jnp.max(H) > NEG / 2
            b0 = jnp.argmax(H).astype(jnp.int32)

            def bt(b, k):
                bc = jnp.clip(b, 0, NBINS - 1)
                pick = choices[k, bc]
                return jnp.where(pick >= 0, parents[k, bc], b), pick

            _, picks_rev = jax.lax.scan(
                bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & feasible & (jnp.arange(W, dtype=jnp.int32) < n_active)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            # Infeasible window: the reference emits a horizon-1 SKIP round
            # that leaves the NPU carry untouched.
            busy_until = jnp.where(feasible, free_end, npu_free)
            horizon = jnp.where(feasible, n_active, 1)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6]

    return LaneProgram(one, (0,) * 11 + (None,) * 2)


@_planner("jax_accuracy")
def _run_accuracy(models, scenarios, strict):
    def run_group(W, group):
        c = _common(models, group, W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        # Bin arithmetic in f64 on the host — the same numpy expressions as
        # local_accuracy_dp_jax, vectorized over the batch.
        arr_bins = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl_bins = np.floor((c.arrivals + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        horizon_t = (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        nbins_real = (np.ceil(horizon_t / grid) + 2).astype(np.int32)
        NBINS = _quant_bins(int(nbins_real.max()))
        # inf (server-only) and over-horizon durations clamp to NBINS: both
        # are unreachable in-bin exactly as the reference's raw values are.
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        t0 = time.perf_counter()
        with enable_x64():
            out = _accuracy_program(c.W, NBINS, c.J, strict)(
                c.gamma, c.deadline, grid, c.n_active, nbins_real, c.n_frames,
                arr_bins, dl_bins, dur, c.arrivals, c.acc_stat64,
                c.t_npu64, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out, time.perf_counter() - t0)

    return _stitch(
        scenarios, lambda s: _quant_w(_window_frames(s.stream, s.params)), run_group
    )


# ---------------------------------------------------------------------------
# jax_utility: Max-Utility Pareto-front DP (skips allowed).
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _utility_program(W: int, width: int, J: int, strict: bool):
    def one(gamma, deadline, n_active, n_frames, g32, d32, a32, w32,
            arrivals, acc_stat, t_npu64, t_npu32, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s = c
            active = head < n_frames
            t0 = head.astype(jnp.float64) * gamma
            npu_free = jnp.maximum(0.0, busy - t0)
            (_, u, _, _), parents, actions, _ = _utility_dp(
                t_npu32, acc_dp32, n_active,
                n_frames=W, width=width, gamma=g32, deadline=d32, alpha=a32,
                npu_free=npu_free.astype(jnp.float32),
                first_arrival=jnp.float32(0.0), window=w32,
            )
            slot0 = jnp.argmax(u).astype(jnp.int32)

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & (picks >= 0)  # only picked frames execute; rest SKIP
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            head = jnp.where(active, head + n_active, head)  # horizon is always n
            busy = jnp.where(active, t0 + free_end, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6]

    return LaneProgram(one, (0,) * 10 + (None,) * 3)


@_planner("jax_utility")
def _run_utility(models, scenarios, strict):
    # ``width`` is a compiled Pareto-front shape, so it joins the group key
    # (a width axis in a sweep grid costs one compile per distinct value).
    def run_group(key, group):
        W, width = key
        c = _common(models, group, W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        # The f32 casts the reference wrapper performs, precomputed in bulk.
        g32 = c.gamma.astype(np.float32)
        d32 = c.deadline.astype(np.float32)
        a32 = alpha.astype(np.float32)
        window = np.maximum(c.n_active.astype(np.float64) * c.gamma, c.gamma)
        w32 = window.astype(np.float32)
        t_npu32 = c.t_npu64.astype(np.float32)
        t0 = time.perf_counter()
        with enable_x64():
            out = _utility_program(c.W, width, c.J, strict)(
                c.gamma, c.deadline, c.n_active, c.n_frames,
                g32, d32, a32, w32, c.arrivals, c.acc_stat64,
                c.t_npu64, t_npu32, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out, time.perf_counter() - t0)

    return _stitch(
        scenarios,
        lambda s: (_quant_w(_window_frames(s.stream, s.params)), int(s.params["width"])),
        run_group,
    )


# ---------------------------------------------------------------------------
# Network-aware planners: the paper's Max-Accuracy / Max-Utility heuristics.
# Each round is the reference plan_round rendered as array expressions —
# bandwidth looked up at the round's start time, per-resolution upload
# times, feasible-server-model argmax, the f64 local-phase DP twins of
# jax_sched, and candidate selection on the reference's normalized scores —
# followed by the shared audit fold.  Host-side precomputation mirrors the
# reference expression by expression (frame bits, accuracy tables, bin
# edges), all in float64.
# ---------------------------------------------------------------------------

# max_utility._prune's cap: the width at which _utility_dp64's truncation
# coincides with the reference.  The planner first runs a narrow FAST width
# (the Pareto sort dominates kernel cost and scales ~width·log(width); real
# fronts hold a few dozen entries) and reruns only the lanes whose overflow
# flag reports a front outgrew it — exactness is never traded for speed.
_UTIL_CAP = 256
_UTIL_FAST_WIDTH = 64


def _trace_bw(bw_t: jax.Array, bw_v: jax.Array, t: jax.Array) -> jax.Array:
    """Bandwidth at time ``t``: the step function ``Trace.piecewise``
    defines — the last segment with ``t_start <= t`` wins, and before the
    first segment's start the first value applies.  Padded sentinel
    segments carry ``t_start = +inf``, so the right-bisection can provably
    never select them (any finite ``t`` bisects before every ``inf``)."""
    idx = jnp.searchsorted(bw_t, t, side="right") - 1
    return bw_v[jnp.clip(idx, 0, bw_t.shape[0] - 1)]


def segment_arrays(
    segs_list: Sequence[Sequence[tuple[float, float]]],
) -> tuple[np.ndarray, np.ndarray, int]:
    """Pad per-scenario ``(t_start, bps)`` segment lists into [B, S] tensors.

    The single definition of the on-device trace layout, shared with the
    fleet engine (``sim_multi_batch``): segments sort like
    ``Trace.piecewise``, S pads to the batch's power-of-two maximum, and
    sentinel entries carry ``t_start = +inf`` (never selectable by
    ``_trace_bw``'s right bisection) with the last real value repeated.
    """
    B = len(segs_list)
    clean = [
        sorted((float(t), float(v)) for t, v in segs) or [(0.0, 0.0)]
        for segs in segs_list
    ]
    S = _quant_pow2(max(len(segs) for segs in clean))
    bw_t = np.full((B, S), np.inf, np.float64)
    bw_v = np.zeros((B, S), np.float64)
    for i, segs in enumerate(clean):
        bw_t[i, : len(segs)] = [t for t, _ in segs]
        bw_v[i, : len(segs)] = [v for _, v in segs]
        bw_v[i, len(segs):] = segs[-1][1]
    return bw_t, bw_v, S


def _net_arrays(group: list[BatchScenario]) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Per-scenario network tensors: rtt [B] plus the padded segment
    tensors of :func:`segment_arrays`."""
    bw_t, bw_v, S = segment_arrays([s.bw_segments for s in group])
    rtt = np.array([s.rtt for s in group], np.float64)
    return rtt, bw_t, bw_v, S


def _offload_tables(
    models: list[ModelProfile], group: list[BatchScenario]
) -> tuple[np.ndarray, np.ndarray]:
    """Host-precomputed offload tables: frame payload bits [B, R] (the exact
    ``frame_bytes(r) * 8.0`` the reference feeds ``upload_time``) and server
    accuracy [B, J, R] at each scenario's offered resolutions."""
    nbits8 = np.array(
        [[s.stream.frame_bytes(r) * 8.0 for r in s.stream.resolutions] for s in group],
        np.float64,
    )
    acc_sv = np.array(
        [
            [[m.accuracy(r, where="server") for r in s.stream.resolutions] for m in models]
            for s in group
        ],
        np.float64,
    )
    return nbits8, acc_sv


def _net_group_key(s: BatchScenario) -> tuple[int, int]:
    return (_quant_w(_window_frames(s.stream, s.params)), len(s.stream.resolutions))


@lru_cache(maxsize=None)
def _max_accuracy_program(W: int, NBINS: int, S: int, J: int, R: int, strict: bool):
    def one(gamma, deadline, rtt, grid, n_active, n_frames,
            arr0, dl0, arr1, dl1, dur, arrivals, acc_stat,
            nbits8, acc_sv, bw_t, bw_v, t_srv, acc_dp, t_npu64):
        ks = jnp.arange(W, dtype=jnp.int32)

        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, offl, rounds, npu_s = c
            active = head < n_frames
            rounded = n_frames > 0  # traced, always true: _no_fma's gate
            t0 = _no_fma(head.astype(jnp.float64) * gamma, rounded)
            npu_free = jnp.maximum(0.0, busy - t0)
            start_bin = jnp.ceil(jnp.maximum(npu_free, 0.0) / grid).astype(jnp.int32)
            bw0 = _trace_bw(bw_t, bw_v, t0)  # the reference's trace.at(t0)
            t_up = jnp.where(bw0 > 0.0, nbits8 / bw0, jnp.inf)  # [R]
            budget = deadline - t_up - rtt  # [R]
            fits = t_srv[:, None] <= budget[None, :]  # [J, R]
            a_cand = jnp.where(fits, acc_sv, -jnp.inf)
            j_best = jnp.argmax(a_cand, axis=0).astype(jnp.int32)  # first max
            a_best = jnp.max(a_cand, axis=0)
            r_ok = (budget > 0.0) & jnp.any(fits, axis=0)
            n_l = jnp.floor(jnp.where(r_ok, t_up, 0.0) / gamma)
            n_l = jnp.clip(n_l, 0, W).astype(jnp.int32)  # [R]
            cho1, par1, mh1, ab1, alive1 = _accuracy_dp64(
                dur, acc_dp, arr1, dl1, start_bin, n_frames=W, nbins=NBINS
            )
            nlm1 = jnp.clip(n_l - 1, 0, W - 1)
            # The reference sizes each DP instance at ceil(horizon/grid)+2
            # bins and declares start_bin >= nbins infeasible; rebuild that
            # per-candidate bound from the shared prefix scan.
            nb1 = jnp.ceil(
                (gamma + _no_fma((n_l.astype(jnp.float64) - 1.0) * gamma, rounded)
                 + deadline) / grid
            ).astype(jnp.int32) + 2
            dp_ok = jnp.where(n_l == 0, True, alive1[nlm1] & (start_bin < nb1))
            dp_tot = jnp.where(n_l == 0, 0.0, mh1[nlm1])
            feas = r_ok & dp_ok
            norm = jnp.where(feas, (a_best + dp_tot) / (n_l + 1).astype(jnp.float64), NEG)
            r_star = jnp.argmax(norm).astype(jnp.int32)  # first max = lowest r
            off_exists = feas[r_star]
            off_norm = norm[r_star]

            cho0, par0, mh0, ab0, alive0 = _accuracy_dp64(
                dur, acc_dp, arr0, dl0, start_bin, n_frames=W, nbins=NBINS
            )
            # local_window_plan tries nn = n..1 and keeps the first feasible;
            # aliveness is prefix-monotone, so that is the leading-alive
            # count (and the start_bin bound only loosens as nn grows).
            A = jnp.sum((alive0 & (ks < n_active)).astype(jnp.int32), dtype=jnp.int32)
            nb0 = jnp.ceil(
                (_no_fma((A.astype(jnp.float64) - 1.0) * gamma, rounded) + deadline)
                / grid
            ).astype(jnp.int32) + 2
            loc_exists = (A >= 1) & (start_bin < nb0)
            loc_norm = jnp.where(
                loc_exists, mh0[jnp.clip(A - 1, 0, W - 1)] / A.astype(jnp.float64), NEG
            )
            use_loc = loc_exists & (loc_norm > jnp.where(off_exists, off_norm, NEG))
            use_off = off_exists & ~use_loc

            nn = jnp.where(use_off, n_l[r_star], jnp.where(use_loc, A, 0))

            # Backtrack both DPs on [W] vectors (a second cheap scan beats
            # materializing a [W, NBINS] select of the winner's tables).
            def backtrack(cho, par, b0, upto):
                def bt(b, k):
                    on = k < upto  # prefix records: frames past upto not ours
                    bc = jnp.clip(b, 0, NBINS - 1)
                    pick = jnp.where(on, cho[k, bc], -1)
                    return jnp.where(on & (pick >= 0), par[k, bc], b), pick

                _, picks_rev = jax.lax.scan(
                    bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
                )
                return picks_rev[::-1]

            picks_off = backtrack(cho1, par1, ab1[nlm1[r_star]], jnp.where(use_off, nn, 0))
            picks_loc = backtrack(cho0, par0, ab0[jnp.clip(A - 1, 0, W - 1)],
                                  jnp.where(use_loc, nn, 0))
            picks = jnp.where(use_off, picks_off, picks_loc)

            # Head-frame offload first: decision order is SERVER, then NPUs.
            srv_fin = (t_up[r_star] + rtt) + t_srv[j_best[r_star]]
            if strict:
                srv_bad = use_off & (srv_fin > deadline + AUDIT_TOL)
            else:
                srv_bad = jnp.bool_(False)
            srv_take = active & use_off & ~srv_bad
            acc_sum = acc_sum + jnp.where(srv_take, acc_sv[j_best[r_star], r_star], 0.0)
            proc = proc + srv_take.astype(jnp.int32)
            offl = offl + srv_take.astype(jnp.int32)
            miss = miss + (active & srv_bad).astype(jnp.int32)

            fa = jnp.where(use_off, gamma, 0.0)
            gate = active & (picks >= 0) & (ks < nn)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, frame_offset=jnp.where(use_off, 1, 0),
                n_frames=n_frames, n_active=n_active, arrivals=fa + arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                picks=picks, gate=gate, free0=free0, acc_sum=acc_sum,
                proc=proc, miss=miss, npu_s=npu_s, W=W, J=J, strict=strict,
            )
            busy_until = jnp.where(use_off | use_loc, free_end, npu_free)
            horizon = jnp.where(
                use_off, n_l[r_star] + 1, jnp.where(use_loc, A, 1)
            ).astype(jnp.int32)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = rounds + active.astype(jnp.int32)
            return head, busy, acc_sum, proc, miss, offl, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[6], out[7], out[5]

    return LaneProgram(one, (0,) * 17 + (None,) * 3)


@_planner("max_accuracy")
def _run_max_accuracy(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        W, R = key
        c = _common(models, group, W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        # Bin arithmetic in f64 on the host — the same numpy expressions as
        # max_accuracy.local_dp, for both first_arrival values (0: the pure
        # local window; gamma: the frames buffered behind an offload).
        arr0 = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl0 = np.floor((c.arrivals + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        arrivals1 = c.gamma[:, None] + c.arrivals
        arr1 = np.ceil(arrivals1 / grid[:, None]).astype(np.int32)
        dl1 = np.floor((arrivals1 + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        horizon_t = c.gamma + (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        NBINS = _quant_bins(int((np.ceil(horizon_t / grid) + 2).max()))
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        rtt, bw_t, bw_v, S = _net_arrays(group)
        nbits8, acc_sv = _offload_tables(models, group)
        t0 = time.perf_counter()
        with enable_x64():
            out = _max_accuracy_program(c.W, NBINS, S, c.J, R, strict)(
                c.gamma, c.deadline, rtt, grid, c.n_active, c.n_frames,
                arr0, dl0, arr1, dl1, dur, c.arrivals, c.acc_stat64,
                nbits8, acc_sv, bw_t, bw_v, t_srv, acc_dp, c.t_npu64,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out[:5], time.perf_counter() - t0, offloaded=out[5])

    return _stitch(scenarios, _net_group_key, run_group)


# ---------------------------------------------------------------------------
# Detect+track planners (tracking.py): no bin DP — candidate scoring is
# closed-form (fresh accuracy times a host-precomputed interval mean), so
# the whole round is a handful of array expressions plus a short sequential
# fold over the tracked frames.  One program serves both policies; ``fixed``
# is a compile-time flag (track_fixed scores raw accuracy and always
# consumes ``k`` frames, track_accuracy scores interval means and lets the
# winning candidate set the horizon).  Decay tables (``retention_powers`` /
# ``interval_means``) are computed on the host with the same Python
# arithmetic the reference planners use, so every product on device
# multiplies the identical float64 constants.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _track_program(S: int, J: int, R: int, KQ: int, A: int, strict: bool, fixed: bool):
    def one(gamma, deadline, rtt, n_frames, k_lim, im, ret_pow,
            acc_stat, nbits8, acc_sv, bw_t, bw_v, t_srv, t_npu64):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, det_acc, det_frm, acc_sum, proc, miss, offl, rounds, npu_s = c
            active = head < n_frames
            rounded = n_frames > 0  # traced, always true: _no_fma's gate
            t0 = _no_fma(head.astype(jnp.float64) * gamma, rounded)
            npu_free = jnp.maximum(0.0, busy - t0)
            # NPU candidates: j ascending (the concat order below).
            local = jnp.isfinite(t_npu64)
            kf = jnp.where(local, jnp.ceil(t_npu64 / gamma), 0.0)
            k_npu = jnp.maximum(kf.astype(jnp.int32), 1)  # [J] npu_interval
            feas_npu = local & (npu_free + t_npu64 <= deadline) & (k_npu <= k_lim)
            # Offload candidates: the reference's _server_candidates, r asc.
            bw0 = _trace_bw(bw_t, bw_v, t0)
            t_up = jnp.where(bw0 > 0.0, nbits8 / bw0, jnp.inf)  # [R]
            budget = deadline - t_up - rtt  # [R]
            fits = t_srv[:, None] <= budget[None, :]  # [J, R]
            a_cand = jnp.where(fits, acc_sv, -jnp.inf)
            j_best = jnp.argmax(a_cand, axis=0).astype(jnp.int32)  # first max
            a_best = jnp.max(a_cand, axis=0)
            r_ok = (budget > 0.0) & jnp.any(fits, axis=0)
            k_srv = jnp.floor(jnp.where(r_ok, t_up, 0.0) / gamma).astype(jnp.int32) + 1
            feas_srv = r_ok & (k_srv <= k_lim)
            if fixed:
                s_npu = jnp.where(feas_npu, acc_stat, -jnp.inf)
                s_srv = jnp.where(feas_srv, a_best, -jnp.inf)
            else:
                s_npu = jnp.where(
                    feas_npu, acc_stat * im[jnp.clip(k_npu - 1, 0, KQ - 1)], -jnp.inf
                )
                s_srv = jnp.where(
                    feas_srv, a_best * im[jnp.clip(k_srv - 1, 0, KQ - 1)], -jnp.inf
                )
            # NPU-then-server candidate order with strict > first-wins is
            # exactly a first-maximum argmax over the concatenation (real
            # scores are >= 0, so -inf marks infeasible unambiguously).
            scores = jnp.concatenate([s_npu, s_srv])
            idx = jnp.argmax(scores).astype(jnp.int32)
            exists = scores[idx] > -jnp.inf
            det_npu = exists & (idx < J)
            j_pick = jnp.clip(idx, 0, J - 1)
            r_pick = jnp.clip(idx - J, 0, R - 1)
            d_acc = jnp.where(det_npu, acc_stat[j_pick], a_best[r_pick])
            k_det = jnp.where(det_npu, k_npu[j_pick], k_srv[r_pick])
            if fixed:
                horizon = k_lim  # the interval is consumed even on SKIP
            else:
                horizon = jnp.where(exists, k_det, 1)
            fin_npu = npu_free + t_npu64[j_pick]
            fin_srv = (t_up[r_pick] + rtt) + t_srv[j_best[r_pick]]
            fin = jnp.where(det_npu, fin_npu, fin_srv)
            if strict:
                bad = exists & (fin > deadline + AUDIT_TOL)
            else:
                bad = jnp.bool_(False)
            # Detection first (audit order), then tracked frames ascending.
            take = active & exists & ~bad
            acc_sum = acc_sum + jnp.where(take, d_acc, 0.0)
            proc = proc + take.astype(jnp.int32)
            offl = offl + (take & ~det_npu).astype(jnp.int32)
            miss = miss + (active & bad).astype(jnp.int32)
            det_acc = jnp.where(take, d_acc, det_acc)
            det_frm = jnp.where(take, head, det_frm)
            off0 = jnp.where(exists, 1, 0)  # SKIP tracks the head frame too

            def tr(o, carry):
                a_s, pr = carry
                on = active & (o >= off0) & (o < horizon) & (head + o < n_frames)
                age = jnp.clip(head + o - det_frm, 0, A - 1)
                v = _no_fma(det_acc * ret_pow[age], rounded)
                return a_s + jnp.where(on, v, 0.0), pr + on.astype(jnp.int32)

            acc_sum, proc = jax.lax.fori_loop(0, KQ, tr, (acc_sum, proc))
            npu_s = npu_s + jnp.where(active & det_npu, t_npu64[j_pick], 0.0)
            busy_until = jnp.where(det_npu, fin_npu, npu_free)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = rounds + active.astype(jnp.int32)
            return head, busy, det_acc, det_frm, acc_sum, proc, miss, offl, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.full((), -1, jnp.int32),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[4], out[5], out[6], out[8], out[9], out[7]

    return LaneProgram(one, (0,) * 12 + (None,) * 2)


def _run_track(models, scenarios, strict, *, fixed: bool):
    t_srv = np.array([m.t_server for m in models], np.float64)
    kname = "k" if fixed else "k_max"

    def key_fn(s):
        # KQ bounds the horizon (and the tracked-frame fold length); A sizes
        # the retention table — ages reach n_frames with the -1 initial state.
        return (_quant_w(int(s.params[kname])), len(s.stream.resolutions),
                _quant_pow2(s.n_frames + 1))

    def run_group(key, group):
        KQ, R, A = key
        c = _common(models, group, W=1)  # windows are a classify concept
        B = len(group)
        k_lim = np.array([int(s.params[kname]) for s in group], np.int32)
        im = np.zeros((B, KQ), np.float64)
        if not fixed:
            # interval_means is prefix-stable, so padding KQ past a lane's
            # k_max cannot change any entry the planner may select.
            for i, s in enumerate(group):
                ret_b = retention(float(s.params["decay"]), float(s.params["density"]))
                im[i, :] = interval_means(ret_b, KQ)
        ret_pow = np.empty((B, A), np.float64)
        for i, s in enumerate(group):
            ret_pow[i, :] = retention_powers(s.workload.retention, A)
        rtt, bw_t, bw_v, S = _net_arrays(group)
        nbits8, acc_sv = _offload_tables(models, group)
        t0 = time.perf_counter()
        with enable_x64():
            out = _track_program(S, c.J, R, KQ, A, strict, fixed)(
                c.gamma, c.deadline, rtt, c.n_frames, k_lim, im, ret_pow,
                c.acc_stat64, nbits8, acc_sv, bw_t, bw_v, t_srv, c.t_npu64,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out[:5], time.perf_counter() - t0, offloaded=out[5])

    return _stitch(scenarios, key_fn, run_group)


@_planner("track_accuracy")
def _run_track_accuracy(models, scenarios, strict):
    return _run_track(models, scenarios, strict, fixed=False)


@_planner("track_fixed")
def _run_track_fixed(models, scenarios, strict):
    return _run_track(models, scenarios, strict, fixed=True)


@lru_cache(maxsize=None)
def _max_utility_program(W: int, S: int, J: int, R: int, strict: bool, width: int):
    def one(gamma, deadline, rtt, alpha, fps, n_w, n_frames, arrivals, acc_stat,
            nbits8, acc_sv, bw_t, bw_v, t_srv, acc_dp, t_npu64):
        ks = jnp.arange(W, dtype=jnp.int32)

        def backtrack(u_final, parents, actions):
            slot0 = jnp.argmax(u_final).astype(jnp.int32)  # first max = front order

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            return picks_rev[::-1]

        def cand_stats(picks, acc0):
            # _round_utility's decision-order f64 fold; the head offload's
            # server accuracy seeds acc0 so the summation order matches.
            def f(carry, pick):
                n, a = carry
                takes = pick >= 0
                j = jnp.clip(pick, 0, J - 1)
                return (
                    n + takes.astype(jnp.int32),
                    a + jnp.where(takes, acc_stat[j], 0.0),
                ), None

            (n, a), _ = jax.lax.scan(f, (jnp.int32(0), acc0), picks)
            return n, a

        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, offl, rounds, npu_s, ovf = c
            active = head < n_frames
            rounded = n_frames > 0  # traced, always true: _no_fma's gate
            t0 = _no_fma(head.astype(jnp.float64) * gamma, rounded)
            npu_free = jnp.maximum(0.0, busy - t0)
            bw0 = _trace_bw(bw_t, bw_v, t0)
            t_up = jnp.where(bw0 > 0.0, nbits8 / bw0, jnp.inf)  # [R]
            # Offload phase: argmax_{j,r} capped-rate + alpha * a(j, r); the
            # reference iterates r-outer/j-inner with strict >, so the first
            # maximum over the r-major flattening wins ties identically.
            feas = (t_up[:, None] + t_srv[None, :] + rtt) <= deadline  # [R, J]
            rate = jnp.minimum(1.0 / jnp.maximum(t_up, 1e-9), fps)
            score = rate[:, None] + _no_fma(
                alpha * jnp.swapaxes(acc_sv, 0, 1), rounded
            )  # [R, J]
            flat = jnp.where(feas, score, -jnp.inf).reshape(-1)
            off_exists = jnp.any(feas)
            pick_rj = jnp.argmax(flat).astype(jnp.int32)
            r0 = pick_rj // J
            j0 = pick_rj - r0 * J
            t_up0 = jnp.where(off_exists, t_up[r0], 0.0)
            n_l = jnp.clip(jnp.floor(t_up0 / gamma), 0, W).astype(jnp.int32)
            n_plan = jnp.maximum(n_l, n_w - 1)
            win1 = jnp.maximum(jnp.maximum(n_plan, 1).astype(jnp.float64) * gamma, gamma)
            (_, u1, _, _), par1, act1, ov1 = _utility_dp64(
                t_npu64, acc_dp, n_plan, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=gamma, window=win1,
            )
            win2 = jnp.maximum(n_w.astype(jnp.float64) * gamma, gamma)
            (_, u2, _, _), par2, act2, ov2 = _utility_dp64(
                t_npu64, acc_dp, n_w, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=jnp.float64(0.0), window=win2,
            )
            ovf = ovf | (active & (ov1 | ov2))
            picks1 = backtrack(u1, par1, act1)
            picks2 = backtrack(u2, par2, act2)
            srv_acc = acc_sv[j0, r0]
            n1, a_off = cand_stats(picks1, srv_acc)  # server acc accumulates first
            n2, a_loc = cand_stats(picks2, jnp.float64(0.0))
            # The true round objective (_round_utility) for both candidates.
            p_off = (n1 + 1).astype(jnp.float64)
            h_off = jnp.maximum(n_plan + 1, 1).astype(jnp.float64)
            u_off = jnp.where(
                off_exists, p_off / (h_off * gamma) + alpha * a_off / p_off, NEG
            )
            u_loc = jnp.where(
                n2 > 0,
                n2.astype(jnp.float64) / (n_w.astype(jnp.float64) * gamma)
                + alpha * a_loc / n2.astype(jnp.float64),
                0.0,
            )
            use_off = off_exists & (u_off >= u_loc)  # first candidate wins ties
            use_loc = ~use_off & (n2 > 0)

            nn = jnp.where(use_off, n_plan, jnp.where(use_loc, n_w, 0))
            picks = jnp.where(use_off, picks1, picks2)
            srv_fin = (t_up0 + rtt) + t_srv[jnp.clip(j0, 0, J - 1)]
            if strict:
                srv_bad = use_off & (srv_fin > deadline + AUDIT_TOL)
            else:
                srv_bad = jnp.bool_(False)
            srv_take = active & use_off & ~srv_bad
            acc_sum = acc_sum + jnp.where(srv_take, srv_acc, 0.0)
            proc = proc + srv_take.astype(jnp.int32)
            offl = offl + srv_take.astype(jnp.int32)
            miss = miss + (active & srv_bad).astype(jnp.int32)

            fa = jnp.where(use_off, gamma, 0.0)
            gate = active & (picks >= 0) & (ks < nn)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, frame_offset=jnp.where(use_off, 1, 0),
                n_frames=n_frames, n_active=n_w, arrivals=fa + arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                picks=picks, gate=gate, free0=free0, acc_sum=acc_sum,
                proc=proc, miss=miss, npu_s=npu_s, W=W, J=J, strict=strict,
            )
            busy_until = jnp.where(use_off | use_loc, free_end, npu_free)
            horizon = jnp.where(
                use_off, n_plan + 1, jnp.where(use_loc, n_w, 1)
            ).astype(jnp.int32)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = rounds + active.astype(jnp.int32)
            return head, busy, acc_sum, proc, miss, offl, rounds, npu_s, ovf

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), bool),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[6], out[7], out[5], out[8]

    return LaneProgram(one, (0,) * 13 + (None,) * 3)


@_planner("max_utility")
def _run_max_utility(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        W, R = key
        c = _common(models, group, W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        fps = np.array([s.stream.fps for s in group], np.float64)
        rtt, bw_t, bw_v, S = _net_arrays(group)
        nbits8, acc_sv = _offload_tables(models, group)
        lane_args = (c.gamma, c.deadline, rtt, alpha, fps, c.n_active, c.n_frames,
                     c.arrivals, c.acc_stat64, nbits8, acc_sv, bw_t, bw_v)
        t0 = time.perf_counter()
        with enable_x64():
            out = _max_utility_program(c.W, S, c.J, R, strict, _UTIL_FAST_WIDTH)(
                *lane_args, t_srv, acc_dp, c.t_npu64,
            )
            out = [np.array(a) for a in out]
            overflowed = np.nonzero(out[6])[0]
            if overflowed.size:
                # A front outgrew the fast width somewhere in these lanes:
                # rerun just them at the reference prune cap (exact for any
                # front size) and splice the results back in.
                sub = _max_utility_program(c.W, S, c.J, R, strict, _UTIL_CAP)(
                    *(a[overflowed] for a in lane_args), t_srv, acc_dp, c.t_npu64,
                )
                for dst, src in zip(out[:6], sub[:6]):
                    dst[overflowed] = np.asarray(src)
        return _collect(c, out[:5], time.perf_counter() - t0, offloaded=out[5])

    return _stitch(scenarios, _net_group_key, run_group)
