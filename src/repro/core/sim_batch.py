"""Vectorized fleet-sweep backend: whole scenario grids as ONE tensor program.

The reference simulator (``simulator.simulate``) replays one stream at a
time in a Python event loop — every figure sweep pays interpreter cost per
frame per grid point.  This module executes and audits the same round plans
for a *batch* of scenarios (bandwidth × deadline × fps × policy-param grid
points) as a single jit+vmap program: per scenario, a ``lax.while_loop``
over scheduling rounds whose body (a) runs the policy's jitted DP
(:mod:`repro.core.jax_sched`), (b) backtracks the argmax schedule, and
(c) applies the shared audit contract of :mod:`repro.core.audit` — all on
device, returning per-scenario :class:`~repro.core.schedule.StreamStats`
tensors (accuracy sum, processed/missed counts, NPU occupancy).

Exactness contract (golden-tested in ``tests/test_sim_batch.py``): for every
scenario in the batch, the returned stats are **bit-identical** to
``simulate(PolicySpec(name, params).build(), ...)`` — same bin
discretization, same f32 DP recurrences, same f64 audit arithmetic in the
same order.  Three mechanisms make that possible:

  * every host-side quantity the reference computes in float64 (bin edges,
    arrival times, windows, f32 casts of policy params) is precomputed here
    with the identical numpy expressions;
  * the only round-coupled quantity, ``npu_free``, is carried on device in
    float64 — the module runs its programs inside ``jax.experimental
    .enable_x64`` and the DP kernels pin their own dtypes so the f32
    recurrences do not silently widen;
  * fixed shapes come from *padding*, never truncation: windows pad to the
    batch-max frame count ``W`` (padded frames are identity no-ops in the
    kernels) and the Max-Accuracy time grid pads to the batch-max bin count
    (padded bins provably stay ``NEG`` and cannot enter any argmax).

Only policies registered with ``batched=True`` (the local-plan jitted DPs
``jax_accuracy`` / ``jax_utility``) have a planner here; ``Session
.run_sweep`` falls back to the reference loop for everything else.  Their
plans never offload, so ``frames_offloaded`` is always 0 and no network
state is simulated on device (see docs/simulation.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .audit import AUDIT_TOL
from .jax_sched import NEG, _accuracy_dp, _utility_dp
from .profiles import ModelProfile, StreamSpec
from .schedule import StreamStats

__all__ = ["BatchScenario", "batched_policies", "simulate_batch"]


@dataclass(frozen=True)
class BatchScenario:
    """One grid point as the batched backend sees it: a stream shape, a frame
    budget, and the policy's *resolved* parameter dict (defaults filled in,
    e.g. ``PolicySpec(...).resolved``).  Network state is deliberately absent
    — batched policies are local-only plans and never consult it."""

    stream: StreamSpec = field(default_factory=StreamSpec)
    n_frames: int = 120
    params: Mapping[str, Any] = field(default_factory=dict)


_PLANNERS: dict[str, Callable[..., list[StreamStats]]] = {}


def _planner(name: str):
    def deco(fn):
        _PLANNERS[name] = fn
        return fn

    return deco


def batched_policies() -> tuple[str, ...]:
    """Policy names this backend can execute (mirrors ``batched=True`` in the
    registry; ``tests/test_sim_batch.py`` asserts the two stay in sync)."""
    return tuple(sorted(_PLANNERS))


def simulate_batch(
    policy: str,
    models: Sequence[ModelProfile],
    scenarios: Sequence[BatchScenario],
    *,
    strict: bool = True,
) -> list[StreamStats]:
    """Run ``policy`` over every scenario in one compiled program.

    Returns one audited :class:`StreamStats` per scenario, in order,
    bit-identical to the reference ``simulate`` loop.  Raises ``ValueError``
    for policies without a batched planner — callers that want a silent
    fallback should route through ``Session.run_sweep`` instead.
    """
    fn = _PLANNERS.get(policy)
    if fn is None:
        raise ValueError(
            f"policy {policy!r} has no batched backend; available: {batched_policies()}"
        )
    if not scenarios:
        return []
    return fn(list(models), list(scenarios), bool(strict))


# ---------------------------------------------------------------------------
# Shared host-side precomputation (float64 numpy — mirrors the reference
# wrappers in jax_sched expression by expression).
# ---------------------------------------------------------------------------


def _window_frames(stream: StreamSpec, params: Mapping[str, Any]) -> int:
    """Mirror of the plan-round wrappers' window choice."""
    wf = params.get("window_frames")
    if wf is not None:
        return int(wf)
    return max(int(np.floor(stream.deadline / stream.gamma)), 1)


# Scenario grouping: one monolithic batch would force every lane to pay the
# batch-max window, bin count, AND round count (a vmapped while_loop runs
# until the deepest lane finishes).  Scenarios are instead partitioned into
# shape-homogeneous groups keyed on a *quantized* window size (and the
# Max-Accuracy bin count quantized to multiples of 128), which bounds
# in-group padding waste by ~2x while keeping the jit cache small and stable
# across sweeps.  Padding is provably inert (see module docstring), so the
# partition cannot change any result — only wall-clock.

_W_LADDER = (1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14, 16, 20, 24, 28, 32, 40, 48, 64, 96, 128)


def _quant_w(n: int) -> int:
    for w in _W_LADDER:
        if n <= w:
            return w
    return int(2 ** np.ceil(np.log2(n)))


def _quant_bins(n: int, q: int = 128) -> int:
    return int(q * np.ceil(max(n, 1) / q))


def _stitch(scenarios, key_fn, run_group) -> list[StreamStats]:
    """Partition ``scenarios`` by ``key_fn``, run each group, reassemble in
    the original order."""
    groups: dict[Any, list[int]] = {}
    for i, s in enumerate(scenarios):
        groups.setdefault(key_fn(s), []).append(i)
    stats: list[StreamStats | None] = [None] * len(scenarios)
    for key in sorted(groups):
        idx = groups[key]
        for i, st in zip(idx, run_group(key, [scenarios[i] for i in idx])):
            stats[i] = st
    return stats  # type: ignore[return-value]


@dataclass
class _Common:
    """Per-group arrays shared by both planners."""

    B: int
    J: int
    W: int  # padded window (quantized group maximum)
    n_active: np.ndarray  # [B] i32 real window per scenario
    gamma: np.ndarray  # [B] f64
    deadline: np.ndarray  # [B] f64
    n_frames: np.ndarray  # [B] i32
    arrivals: np.ndarray  # [B, W] f64, k * gamma
    t_npu64: np.ndarray  # [J] f64 (inf for server-only models)
    acc_dp32: np.ndarray  # [J] f32 — the DP's accuracy table (raw max key)
    acc_stat64: np.ndarray  # [B, J] f64 — audit accuracy at the stream's r_max


def _common(
    models: list[ModelProfile], scenarios: list[BatchScenario], W: int | None = None
) -> _Common:
    B, J = len(scenarios), len(models)
    n_active = np.array([_window_frames(s.stream, s.params) for s in scenarios], np.int32)
    W = int(n_active.max()) if W is None else int(W)
    gamma = np.array([s.stream.gamma for s in scenarios], np.float64)
    deadline = np.array([s.stream.deadline for s in scenarios], np.float64)
    n_frames = np.array([s.n_frames for s in scenarios], np.int32)
    arrivals = np.arange(W, dtype=np.float64)[None, :] * gamma[:, None]
    t_npu64 = np.array([m.t_npu for m in models], np.float64)
    acc_dp32 = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float32
    )
    acc_stat64 = np.array(
        [[m.accuracy(s.stream.r_max, where="npu") for m in models] for s in scenarios],
        np.float64,
    )
    return _Common(B, J, W, n_active, gamma, deadline, n_frames, arrivals,
                   t_npu64, acc_dp32, acc_stat64)


def _collect(c: _Common, out, wall_s: float) -> list[StreamStats]:
    acc_sum, proc, miss, rounds, npu_busy = (np.asarray(a) for a in out)
    # The whole group schedules in one device program; apportion its wall
    # time by round count so schedule_time/schedule_calls stays the honest
    # amortized per-round cost (what figure rows report as us_per_call).
    total_rounds = max(int(rounds.sum()), 1)
    return [
        StreamStats(
            frames_total=int(c.n_frames[b]),
            frames_processed=int(proc[b]),
            frames_missed_deadline=int(miss[b]),
            frames_offloaded=0,  # batched policies are local-only plans
            accuracy_sum=float(acc_sum[b]),
            elapsed=float(c.n_frames[b] * c.gamma[b]),
            schedule_calls=int(rounds[b]),
            schedule_time=wall_s * float(rounds[b]) / total_rounds,
            npu_busy_s=float(npu_busy[b]),
        )
        for b in range(c.B)
    ]


def _audit_scan(*, head, n_frames, n_active, arrivals, deadline, t_npu64, acc_stat,
                picks, gate, free0, acc_sum, proc, miss, npu_s, W, J, strict):
    """On-device rendering of the :mod:`repro.core.audit` contract for a
    local-only round: sequential f64 fold over the (padded) window in frame
    order, so accuracy accumulates exactly as the reference loop's repeated
    ``+=``.  ``gate[k]`` says whether frame ``k`` really executes."""

    def au(carry, xs):
        free, a_s, pr, ms, nb = carry
        k, pick, act = xs
        j = jnp.clip(pick, 0, J - 1)
        arr_k = arrivals[k]
        start = jnp.maximum(free, arr_k)
        finish = start + t_npu64[j]
        if strict:
            bad = act & (finish > (arr_k + deadline) + AUDIT_TOL)
        else:
            bad = jnp.zeros_like(act)
        in_range = (head + k) < n_frames
        take = act & (~bad) & in_range
        a_s = a_s + jnp.where(take, acc_stat[j], 0.0)
        pr = pr + take.astype(jnp.int32)
        ms = ms + bad.astype(jnp.int32)  # missed counts even past-stream frames
        nb = nb + jnp.where(act, t_npu64[j], 0.0)
        free = jnp.where(act, finish, free)
        return (free, a_s, pr, ms, nb), None

    ks = jnp.arange(W, dtype=jnp.int32)
    carry, _ = jax.lax.scan(au, (free0, acc_sum, proc, miss, npu_s), (ks, picks, gate))
    return carry


# ---------------------------------------------------------------------------
# jax_accuracy: Max-Accuracy local DP over a (padded) time-bin grid.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _accuracy_program(W: int, NBINS: int, J: int, strict: bool):
    def one(gamma, deadline, grid, n_active, nbins_real, n_frames,
            arr_bins, dl_bins, dur, arrivals, acc_stat, t_npu64, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s = c
            active = head < n_frames  # lane gating under vmap-of-while
            t0 = head.astype(jnp.float64) * gamma
            npu_free = jnp.maximum(0.0, busy - t0)
            # Reference: int(np.ceil(max(npu_free, 0.0) / grid)), clipped to
            # the scenario's REAL bin count (not the padded one) — the clip
            # target is observable when npu_free overruns the horizon.
            start_bin = jnp.ceil(jnp.maximum(npu_free, 0.0) / grid).astype(jnp.int32)
            start_bin = jnp.clip(start_bin, 0, nbins_real - 1)
            H, choices, parents = _accuracy_dp(
                dur, acc_dp32, arr_bins, dl_bins, start_bin, n_active,
                n_frames=W, nbins=NBINS,
            )
            feasible = jnp.max(H) > NEG / 2
            b0 = jnp.argmax(H).astype(jnp.int32)

            def bt(b, k):
                bc = jnp.clip(b, 0, NBINS - 1)
                pick = choices[k, bc]
                return jnp.where(pick >= 0, parents[k, bc], b), pick

            _, picks_rev = jax.lax.scan(
                bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & feasible & (jnp.arange(W, dtype=jnp.int32) < n_active)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            # Infeasible window: the reference emits a horizon-1 SKIP round
            # that leaves the NPU carry untouched.
            busy_until = jnp.where(feasible, free_end, npu_free)
            horizon = jnp.where(feasible, n_active, 1)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6]

    return jax.jit(jax.vmap(
        one, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None, None)
    ))


@_planner("jax_accuracy")
def _run_accuracy(models, scenarios, strict):
    def run_group(W, group):
        c = _common(models, group, W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        # Bin arithmetic in f64 on the host — the same numpy expressions as
        # local_accuracy_dp_jax, vectorized over the batch.
        arr_bins = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl_bins = np.floor((c.arrivals + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        horizon_t = (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        nbins_real = (np.ceil(horizon_t / grid) + 2).astype(np.int32)
        NBINS = _quant_bins(int(nbins_real.max()))
        # inf (server-only) and over-horizon durations clamp to NBINS: both
        # are unreachable in-bin exactly as the reference's raw values are.
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        t0 = time.perf_counter()
        with enable_x64():
            out = _accuracy_program(c.W, NBINS, c.J, strict)(
                c.gamma, c.deadline, grid, c.n_active, nbins_real, c.n_frames,
                arr_bins, dl_bins, dur, c.arrivals, c.acc_stat64,
                c.t_npu64, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out, time.perf_counter() - t0)

    return _stitch(
        scenarios, lambda s: _quant_w(_window_frames(s.stream, s.params)), run_group
    )


# ---------------------------------------------------------------------------
# jax_utility: Max-Utility Pareto-front DP (skips allowed).
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _utility_program(W: int, width: int, J: int, strict: bool):
    def one(gamma, deadline, n_active, n_frames, g32, d32, a32, w32,
            arrivals, acc_stat, t_npu64, t_npu32, acc_dp32):
        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, acc_sum, proc, miss, rounds, npu_s = c
            active = head < n_frames
            t0 = head.astype(jnp.float64) * gamma
            npu_free = jnp.maximum(0.0, busy - t0)
            (_, u, _, _), parents, actions, _ = _utility_dp(
                t_npu32, acc_dp32, n_active,
                n_frames=W, width=width, gamma=g32, deadline=d32, alpha=a32,
                npu_free=npu_free.astype(jnp.float32),
                first_arrival=jnp.float32(0.0), window=w32,
            )
            slot0 = jnp.argmax(u).astype(jnp.int32)

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            picks = picks_rev[::-1]

            gate = active & (picks >= 0)  # only picked frames execute; rest SKIP
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, n_frames=n_frames, n_active=n_active, arrivals=arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat, picks=picks,
                gate=gate, free0=free0, acc_sum=acc_sum, proc=proc, miss=miss,
                npu_s=npu_s, W=W, J=J, strict=strict,
            )
            head = jnp.where(active, head + n_active, head)  # horizon is always n
            busy = jnp.where(active, t0 + free_end, busy)
            rounds = jnp.where(active, rounds + 1, rounds)
            return head, busy, acc_sum, proc, miss, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[2], out[3], out[4], out[5], out[6]

    return jax.jit(jax.vmap(
        one, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None, None, None)
    ))


@_planner("jax_utility")
def _run_utility(models, scenarios, strict):
    # ``width`` is a compiled Pareto-front shape, so it joins the group key
    # (a width axis in a sweep grid costs one compile per distinct value).
    def run_group(key, group):
        W, width = key
        c = _common(models, group, W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        # The f32 casts the reference wrapper performs, precomputed in bulk.
        g32 = c.gamma.astype(np.float32)
        d32 = c.deadline.astype(np.float32)
        a32 = alpha.astype(np.float32)
        window = np.maximum(c.n_active.astype(np.float64) * c.gamma, c.gamma)
        w32 = window.astype(np.float32)
        t_npu32 = c.t_npu64.astype(np.float32)
        t0 = time.perf_counter()
        with enable_x64():
            out = _utility_program(c.W, width, c.J, strict)(
                c.gamma, c.deadline, c.n_active, c.n_frames,
                g32, d32, a32, w32, c.arrivals, c.acc_stat64,
                c.t_npu64, t_npu32, c.acc_dp32,
            )
            out = [np.asarray(a) for a in out]
        return _collect(c, out, time.perf_counter() - t0)

    return _stitch(
        scenarios,
        lambda s: (_quant_w(_window_frames(s.stream, s.params)), int(s.params["width"])),
        run_group,
    )
