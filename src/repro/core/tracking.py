"""Detect+track workload class: temporally coupled frames (FastMOT-style).

The classification workload treats every frame independently; real edge
video pipelines do not.  The dominant pattern (FastMOT; "Distributed
Edge-based Video Analytics on the Move") runs a *cheap local tracker on
every frame* and a *heavy detector every k frames*: tracked frames inherit
the last detection's accuracy, decayed by staleness and crowd density.
This module makes that workload a first-class citizen of the scheduler:

  retention        r = (1 - decay) ** density          (per-frame survival)
  tracked frame f  accuracy = det_acc * r ** (f - det_frame)

so the per-round decision space gains a *detector interval* axis ``k``
alongside the paper's offload/NPU placement: a detection placed on the NPU
occupies it for ``T_j^npu`` (forcing k >= ceil(T_j^npu / gamma)); a
detection offloaded at resolution ``rho`` occupies the uplink for
``t_up`` (forcing k >= floor(t_up / gamma) + 1); every frame inside the
interval is carried by the tracker and scores the decayed accuracy.

Execution semantics (the audit contract's tracking extension) live in
:mod:`repro.core.audit` (``TrackState`` / ``apply_track_round``); this
module owns the workload description (:class:`WorkloadSpec`), the decay
tables shared verbatim by the reference loop and both batched engines
(:func:`retention_powers` / :func:`interval_means` — host Python
arithmetic, so all backends multiply the *same* float64 constants), the
registered planners (``track_accuracy``, ``track_fixed``), and the
exhaustive oracle used by the bound test (:func:`exhaustive_track_best`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

from .profiles import ModelProfile, NetworkState, StreamSpec, best_server_model
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

__all__ = [
    "WORKLOAD_KINDS",
    "WorkloadSpec",
    "exhaustive_track_best",
    "interval_means",
    "npu_interval",
    "retention",
    "retention_powers",
    "upload_interval",
]

WORKLOAD_KINDS = ("classify", "track")

# Default decay curve: calibrated to FastMOT's FPS-vs-#targets table shape —
# a moderate scene loses ~15% of its tracked accuracy per frame of staleness.
DEFAULT_DECAY = 0.15
DEFAULT_DENSITY = 1.0
DEFAULT_K_MAX = 8


def retention(decay: float, density: float) -> float:
    """Per-frame accuracy retention ``(1 - decay) ** density``.

    ``decay`` is the per-frame fractional loss for a unit-density scene;
    ``density`` scales it for crowd size (FastMOT: more targets decay
    faster).  Host Python arithmetic — every backend consumes this value.
    """
    return (1.0 - float(decay)) ** float(density)


def retention_powers(ret: float, n: int) -> list[float]:
    """``[ret ** age for age in 0..n-1]`` — the tracked-frame scoring table.

    Computed with Python ``**`` on the host so the reference loop (which
    evaluates ``ret ** age`` directly) and the batched engines (which look
    the value up from this table on device) score bit-identical floats.
    """
    return [ret**age for age in range(max(n, 1))]


def interval_means(ret: float, k_max: int) -> list[float]:
    """``out[k-1]`` = mean retention over a k-frame detector interval.

    A detection refreshed every ``k`` frames yields per-frame accuracy
    ``det_acc * (1 + ret + ... + ret^(k-1)) / k``; planners score a
    candidate (placement, k) as ``det_acc * out[k-1]``.  Monotone
    non-increasing in ``k`` (each new term is <= the running mean), which
    is why the minimum feasible interval is optimal per placement.
    """
    out: list[float] = []
    s = 0.0
    for k in range(1, max(k_max, 1) + 1):
        s += ret ** (k - 1)
        out.append(s / k)
    return out


def npu_interval(t_npu: float, gamma: float) -> int:
    """Minimum detector interval for an NPU detection: the NPU is busy for
    ``t_npu``, so the next detection cannot be planned before it frees."""
    return max(int(math.ceil(t_npu / gamma)), 1)


def upload_interval(t_up: float, gamma: float) -> int:
    """Minimum detector interval for an offloaded detection: the paper's
    ``n_l = floor(t_up / gamma)`` frames arrive while the link is busy,
    plus the head frame itself."""
    return int(math.floor(t_up / gamma)) + 1


@dataclass(frozen=True)
class WorkloadSpec:
    """What the stream's frames *are* — the world truth the executor scores.

    ``kind="classify"`` (default) is the paper's independent-frame
    workload; ``kind="track"`` makes frames temporally coupled with the
    decay model above.  Planner parameters (``decay``/``density`` on
    ``track_accuracy``) are the planner's *belief* and default to the same
    values, mirroring how ``run_online`` separates estimator from truth;
    the executor always scores with this spec.
    """

    kind: str = "classify"
    decay: float = DEFAULT_DECAY
    density: float = DEFAULT_DENSITY

    def __post_init__(self) -> None:
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(
                f"unknown workload kind {self.kind!r}; expected one of {WORKLOAD_KINDS}"
            )
        for name, lo, hi in (("decay", 0.0, 1.0), ("density", 0.0, None)):
            v = getattr(self, name)
            bad = (
                not isinstance(v, (int, float))
                or isinstance(v, bool)
                or v < lo
                or (hi is not None and v > hi)
            )
            if bad:
                rng = f"[{lo}, {hi}]" if hi is not None else f">= {lo}"
                raise ValueError(f"workload {name} must be a number {rng}, got {v!r}")

    @property
    def is_track(self) -> bool:
        return self.kind == "track"

    @property
    def retention(self) -> float:
        return retention(self.decay, self.density)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict[str, Any]:
        return {"kind": self.kind, "decay": self.decay, "density": self.density}

    @staticmethod
    def from_json(data: Mapping[str, Any]) -> "WorkloadSpec":
        if not isinstance(data, Mapping) or "kind" not in data:
            raise ValueError(f"not a WorkloadSpec payload: {data!r}")
        return WorkloadSpec(
            kind=str(data["kind"]),
            decay=float(data.get("decay", DEFAULT_DECAY)),
            density=float(data.get("density", DEFAULT_DENSITY)),
        )


# ---------------------------------------------------------------------------
# Candidate enumeration shared by both planners and the oracle.
# ---------------------------------------------------------------------------


def _npu_candidates(
    models: Sequence[ModelProfile], stream: StreamSpec
) -> list[tuple[int, float, float]]:
    """``(j, t_npu, accuracy)`` for every locally runnable model, j ascending."""
    return [
        (j, m.t_npu, m.accuracy(stream.r_max, where="npu"))
        for j, m in enumerate(models)
        if m.runs_local
    ]


def _server_candidates(
    models: Sequence[ModelProfile], stream: StreamSpec, net: NetworkState
) -> list[tuple[int, int, float, float, float]]:
    """``(r, j, t_up, t_server, accuracy)`` per feasible resolution, r ascending.

    Feasible means the upload + RTT leave a positive server budget and some
    server model fits it (paper §IV.B.1 candidate structure).
    """
    out: list[tuple[int, int, float, float, float]] = []
    T = stream.deadline
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        budget = T - t_up - net.rtt
        if budget <= 0:
            continue
        found = best_server_model(models, r, budget)
        if found is None:
            continue
        j, acc = found
        out.append((r, j, t_up, models[j].t_server, acc))
    return out


def _skip_plan(npu_free: float, horizon: int = 1) -> RoundPlan:
    return RoundPlan(
        decisions=[Decision(0, Where.SKIP)], horizon=horizon, npu_busy_until=npu_free
    )


def _detect_plan(
    kind: Where,
    *,
    j: int,
    k: int,
    acc: float,
    score: float,
    npu_free: float,
    start: float,
    finish: float,
    resolution: int = -1,
) -> RoundPlan:
    busy = finish if kind is Where.NPU else npu_free
    return RoundPlan(
        decisions=[
            Decision(0, kind, j, resolution, start=start, finish=finish)
        ],
        horizon=k,
        expected_accuracy_sum=score * k,
        npu_busy_until=busy,
    )


_TRACK_PARAMS = (
    Param.number(
        "decay",
        DEFAULT_DECAY,
        lo=0.0,
        hi=1.0,
        doc="believed per-frame fractional accuracy loss of tracked frames",
    ),
    Param.number(
        "density",
        DEFAULT_DENSITY,
        lo=0.0,
        doc="believed target density scaling the decay (FastMOT FPS-vs-#targets)",
    ),
    Param.integer(
        "k_max",
        DEFAULT_K_MAX,
        lo=1,
        doc="largest detector interval the planner may choose",
    ),
)


@register_policy(
    "track_accuracy",
    params=_TRACK_PARAMS,
    doc=(
        "Detect+track DP: jointly picks the detector interval k and the "
        "detection placement (NPU model / offload resolution+model) that "
        "maximize mean decayed accuracy per frame under the deadline."
    ),
    batched=True,
    batched_multi=True,
    workloads=("track",),
)
def plan_track_accuracy(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    decay: float = DEFAULT_DECAY,
    density: float = DEFAULT_DENSITY,
    k_max: int = DEFAULT_K_MAX,
) -> RoundPlan:
    """One round: choose the detection whose interval-mean accuracy is best.

    For each placement the minimum feasible interval is optimal (the
    interval mean is non-increasing in k, see :func:`interval_means`), so
    the joint (placement, k) search reduces to scoring each placement at
    its own minimum k.  Candidate order is NPU models ascending then
    offload resolutions ascending; strict ``>`` keeps the first maximum —
    the batched backends replay this order bit-for-bit.
    """
    T = stream.deadline
    gamma = stream.gamma
    ret = retention(decay, density)
    im = interval_means(ret, k_max)
    free = max(npu_free, 0.0)
    best_score = -1.0
    best: RoundPlan | None = None

    for j, t_npu, acc in _npu_candidates(models, stream):
        finish = free + t_npu
        if finish > T:
            continue
        k = npu_interval(t_npu, gamma)
        if k > k_max:
            continue
        score = acc * im[k - 1]
        if score > best_score:
            best_score = score
            best = _detect_plan(
                Where.NPU, j=j, k=k, acc=acc, score=score,
                npu_free=free, start=free, finish=finish,
            )

    for r, j, t_up, t_server, acc in _server_candidates(models, stream, net):
        k = upload_interval(t_up, gamma)
        if k > k_max:
            continue
        score = acc * im[k - 1]
        if score > best_score:
            best_score = score
            best = _detect_plan(
                Where.SERVER, j=j, k=k, acc=acc, score=score,
                npu_free=free, start=0.0, finish=t_up + net.rtt + t_server,
                resolution=r,
            )

    return best if best is not None else _skip_plan(free)


@register_policy(
    "track_fixed",
    params=(
        Param.integer(
            "k",
            lo=1,
            doc="fixed detector interval: one detection attempt every k frames",
        ),
    ),
    doc=(
        "Fixed-interval detect+track baseline: every k frames, run the "
        "highest-accuracy detection that fits inside the interval and the "
        "deadline; the tracker carries the other frames."
    ),
    batched=True,
    batched_multi=True,
    workloads=("track",),
)
def plan_track_fixed(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    k: int = 1,
) -> RoundPlan:
    """One round of the classical fixed-k tracker: the interval is given,
    only the detection placement is chosen (highest fresh accuracy that
    fits; NPU models then offload resolutions, strict ``>`` first-wins).
    The round always consumes ``k`` frames — even when no detection fits,
    the tracker coasts on the stale state for the whole interval.
    """
    T = stream.deadline
    gamma = stream.gamma
    free = max(npu_free, 0.0)
    best_acc = -1.0
    best: RoundPlan | None = None

    for j, t_npu, acc in _npu_candidates(models, stream):
        finish = free + t_npu
        if finish > T or npu_interval(t_npu, gamma) > k:
            continue
        if acc > best_acc:
            best_acc = acc
            best = _detect_plan(
                Where.NPU, j=j, k=k, acc=acc, score=acc,
                npu_free=free, start=free, finish=finish,
            )

    for r, j, t_up, t_server, acc in _server_candidates(models, stream, net):
        if upload_interval(t_up, gamma) > k:
            continue
        if acc > best_acc:
            best_acc = acc
            best = _detect_plan(
                Where.SERVER, j=j, k=k, acc=acc, score=acc,
                npu_free=free, start=0.0, finish=t_up + net.rtt + t_server,
                resolution=r,
            )

    return best if best is not None else _skip_plan(free, horizon=k)


# ---------------------------------------------------------------------------
# Exhaustive oracle (bound test) — enumerates every executor-accepted action.
# ---------------------------------------------------------------------------


def exhaustive_track_best(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    n_frames: int,
    *,
    retention: float,
    k_max: int = DEFAULT_K_MAX,
) -> float:
    """Optimal accuracy sum over ALL detect+track executions (constant net).

    Plain recursion over ``(head, npu_free, det_acc, det_frame)``: at each
    round boundary the executor accepts SKIP (horizon 1), an NPU detection
    with any interval ``k in 1..k_max`` (the NPU occupancy carries into the
    next round when ``k`` undercuts ``ceil(t_npu / gamma)``), or an
    offloaded detection with any ``k in 1..k_max``.  This is a superset of
    what the registered planners emit, so it upper-bounds every tracking
    heuristic; ``tests/test_oracle_bound.py`` pins that.
    """
    gamma = stream.gamma
    T = stream.deadline
    ret = retention
    npu_cands = _npu_candidates(models, stream)
    # For offloads, every interval choice leaves the same carry state, so
    # only the highest-accuracy feasible (resolution, model) pair matters.
    srv_accs = [acc for (_, _, _, _, acc) in _server_candidates(models, stream, net)]
    best_srv = max(srv_accs) if srv_accs else None
    memo: dict[tuple, float] = {}

    def tracked_sum(acc: float, head: int, lo: int, k: int) -> float:
        # ages lo..k-1 relative to a detection at `head`, clipped to stream end
        return sum(
            acc * ret**i for i in range(lo, k) if head + i < n_frames
        )

    def rec(head: int, npu_free: float, det_acc: float, det_frame: int) -> float:
        if head >= n_frames:
            return 0.0
        key = (head, round(npu_free, 9), det_acc, det_frame)
        if key in memo:
            return memo[key]
        # SKIP, horizon 1: the tracker coasts one frame on the stale state.
        best = det_acc * ret ** (head - det_frame) + rec(
            head + 1, max(npu_free - gamma, 0.0), det_acc, det_frame
        )
        for _, t_npu, acc in npu_cands:
            finish = max(npu_free, 0.0) + t_npu
            if finish > T + 1e-12:
                continue
            for k in range(1, k_max + 1):
                v = (
                    acc
                    + tracked_sum(acc, head, 1, k)
                    + rec(head + k, max(finish - k * gamma, 0.0), acc, head)
                )
                if v > best:
                    best = v
        if best_srv is not None:
            for k in range(1, k_max + 1):
                v = (
                    best_srv
                    + tracked_sum(best_srv, head, 1, k)
                    + rec(
                        head + k, max(npu_free - k * gamma, 0.0), best_srv, head
                    )
                )
                if v > best:
                    best = v
        memo[key] = best
        return best

    return rec(0, 0.0, 0.0, -1)
