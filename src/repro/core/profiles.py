"""Model/stream/network profiles — the scheduler's world model.

All the quantities in the paper's Table I live here:

  T_j^npu   ModelProfile.t_npu           (seconds; local quantized path)
  T_j^o     ModelProfile.t_server        (seconds; edge full-precision path)
  a(j, r)   ModelProfile.accuracy(r)     (piecewise-linear in resolution)
  S(I, r)   StreamSpec.frame_bytes(r)    (PNG-calibrated byte model)
  B, T_c    NetworkState.bandwidth_bps / .rtt
  f, gamma  StreamSpec.fps / .gamma
  T         StreamSpec.deadline

Times are SECONDS everywhere in core/.  Profile constructors accept ms for
readability (`*_ms` kwargs) because the paper speaks in ms.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

# Paper §VI: 5 candidate offload resolutions, deadline 200 ms.
PAPER_RESOLUTIONS: tuple[int, ...] = (45, 90, 134, 179, 224)
PAPER_DEADLINE_S: float = 0.200

# Byte model calibration: PNG ≈ 0.5 × raw RGB.  At B = 2.5 Mbps this gives
# 224px → 241 ms and 90px → 38.9 ms, matching Table II's "39 - 242 ms".
PNG_RATIO: float = 0.5


def frame_bytes(resolution: int, png_ratio: float = PNG_RATIO) -> float:
    """S(I, r): bytes of one video frame resized to ``resolution``²."""
    return float(resolution) * float(resolution) * 3.0 * png_ratio


@dataclass(frozen=True)
class ModelProfile:
    """One CNN model the scheduler can pick (paper's index j).

    ``acc_server``/``acc_npu`` map resolution -> accuracy; the NPU path always
    runs at the maximum resolution (paper §V.B: local frames are not resized)
    so only ``acc_npu[r_max]`` is consulted for local decisions.
    """

    name: str
    t_npu: float  # T_j^npu, seconds; inf if the model cannot run locally
    t_server: float  # T_j^o, seconds; inf if not deployed on the edge
    acc_server: Mapping[int, float] = field(default_factory=dict)
    acc_npu: Mapping[int, float] = field(default_factory=dict)

    @property
    def runs_local(self) -> bool:
        return self.t_npu != float("inf")

    @property
    def runs_server(self) -> bool:
        return self.t_server != float("inf")

    def accuracy(self, resolution: int, *, where: str) -> float:
        """a(j, r) with piecewise-linear interpolation between profiled points."""
        table = self.acc_server if where == "server" else self.acc_npu
        if not table:
            return 0.0
        keys = sorted(table)
        if resolution in table:
            return float(table[resolution])
        if resolution <= keys[0]:
            return float(table[keys[0]])
        if resolution >= keys[-1]:
            return float(table[keys[-1]])
        hi = bisect.bisect_left(keys, resolution)
        r0, r1 = keys[hi - 1], keys[hi]
        a0, a1 = table[r0], table[r1]
        w = (resolution - r0) / (r1 - r0)
        return float(a0 + w * (a1 - a0))


def profile_ms(
    name: str,
    *,
    t_npu_ms: float = float("inf"),
    t_server_ms: float = float("inf"),
    acc_server: Mapping[int, float] | None = None,
    acc_npu: Mapping[int, float] | None = None,
) -> ModelProfile:
    return ModelProfile(
        name=name,
        t_npu=t_npu_ms / 1e3,
        t_server=t_server_ms / 1e3,
        acc_server=dict(acc_server or {}),
        acc_npu=dict(acc_npu or {}),
    )


@dataclass(frozen=True)
class StreamSpec:
    """The video stream the application hands us (paper's f, gamma, T, r set)."""

    fps: float = 30.0
    deadline: float = PAPER_DEADLINE_S  # T, seconds
    resolutions: tuple[int, ...] = PAPER_RESOLUTIONS
    png_ratio: float = PNG_RATIO

    @property
    def gamma(self) -> float:
        return 1.0 / self.fps

    @property
    def r_max(self) -> int:
        return max(self.resolutions)

    def frame_bytes(self, resolution: int) -> float:
        return frame_bytes(resolution, self.png_ratio)


@dataclass(frozen=True)
class NetworkState:
    """Link between serving tier and edge pool (paper's B and T_c)."""

    bandwidth_bps: float  # B, payload bits per second
    rtt: float = 0.100  # T_c, seconds

    @property
    def bandwidth_mbps(self) -> float:
        return self.bandwidth_bps / 1e6

    def upload_time(self, nbytes: float) -> float:
        if self.bandwidth_bps <= 0:
            return float("inf")
        return nbytes * 8.0 / self.bandwidth_bps


def network_mbps(mbps: float, rtt_ms: float = 100.0) -> NetworkState:
    return NetworkState(bandwidth_bps=mbps * 1e6, rtt=rtt_ms / 1e3)


# ---------------------------------------------------------------------------
# Paper-faithful profiles (Table II + Fig. 4 shape).  Fig. 4 is not published
# numerically; the curves below are monotone, concave, anchored at Table II's
# 224px values, and reproduce its qualitative shape ("accuracy does not scale
# linearly with the resolution").
#
# These are the FALLBACK when no measured profile exists: they are typed-in
# constants from the paper's hardware, not this host's.  For profiles measured
# by actually executing the int8 Pallas path vs the full-precision edge path
# on the current backend, run ``serving/calibrate.py`` and load its JSON
# artifact through ``ScenarioSpec`` (see docs/serving.md).
# ---------------------------------------------------------------------------

RESNET50 = profile_ms(
    "resnet-50",
    t_npu_ms=52.0,
    t_server_ms=69.0,
    acc_server={45: 0.20, 90: 0.42, 134: 0.56, 179: 0.63, 224: 0.67},
    acc_npu={224: 0.52},
)

SQUEEZENET = profile_ms(
    "squeezenet",
    t_npu_ms=17.0,
    t_server_ms=9.0,
    acc_server={45: 0.12, 90: 0.29, 134: 0.40, 179: 0.47, 224: 0.51},
    acc_npu={224: 0.41},
)

PAPER_MODELS: tuple[ModelProfile, ...] = (RESNET50, SQUEEZENET)
PAPER_STREAM = StreamSpec()


def scale_profile(p: ModelProfile, *, npu_speedup: float = 1.0, acc_delta: float = 0.0) -> ModelProfile:
    """Utility for ablations: perturb a profile without rebuilding tables."""
    acc_npu = {r: max(0.0, min(1.0, a + acc_delta)) for r, a in p.acc_npu.items()}
    return replace(p, t_npu=p.t_npu / npu_speedup, acc_npu=acc_npu)


def best_server_model(
    models: Sequence[ModelProfile], resolution: int, budget: float
) -> tuple[int, float] | None:
    """Paper §IV.B.1: highest-accuracy server model with T_j^o <= budget.

    Returns (model_index, accuracy) or None if no model fits the budget.
    """
    best: tuple[int, float] | None = None
    for j, m in enumerate(models):
        if not m.runs_server or m.t_server > budget:
            continue
        a = m.accuracy(resolution, where="server")
        if best is None or a > best[1]:
            best = (j, a)
    return best
