"""Max-Accuracy scheduling (paper §IV, Algorithm 1).

Per round: try every offload resolution r for the head frame I_0, pick the
highest-accuracy feasible server model (offload phase), then schedule the
n_l = floor(S(I_0,r)/(B*gamma)) frames that arrive during the upload on the
NPU with an exact dynamic program over a discretized time grid (local phase).
The candidate with the best *normalized* accuracy A'/(n_l+1) wins.  A pure
local candidate (process I_0 on the NPU, horizon 1) is always in the running,
which is what makes Max-Accuracy degrade gracefully to the Local policy when
the network is poor (paper Fig. 5).

Implementation notes vs the paper's pseudocode:
  * Line 7-10 of Algorithm 1 adds every feasible server model; the prose
    ("the model with the highest accuracy ... will be selected") makes clear
    only the best one is meant — we implement the prose.
  * The DP uses conservative rounding (durations ceil'd to the grid, deadlines
    floor'd) so any extracted schedule is feasible in continuous time; the
    final Decision timestamps are recomputed exactly.

A prose walkthrough of the DP grid (and how the multi-tenant edge server
reuses this solver as its inner loop) lives in docs/scheduling.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .profiles import ModelProfile, NetworkState, StreamSpec, best_server_model
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

NEG = -1e18


@dataclass(frozen=True)
class LocalDPResult:
    """Result of the local-phase DP over frames 1..n (or 0..n-1)."""

    total_accuracy: float
    models: list[int]  # chosen model index per frame, aligned with frame ids
    finish_times: list[float]
    feasible: bool


def local_dp(
    models: Sequence[ModelProfile],
    *,
    n_frames: int,
    gamma: float,
    deadline: float,
    npu_free: float,
    first_arrival: float,
    accuracies: Sequence[float] | None = None,
    grid: float = 1e-3,
) -> LocalDPResult:
    """Exact DP: H(k, t) = max_j H(k-1, t - T_j^npu) + a(j, r_max)  (Eq. 7/8).

    Frame k (0-based here) arrives at ``first_arrival + k*gamma`` and must
    finish by ``arrival + deadline``.  All frames must be processed; if any
    frame admits no model, the instance is infeasible (Max-Accuracy does not
    skip frames).
    """
    local = [(j, m) for j, m in enumerate(models) if m.runs_local]
    if n_frames <= 0:
        return LocalDPResult(0.0, [], [], True)
    if not local:
        return LocalDPResult(NEG, [], [], False)

    if accuracies is None:
        acc = {j: m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for j, m in local}
    else:
        acc = {j: accuracies[j] for j, _ in local}

    horizon = first_arrival + (n_frames - 1) * gamma + deadline
    nbins = int(np.ceil(horizon / grid)) + 2
    dur_bins = {j: int(np.ceil(m.t_npu / grid)) for j, m in local}

    # H[b]: best accuracy sum with the NPU freeing exactly at bin b.
    H = np.full(nbins, NEG)
    start_bin = int(np.ceil(max(npu_free, 0.0) / grid))
    if start_bin >= nbins:
        return LocalDPResult(NEG, [], [], False)
    H[start_bin] = 0.0

    choice = np.full((n_frames, nbins), -1, dtype=np.int32)
    parent = np.full((n_frames, nbins), -1, dtype=np.int32)

    for k in range(n_frames):
        arrival = first_arrival + k * gamma
        arr_bin = int(np.ceil(arrival / grid))
        dl_bin = int(np.floor((arrival + deadline) / grid))
        Hn = np.full(nbins, NEG)
        # Prefix max of H up to arr_bin: any earlier-free NPU starts at arrival.
        pre = np.maximum.accumulate(H[: arr_bin + 1]) if arr_bin >= 0 else None
        pre_arg = None
        if pre is not None and arr_bin < nbins:
            pre_arg = np.zeros(arr_bin + 1, dtype=np.int32)
            best = H[0]
            bi = 0
            for b in range(arr_bin + 1):
                if H[b] > best:
                    best, bi = H[b], b
                pre_arg[b] = bi
        for j, _m in local:
            d = dur_bins[j]
            a = acc[j]
            # Case A: NPU free at or before arrival -> finish at arr_bin + d.
            fb = arr_bin + d
            if pre is not None and fb < nbins and fb <= dl_bin:
                cand = pre[arr_bin] + a
                if cand > Hn[fb]:
                    Hn[fb] = cand
                    choice[k, fb] = j
                    parent[k, fb] = pre_arg[arr_bin]
            # Case B: NPU free after arrival -> finish = free + d (shift).
            lo = arr_bin + 1
            hi = min(nbins - d, dl_bin - d + 1)
            if hi > lo:
                seg = H[lo:hi] + a
                tgt = slice(lo + d, hi + d)
                better = seg > Hn[tgt]
                idx = np.nonzero(better)[0]
                if idx.size:
                    Hn[tgt.start + idx] = seg[idx]
                    choice[k, tgt.start + idx] = j
                    parent[k, tgt.start + idx] = lo + idx
        H = Hn
        if not np.any(H > NEG / 2):
            return LocalDPResult(NEG, [], [], False)

    end_bin = int(np.argmax(H))
    total = float(H[end_bin])
    if total <= NEG / 2:
        return LocalDPResult(NEG, [], [], False)

    # Backtrack, then recompute exact continuous-time finishes.
    chosen = []
    b = end_bin
    for k in range(n_frames - 1, -1, -1):
        chosen.append(int(choice[k, b]))
        b = int(parent[k, b])
    chosen.reverse()

    finishes: list[float] = []
    free = max(npu_free, 0.0)
    for k, j in enumerate(chosen):
        arrival = first_arrival + k * gamma
        start = max(free, arrival)
        free = start + models[j].t_npu
        finishes.append(free)
        if free > arrival + deadline + 1e-9:
            return LocalDPResult(NEG, [], [], False)  # conservative rounding prevents this
    return LocalDPResult(total, chosen, finishes, True)


def local_window_plan(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    *,
    npu_free: float = 0.0,
    grid: float = 1e-3,
    window_frames: int | None = None,
) -> RoundPlan | None:
    """Optimal all-local plan over a deadline-sized window (shared by the
    Local baseline and Max-Accuracy's local candidate — planning whole
    windows, not single frames, is what keeps Max-Accuracy >= Local)."""
    gamma, T = stream.gamma, stream.deadline
    n = window_frames if window_frames is not None else max(int(np.floor(T / gamma)), 1)
    for nn in range(n, 0, -1):
        dp = local_dp(
            models, n_frames=nn, gamma=gamma, deadline=T, npu_free=npu_free,
            first_arrival=0.0, grid=grid,
        )
        if dp.feasible:
            decisions = [
                Decision(k, Where.NPU, j, stream.r_max, start=fin - models[j].t_npu, finish=fin)
                for k, (j, fin) in enumerate(zip(dp.models, dp.finish_times))
            ]
            return RoundPlan(
                decisions=decisions,
                horizon=nn,
                expected_accuracy_sum=dp.total_accuracy,
                npu_busy_until=dp.finish_times[-1] if dp.finish_times else npu_free,
            )
    return None


@register_policy(
    "max_accuracy",
    params=(Param.number("grid", 1e-3, doc="local-phase DP time grid (s)"),),
    doc="Paper §IV Algorithm 1: per-round Max-Accuracy offload + local DP.",
    # Network-aware vectorized backend (core/sim_batch): whole scenario
    # grids — constant AND piecewise traces — run as one jit+vmap program.
    # Fleet grids route to the dedicated fleet planner in core/sim_multi_batch:
    # per-client DP planning over granted (water-filled) bandwidth composed
    # with the shared-link completion audit, so contention is exact — not a
    # replication trick.
    batched=True,
    batched_multi=True,
    # Online sweeps (core/sim_online_batch): the believed-network re-planning
    # loop with scan-carried EWMA estimator state, audited on the true trace.
    batched_online=True,
)
def plan_round(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    grid: float = 1e-3,
) -> RoundPlan:
    """One Max-Accuracy round for head frame I_0 arriving at t=0."""
    gamma, T = stream.gamma, stream.deadline
    best_plan: RoundPlan | None = None
    best_norm = NEG

    # --- offload candidates: one per resolution r (Algorithm 1 outer loop) ---
    for r in stream.resolutions:
        t_up = net.upload_time(stream.frame_bytes(r))
        budget = T - t_up - net.rtt
        if budget <= 0:
            continue
        pick = best_server_model(models, r, budget)
        if pick is None:
            continue
        j0, a0 = pick
        n_l = int(np.floor(t_up / gamma))
        dp = local_dp(
            models,
            n_frames=n_l,
            gamma=gamma,
            deadline=T,
            npu_free=npu_free,
            first_arrival=gamma,
            grid=grid,
        )
        if not dp.feasible:
            continue
        total = a0 + dp.total_accuracy
        norm = total / (n_l + 1)
        if norm > best_norm:
            decisions = [
                Decision(0, Where.SERVER, j0, r, start=0.0, finish=t_up + net.rtt + models[j0].t_server)
            ]
            for k, (j, fin) in enumerate(zip(dp.models, dp.finish_times)):
                decisions.append(
                    Decision(k + 1, Where.NPU, j, stream.r_max, start=fin - models[j].t_npu, finish=fin)
                )
            best_norm = norm
            best_plan = RoundPlan(
                decisions=decisions,
                horizon=n_l + 1,
                expected_accuracy_sum=total,
                npu_busy_until=dp.finish_times[-1] if dp.finish_times else npu_free,
                net_busy_until=t_up,
            )

    # --- pure local candidate: optimal plan over a full deadline window ---
    lp = local_window_plan(models, stream, npu_free=npu_free, grid=grid)
    if lp is not None and lp.expected_accuracy_sum / lp.horizon > best_norm:
        best_norm = lp.expected_accuracy_sum / lp.horizon
        best_plan = lp

    if best_plan is None:
        # Nothing can make the deadline: drop the head frame and move on.
        best_plan = RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
    return best_plan
