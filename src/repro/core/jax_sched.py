"""Jitted (jax.lax) implementations of the two scheduling DPs.

The Python implementations in max_accuracy/max_utility are the reference
semantics; these run the same recurrences as fixed-shape tensor programs so a
serving loop can schedule *on device* in microseconds (the paper reports
< 1 ms on a phone CPU; benchmarks/sched_latency.py measures ours).

  local_accuracy_dp_jax   H(k, t) over a time grid     (scan over frames)
  local_utility_dp_jax    fixed-width Pareto front DP  (scan over frames)

Both return enough (choice/parent) state to extract the argmax schedule on
the host; tests assert exact agreement with the Python reference.

The underlying kernels (``_accuracy_dp`` / ``_utility_dp``) are also the
batched entry points used by :mod:`repro.core.sim_batch`: every dtype is
pinned explicitly (so tracing inside an ``enable_x64`` context cannot
silently promote the f32 recurrences to f64 and drift from the reference),
and both take a *traced* ``n_active`` frame count — frames ``k >= n_active``
are pass-through no-ops (identity parents, choice ``-1``), which lets a
``vmap`` over scenarios with different window lengths share one padded
compiled shape.  Registered policies here declare ``batched=True`` so
``Session.run_sweep`` can route them through the vectorized backend.

A second kernel pair (``_accuracy_dp64`` / ``_utility_dp64``) serves the
*network-aware* batched planners for the paper's own ``max_accuracy`` /
``max_utility`` policies: those Python references run their DPs in float64,
so the twins pin f64 (they must trace inside ``enable_x64``) and reproduce
every sequential tie-break of the reference loops.  The offload phase —
upload time from the granted bandwidth, RTT, edge-vs-NPU choice for the
head frame — lives in the round programs of :mod:`repro.core.sim_batch`,
which feed these kernels the local-phase instances each round's bandwidth
implies.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .profiles import ModelProfile, NetworkState, StreamSpec
from .registry import Param, register_policy
from .schedule import Decision, RoundPlan, Where

NEG = -1e18


# ---------------------------------------------------------------------------
# Max-Accuracy local phase (Eq. 7/8)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_frames", "nbins"))
def _accuracy_dp(
    dur: jax.Array,  # [J] duration bins (int32, precomputed host-side in f64)
    acc: jax.Array,  # [J]
    arr_bins: jax.Array,  # [n_frames] int32
    dl_bins: jax.Array,  # [n_frames] int32
    start_bin: jax.Array,  # [] int32
    n_active: jax.Array | int | None = None,  # [] int32; frames >= this are no-ops
    *,
    n_frames: int,
    nbins: int,
):
    J = dur.shape[0]
    bins = jnp.arange(nbins, dtype=jnp.int32)
    if n_active is None:
        n_active = n_frames
    n_active = jnp.asarray(n_active, jnp.int32)

    H0 = jnp.full((nbins,), NEG, dtype=jnp.float32)
    H0 = H0.at[jnp.clip(start_bin, 0, nbins - 1)].set(0.0)

    def step(H, k):
        arr_bin = arr_bins[k]
        dl_bin = dl_bins[k]
        # prefix max (and argmax) of H over [0, arr_bin]
        masked = jnp.where(bins <= arr_bin, H, NEG)
        pre_val = jnp.max(masked)
        pre_arg = jnp.argmax(masked).astype(jnp.int32)

        def per_model(j):
            d = dur[j]
            a = acc[j]
            # Case A: NPU free <= arrival, finish at arr_bin + d.
            fbA = arr_bin + d
            okA = (fbA <= dl_bin) & (fbA < nbins) & (pre_val > NEG / 2)
            valA = jnp.where((bins == fbA) & okA, pre_val + a, NEG)
            parA = jnp.where((bins == fbA) & okA, pre_arg, -1)
            # Case B: free after arrival; target b takes from source b - d.
            src = bins - d
            okB = (src > arr_bin) & (src >= 0) & (bins <= dl_bin)
            gathered = jnp.where(okB, H[jnp.clip(src, 0, nbins - 1)], NEG)
            valB = jnp.where(gathered > NEG / 2, gathered + a, NEG)
            parB = jnp.where(valB > NEG / 2, jnp.clip(src, 0, nbins - 1), -1)
            val = jnp.where(valA >= valB, valA, valB)
            par = jnp.where(valA >= valB, parA, parB)
            return val, par

        vals, pars = jax.vmap(per_model)(jnp.arange(J, dtype=jnp.int32))  # [J, nbins]
        best_j = jnp.argmax(vals, axis=0)  # [nbins]
        Hn = jnp.take_along_axis(vals, best_j[None], axis=0)[0]
        parent = jnp.take_along_axis(pars, best_j[None], axis=0)[0]
        choice = jnp.where(Hn > NEG / 2, best_j.astype(jnp.int32), -1)
        parent = jnp.where(Hn > NEG / 2, parent, -1)
        # Padded frame (k >= n_active): identity pass-through, no decision.
        on = k < n_active
        Hn = jnp.where(on, Hn, H)
        choice = jnp.where(on, choice, -1)
        parent = jnp.where(on, parent, bins)
        return Hn, (choice, parent)

    H, (choices, parents) = jax.lax.scan(step, H0, jnp.arange(n_frames, dtype=jnp.int32))
    return H, choices, parents


def local_accuracy_dp_jax(
    models: Sequence[ModelProfile],
    *,
    n_frames: int,
    gamma: float,
    deadline: float,
    npu_free: float,
    first_arrival: float,
    grid: float = 1e-3,
):
    """Mirror of max_accuracy.local_dp; returns (total, model per frame) or
    (NEG, []) when infeasible."""
    local = [(j, m) for j, m in enumerate(models) if m.runs_local]
    if n_frames <= 0:
        return 0.0, []
    if not local:
        return NEG, []
    acc = jnp.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for _, m in local], dtype=jnp.float32
    )
    horizon = first_arrival + (n_frames - 1) * gamma + deadline
    nbins = int(np.ceil(horizon / grid)) + 2
    # Bin arithmetic in f64 on the host — identical to max_accuracy.local_dp,
    # so the two implementations agree exactly (no f32 boundary flips).
    dur = jnp.asarray([int(np.ceil(m.t_npu / grid)) for _, m in local], jnp.int32)
    arrivals = first_arrival + np.arange(n_frames) * gamma
    arr_bins = jnp.asarray(np.ceil(arrivals / grid).astype(np.int32))
    dl_bins = jnp.asarray(np.floor((arrivals + deadline) / grid).astype(np.int32))
    start_bin = jnp.asarray(int(np.ceil(max(npu_free, 0.0) / grid)), jnp.int32)
    H, choices, parents = _accuracy_dp(
        dur, acc, arr_bins, dl_bins, start_bin, n_frames=n_frames, nbins=nbins
    )
    H = np.asarray(H)
    total = float(H.max())
    if total <= NEG / 2:
        return NEG, []
    choices = np.asarray(choices)
    parents = np.asarray(parents)
    b = int(H.argmax())
    out = []
    for k in range(n_frames - 1, -1, -1):
        out.append(local[int(choices[k, b])][0])
        b = int(parents[k, b])
    out.reverse()
    return total, out


# ---------------------------------------------------------------------------
# Max-Utility local phase (dominance-pruned triples) — fixed-width front
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_frames", "width"))
def _utility_dp(
    t_npu: jax.Array,  # [J]
    acc: jax.Array,  # [J]
    n_active: jax.Array | int | None = None,  # [] int32; frames >= this are no-ops
    *,
    n_frames: int,
    width: int,
    gamma: jax.Array,
    deadline: jax.Array,
    alpha: jax.Array,
    npu_free: jax.Array,
    first_arrival: jax.Array,
    window: jax.Array,
):
    J = t_npu.shape[0]
    BIG_T = 1e9
    if n_active is None:
        n_active = n_frames
    n_active = jnp.asarray(n_active, jnp.int32)

    t0 = jnp.full((width,), BIG_T, dtype=jnp.float32).at[0].set(jnp.maximum(npu_free, 0.0))
    u0 = jnp.full((width,), NEG, dtype=jnp.float32).at[0].set(0.0)
    m0 = jnp.zeros((width,), jnp.int32)
    valid0 = jnp.zeros((width,), bool).at[0].set(True)
    slots = jnp.arange(width, dtype=jnp.int32)

    def step(state, k):
        t, u, m, valid = state
        arrival = first_arrival + k * gamma
        # Candidates: carry-over (slot s, action -1) + process with model j.
        def proc(j):
            t2 = jnp.maximum(t, arrival) + t_npu[j]
            ok = valid & (t2 <= arrival + deadline + 1e-12)
            # f32 division pinned explicitly: under enable_x64, i32/i32 would
            # promote to f64 and drift from the reference recurrence.
            mf = m.astype(jnp.float32)
            mean_term = (mf / (mf + 1)) * (u - mf / window) + alpha * acc[j] / (mf + 1)
            u2 = mean_term + (mf + 1) / window
            return (
                jnp.where(ok, t2, BIG_T),
                jnp.where(ok, u2, NEG),
                jnp.where(ok, m + 1, 0),
                ok,
            )

        pt, pu, pm, pok = jax.vmap(proc)(jnp.arange(J, dtype=jnp.int32))  # [J, width]
        ct = jnp.concatenate([t, pt.reshape(-1)])
        cu = jnp.concatenate([u, pu.reshape(-1)])
        cm = jnp.concatenate([m, pm.reshape(-1)])
        cparent = jnp.concatenate([slots, jnp.tile(slots, J)])
        caction = jnp.concatenate(
            [jnp.full((width,), -1, jnp.int32), jnp.repeat(jnp.arange(J, dtype=jnp.int32), width)]
        )
        cok = jnp.concatenate([valid, pok.reshape(-1)])
        cu = jnp.where(cok, cu, NEG)
        ct = jnp.where(cok, ct, BIG_T)
        # Pareto prune: sort by (t asc, u desc); keep strictly-rising u —
        # exactly the permutation jnp.lexsort((-cu, ct)) produced.  This
        # step runs window-times per scheduling round, and on CPU tuple
        # sorts and batched scatters are serial, so sweep wall-clock lives
        # and dies here.  Invalid candidates need no explicit flag past this
        # point: they carry (BIG_T, NEG) keys, sort strictly after every
        # valid entry (valid t is bounded by arrival+deadline << BIG_T), and
        # NEG can never beat the strictly-rising-u running max below.
        if jax.dtypes.canonicalize_dtype(jnp.int64) == jnp.int64:
            # x64 (the sim_batch sweep path): two SINGLE-int64 sorts — XLA
            # CPU's fast path — replace the slow generic tuple comparator.
            # Each i64 = (order-isomorphic f32 key << 32) | index; the index
            # doubles as the explicit stable tie-break, so sorting by -cu
            # then (stably, via carried rank) by ct yields the identical
            # total order: (ct, -cu, original position).  Original f32 bits
            # flow through the permutation gather untouched.
            def okey(x):  # monotone f32 -> int64 in [-2^31, 2^31)
                b = jax.lax.bitcast_convert_type(x + jnp.float32(0.0), jnp.int32)
                b = b.astype(jnp.int64)
                return jnp.where(b >= 0, b, jnp.int64(-2147483649) - b)

            idx64 = jnp.arange(ct.shape[0], dtype=jnp.int64)
            by_u = jax.lax.sort(((okey(-cu) << 32) | idx64,), num_keys=1)[0]
            idx_u = (by_u & 0xFFFFFFFF).astype(jnp.int32)
            by_t = jax.lax.sort(((okey(ct)[idx_u] << 32) | idx64,), num_keys=1)[0]
            perm = idx_u[(by_t & 0xFFFFFFFF).astype(jnp.int32)]
        else:
            # x32 (the per-round reference path, batch of one): a stable
            # 3-operand sort whose index payload IS the permutation.
            idx = jnp.arange(ct.shape[0], dtype=jnp.int32)
            perm = jax.lax.sort((ct, -cu, idx), num_keys=2, is_stable=True)[2]
        ct, cu, cm = ct[perm], cu[perm], cm[perm]
        cparent, caction = cparent[perm], caction[perm]
        run = jax.lax.cummax(cu)
        prev_run = jnp.concatenate([jnp.array([NEG], dtype=cu.dtype), run[:-1]])
        keep = cu > prev_run + 1e-12
        # Compact keepers to the front, truncate to width: the r-th output
        # slot gathers the r-th keeper (keepers already sit in rank order),
        # located by searchsorted over the keep-count prefix sum.  Exactly
        # the slots/fill values of a scatter-with-drop by rank, scatter-free.
        csum = jnp.cumsum(keep.astype(jnp.int32))
        pos = jnp.clip(
            jnp.searchsorted(csum, jnp.arange(1, width + 1, dtype=jnp.int32)),
            0, ct.shape[0] - 1,
        )
        filled = slots < csum[-1]
        nt = jnp.where(filled, ct[pos], BIG_T)
        nu = jnp.where(filled, cu[pos], NEG)
        nm = jnp.where(filled, cm[pos], 0)
        nok = filled
        nparent = jnp.where(filled, cparent[pos], -1)
        naction = jnp.where(filled, caction[pos], -1)
        # Padded frame (k >= n_active): identity pass-through, no decision.
        on = k < n_active
        nt = jnp.where(on, nt, t)
        nu = jnp.where(on, nu, u)
        nm = jnp.where(on, nm, m)
        nok = jnp.where(on, nok, valid)
        nparent = jnp.where(on, nparent, slots)
        naction = jnp.where(on, naction, -1)
        return (nt, nu, nm, nok), (nparent, naction, nu)

    state, (parents, actions, us) = jax.lax.scan(
        step, (t0, u0, m0, valid0), jnp.arange(n_frames, dtype=jnp.int32)
    )
    return state, parents, actions, us


def local_utility_dp_jax(
    models: Sequence[ModelProfile],
    *,
    n_frames: int,
    gamma: float,
    deadline: float,
    alpha: float,
    npu_free: float,
    first_arrival: float,
    window: float,
    width: int = 64,
):
    """Mirror of max_utility.local_utility_dp; returns (utility, [(k, j)])."""
    if n_frames <= 0:
        return 0.0, []
    local = [(j, m) for j, m in enumerate(models) if m.runs_local]
    if not local:
        return 0.0, []
    t_npu = jnp.array([m.t_npu for _, m in local], dtype=jnp.float32)
    acc = jnp.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for _, m in local], dtype=jnp.float32
    )
    (t, u, m, valid), parents, actions, us = _utility_dp(
        t_npu,
        acc,
        n_frames=n_frames,
        width=width,
        gamma=jnp.float32(gamma),
        deadline=jnp.float32(deadline),
        alpha=jnp.float32(alpha),
        npu_free=jnp.float32(npu_free),
        first_arrival=jnp.float32(first_arrival),
        window=jnp.float32(max(window, gamma)),
    )
    u = np.asarray(u)
    best_slot = int(u.argmax())
    best_u = float(u[best_slot])
    parents = np.asarray(parents)
    actions = np.asarray(actions)
    decisions: list[tuple[int, int]] = []
    slot = best_slot
    for k in range(n_frames - 1, -1, -1):
        a = int(actions[k, slot])
        if a >= 0:
            decisions.append((k, local[a][0]))
        slot = int(parents[k, slot])
        if slot < 0:
            break
    decisions.reverse()
    return best_u, decisions


# ---------------------------------------------------------------------------
# Reference-faithful float64 twins.  The paper's max_accuracy / max_utility
# policies accumulate their DPs in float64 (numpy arrays / Python floats),
# so the network-aware batched planners (core/sim_batch) cannot reuse the
# f32 kernels above without drifting on ties.  These twins pin f64 — they
# must be traced inside ``jax.experimental.enable_x64`` — and keep every
# sequential tie-break of the reference updates (first model wins ties,
# case A beats case B within a model, stable (t, -u) candidate order).
# ---------------------------------------------------------------------------


def _no_fma(product: jax.Array, gate: jax.Array) -> jax.Array:
    """Force ``product`` to round to float64 before it reaches an add.

    XLA CPU's LLVM backend contracts ``mul`` + ``add`` into ``fma`` inside
    fused loops, keeping the product at extended precision — one ulp off
    the Python reference, which is enough to flip a DP tie-break and pick a
    genuinely different schedule.  Neither XLA flags, nor
    ``lax.optimization_barrier``, nor paired bitcasts survive to codegen;
    a select on a *traced* (never constant-foldable, always-true at
    runtime) predicate does: LLVM will not contract across the select
    instruction, so the product is rounded exactly as the reference's
    intermediate assignment rounds it.  Apply to every f64 multiply whose
    result feeds an add on a reference-bit-exact path.
    """
    return jnp.where(gate, product, 0.0)


@functools.partial(jax.jit, static_argnames=("n_frames", "nbins"))
def _accuracy_dp64(
    dur: jax.Array,  # [J] duration bins (int32; ceil(t_npu/grid), clamped to nbins)
    acc: jax.Array,  # [J] f64 raw acc_npu table values (the DP objective)
    arr_bins: jax.Array,  # [n_frames] int32
    dl_bins: jax.Array,  # [n_frames] int32
    start_bin: jax.Array,  # [] int32
    *,
    n_frames: int,
    nbins: int,
):
    """f64 twin of ``max_accuracy.local_dp`` with per-step *prefix records*.

    One scan serves every window length ``nn <= n_frames``: frame ``k``'s
    recurrence touches only frame-local bins (its own ``arr_bin``/``dl_bin``),
    so the DP over frames ``0..nn-1`` is a strict prefix of the DP over
    ``0..n_frames-1``.  The per-step records ``(maxH, argmax bin, alive)``
    therefore equal what ``local_dp(n_frames=nn)`` returns for every ``nn``
    — the Max-Accuracy round program reads the record at ``nn = n_l(B)``
    for each offload resolution and at the largest alive ``nn`` for the
    pure-local candidate, all from a single kernel call.  Deadness
    propagates (a dead ``H`` can never revive), so ``alive`` is
    prefix-monotone, exactly like the reference's per-frame early-out.
    """
    J = dur.shape[0]
    bins = jnp.arange(nbins, dtype=jnp.int32)
    H0 = jnp.full((nbins,), NEG, dtype=jnp.float64)
    H0 = H0.at[jnp.clip(start_bin, 0, nbins - 1)].set(0.0)

    def step(H, k):
        arr_bin = arr_bins[k]
        dl_bin = dl_bins[k]
        masked = jnp.where(bins <= arr_bin, H, NEG)
        pre_val = jnp.max(masked)
        pre_arg = jnp.argmax(masked).astype(jnp.int32)

        def per_model(j):
            d = dur[j]
            a = acc[j]
            fbA = arr_bin + d
            okA = (fbA <= dl_bin) & (fbA < nbins) & (pre_val > NEG / 2)
            valA = jnp.where((bins == fbA) & okA, pre_val + a, NEG)
            parA = jnp.where((bins == fbA) & okA, pre_arg, -1)
            src = bins - d
            okB = (src > arr_bin) & (src >= 0) & (bins <= dl_bin)
            gathered = jnp.where(okB, H[jnp.clip(src, 0, nbins - 1)], NEG)
            valB = jnp.where(gathered > NEG / 2, gathered + a, NEG)
            parB = jnp.where(valB > NEG / 2, jnp.clip(src, 0, nbins - 1), -1)
            val = jnp.where(valA >= valB, valA, valB)
            par = jnp.where(valA >= valB, parA, parB)
            return val, par

        vals, pars = jax.vmap(per_model)(jnp.arange(J, dtype=jnp.int32))  # [J, nbins]
        best_j = jnp.argmax(vals, axis=0)
        Hn = jnp.take_along_axis(vals, best_j[None], axis=0)[0]
        parent = jnp.take_along_axis(pars, best_j[None], axis=0)[0]
        choice = jnp.where(Hn > NEG / 2, best_j.astype(jnp.int32), -1)
        parent = jnp.where(Hn > NEG / 2, parent, -1)
        maxH = jnp.max(Hn)
        argb = jnp.argmax(Hn).astype(jnp.int32)
        return Hn, (choice, parent, maxH, argb, maxH > NEG / 2)

    _, (choices, parents, maxH, argb, alive) = jax.lax.scan(
        step, H0, jnp.arange(n_frames, dtype=jnp.int32)
    )
    return choices, parents, maxH, argb, alive


@functools.partial(jax.jit, static_argnames=("n_frames", "width"))
def _utility_dp64(
    t_npu: jax.Array,  # [J] f64 (inf for server-only models)
    acc: jax.Array,  # [J] f64 raw acc_npu table values
    n_active: jax.Array,  # [] int32; frames >= this are pass-through no-ops
    *,
    n_frames: int,
    width: int,
    gamma: jax.Array,
    deadline: jax.Array,
    alpha: jax.Array,
    npu_free: jax.Array,
    first_arrival: jax.Array,
    window: jax.Array,
):
    """f64 twin of ``max_utility.local_utility_dp`` (Pareto triples).

    Candidate enumeration order (carried triples first, then processed
    candidates slot-major — exactly the reference's ``for tri in U: for j``
    loops), the stable ``(t, -u)`` sort, the 1e-12 dominance epsilon, and
    the cap-overflow rule all mirror the Python reference.  On overflow the
    reference keeps the ``cap`` highest-utility front entries re-sorted by
    ``t`` — since ``u`` rises strictly along the front, that is exactly the
    LAST ``width`` keepers in t-order, rendered here as a rank offset in the
    compaction.

    ``width`` below ``max_utility._prune``'s cap (256) is a *fast path*:
    results are exact as long as no front ever outgrows it, and the
    returned ``overflow`` flag reports whether one did (gated to live
    frames).  Callers must rerun overflowing instances at ``width = 256``,
    where the truncation rule coincides with the reference cap — the sort
    is the kernel's dominant cost and scales ~``width log width``, so the
    narrow first pass is worth the occasional rerun.
    """
    J = t_npu.shape[0]
    BIG_T = jnp.float64(1e9)
    n_active = jnp.asarray(n_active, jnp.int32)
    rounded = n_active >= 0  # traced, always true: _no_fma's opaque gate
    t0 = jnp.full((width,), BIG_T, jnp.float64).at[0].set(jnp.maximum(npu_free, 0.0))
    u0 = jnp.full((width,), NEG, jnp.float64).at[0].set(0.0)
    m0 = jnp.zeros((width,), jnp.int32)
    valid0 = jnp.zeros((width,), bool).at[0].set(True)
    slots = jnp.arange(width, dtype=jnp.int32)
    M = width * (J + 1)

    def step(state, k):
        t, u, m, valid = state
        arrival = first_arrival + _no_fma(k.astype(jnp.float64) * gamma, rounded)

        def proc(j):
            t2 = jnp.maximum(t, arrival) + t_npu[j]
            ok = valid & (t2 <= arrival + deadline + 1e-12)
            mf = m.astype(jnp.float64)
            mean_term = _no_fma(
                (mf / (mf + 1.0)) * (u - mf / window), rounded
            ) + alpha * acc[j] / (mf + 1.0)
            u2 = mean_term + (mf + 1.0) / window
            return (
                jnp.where(ok, t2, BIG_T),
                jnp.where(ok, u2, NEG),
                jnp.where(ok, m + 1, 0),
                ok,
            )

        pt, pu, pm, pok = jax.vmap(proc)(jnp.arange(J, dtype=jnp.int32))  # [J, width]
        # Slot-major processed candidates (transpose before flatten): the
        # stable sort's tie order must equal the reference's cands list.
        ct = jnp.concatenate([t, pt.T.reshape(-1)])
        cu = jnp.concatenate([u, pu.T.reshape(-1)])
        cm = jnp.concatenate([m, pm.T.reshape(-1)])
        cok = jnp.concatenate([valid, pok.T.reshape(-1)])
        cparent = jnp.concatenate([slots, jnp.repeat(slots, J)])
        caction = jnp.concatenate(
            [jnp.full((width,), -1, jnp.int32), jnp.tile(jnp.arange(J, dtype=jnp.int32), width)]
        )
        cu = jnp.where(cok, cu, NEG)
        ct = jnp.where(cok, ct, BIG_T)
        # Stable sort by (t asc, u desc): invalid candidates carry
        # (BIG_T, NEG) keys and sort strictly after every valid entry.
        idx = jnp.arange(M, dtype=jnp.int32)
        perm = jax.lax.sort((ct, -cu, idx), num_keys=2, is_stable=True)[2]
        ct, cu, cm = ct[perm], cu[perm], cm[perm]
        cparent, caction = cparent[perm], caction[perm]
        # The reference's dominance bar is the last KEPT utility, not the
        # running max of all candidates: a candidate rejected inside the
        # 1e-12 epsilon must not raise the bar for its successors (a plain
        # cummax would, dropping front entries the reference keeps when
        # utilities collide within the epsilon).  The fold is inherently
        # sequential; chunking it (16 unrolled folds per scan step) keeps
        # the scan shallow without changing the semantics.
        CH = 16
        pad = (-cu.shape[0]) % CH
        cu_p = jnp.concatenate([cu, jnp.full((pad,), NEG, cu.dtype)])

        def keep_chunk(bar, u_chunk):
            keeps = []
            for i in range(CH):
                k = u_chunk[i] > bar + 1e-12
                bar = jnp.where(k, u_chunk[i], bar)
                keeps.append(k)
            return bar, jnp.stack(keeps)

        _, keep = jax.lax.scan(
            keep_chunk, jnp.float64(NEG), cu_p.reshape(-1, CH)
        )
        keep = keep.reshape(-1)[: cu.shape[0]]
        csum = jnp.cumsum(keep.astype(jnp.int32))
        count = csum[-1]
        drop = jnp.maximum(count - width, 0)  # cap overflow: shed lowest-u keepers
        pos = jnp.clip(jnp.searchsorted(csum, drop + 1 + slots), 0, M - 1)
        filled = slots < (count - drop)
        nt = jnp.where(filled, ct[pos], BIG_T)
        nu = jnp.where(filled, cu[pos], NEG)
        nm = jnp.where(filled, cm[pos], 0)
        nparent = jnp.where(filled, cparent[pos], -1)
        naction = jnp.where(filled, caction[pos], -1)
        # Padded frame (k >= n_active): identity pass-through, no decision.
        on = k < n_active
        step_overflow = on & (count > width)
        nt = jnp.where(on, nt, t)
        nu = jnp.where(on, nu, u)
        nm = jnp.where(on, nm, m)
        nok = jnp.where(on, filled, valid)
        nparent = jnp.where(on, nparent, slots)
        naction = jnp.where(on, naction, -1)
        return (nt, nu, nm, nok), (nparent, naction, step_overflow)

    state, (parents, actions, overflows) = jax.lax.scan(
        step, (t0, u0, m0, valid0), jnp.arange(n_frames, dtype=jnp.int32)
    )
    return state, parents, actions, jnp.any(overflows)


# ---------------------------------------------------------------------------
# The jitted DPs as registered policies: local-only rounds planned on device.
# ---------------------------------------------------------------------------


@register_policy(
    "jax_accuracy",
    params=(
        Param.integer("window_frames", None, nullable=True, doc="DP window; default floor(T/gamma)"),
        Param.number("grid", 1e-3, doc="DP time grid (s)"),
    ),
    doc="Jitted Max-Accuracy local DP (every window frame on the NPU).",
    batched=True,
    # Fleet grids run the dedicated single-lane planner in
    # core/sim_multi_batch: local-only plans never take an uplink lease,
    # so one lane per scenario carries the whole homogeneous fleet while
    # the allocation gates are counted exactly for the meta report.
    batched_multi=True,
)
def plan_round_accuracy(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    npu_free: float = 0.0,
    window_frames: int | None = None,
    grid: float = 1e-3,
) -> RoundPlan:
    """Local-only round via :func:`local_accuracy_dp_jax` — the on-device
    counterpart of the ``local`` baseline's accuracy mode (all frames
    processed; a best-effort skip of the whole window when infeasible)."""
    gamma, T = stream.gamma, stream.deadline
    n = window_frames if window_frames is not None else max(int(np.floor(T / gamma)), 1)
    total, picks = local_accuracy_dp_jax(
        models, n_frames=n, gamma=gamma, deadline=T,
        npu_free=npu_free, first_arrival=0.0, grid=grid,
    )
    if total <= NEG / 2:
        return RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1, npu_busy_until=npu_free)
    decisions = []
    free = max(npu_free, 0.0)
    acc_sum = 0.0
    for k, j in enumerate(picks):
        start = max(free, k * gamma)
        free = start + models[j].t_npu
        decisions.append(Decision(k, Where.NPU, j, stream.r_max, start=start, finish=free))
        acc_sum += models[j].accuracy(stream.r_max, where="npu")
    return RoundPlan(
        decisions=decisions, horizon=n, expected_accuracy_sum=acc_sum, npu_busy_until=free
    )


@register_policy(
    "jax_utility",
    params=(
        Param.number("alpha", doc="paper Eq. (9) accuracy weight (required)"),
        Param.integer("window_frames", None, nullable=True, doc="DP window; default floor(T/gamma)"),
        Param.integer("width", 64, doc="Pareto-front width of the jitted DP"),
    ),
    doc="Jitted Max-Utility local DP (dominance-pruned front, skips allowed).",
    batched=True,
    # Fleet grids run the dedicated single-lane planner in
    # core/sim_multi_batch: local-only plans never take an uplink lease,
    # so one lane per scenario carries the whole homogeneous fleet while
    # the allocation gates are counted exactly for the meta report.
    batched_multi=True,
)
def plan_round_utility(
    models: Sequence[ModelProfile],
    stream: StreamSpec,
    net: NetworkState,
    *,
    alpha: float,
    npu_free: float = 0.0,
    window_frames: int | None = None,
    width: int = 64,
) -> RoundPlan:
    """Local-only round via :func:`local_utility_dp_jax` — the on-device
    counterpart of the ``local`` baseline's utility mode."""
    gamma, T = stream.gamma, stream.deadline
    n = window_frames if window_frames is not None else max(int(np.floor(T / gamma)), 1)
    utility, picks = local_utility_dp_jax(
        models, n_frames=n, gamma=gamma, deadline=T, alpha=alpha,
        npu_free=npu_free, first_arrival=0.0, window=n * gamma, width=width,
    )
    chosen = dict(picks)
    decisions = []
    free = max(npu_free, 0.0)
    for k in range(n):
        j = chosen.get(k)
        if j is None:
            decisions.append(Decision(k, Where.SKIP))
            continue
        start = max(free, k * gamma)
        free = start + models[j].t_npu
        decisions.append(Decision(k, Where.NPU, j, stream.r_max, start=start, finish=free))
    return RoundPlan(
        decisions=decisions, horizon=n, expected_utility=utility, npu_busy_until=free
    )
