"""Vectorized online-adaptation backend: observe -> replan -> execute on device.

``Session.run_online`` replays the paper's §VI adaptivity story one Python
round at a time: the policy plans against the EWMA belief of
:class:`~repro.core.controller.BandwidthEstimator` (bandwidth shaded by the
pessimism factor, RTT seeded from the first observation), while execution is
audited against the *true* trace — offload finish times are recomputed at
real bandwidth over a serially-occupied uplink, and each upload feeds the
estimator back.  This module executes that whole loop for a *batch* of
scenarios as one jit+vmap program: per lane, a ``lax.while_loop`` over rounds
whose carry holds the estimator state (EWMA bps / RTT), the NPU horizon, and
the true-link occupancy next to the audit accumulators.

Exactness contract (golden-tested in ``tests/test_online_batch.py``): for
every scenario, integer stats (processed / missed / offloaded / rounds) are
**exact** and accuracy sums match the fixed ``run_online`` reference within
:data:`~repro.core.audit.AUDIT_TOL`.  The planning phase is byte-for-byte
the network-aware programs of :mod:`repro.core.sim_batch` with two
substitutions — the bandwidth the planner sees is the carried belief
``bps * pessimism`` instead of a trace lookup, and the RTT is the carried
EWMA instead of a constant — and the execution phase renders ``run_online``'s
offload callback:

  * ``start = max(net_free, t0)`` — the true link is a serial resource
    carried across rounds (a belief-driven offload storm queues up);
  * ``finish = ((start + t_up_true) + rtt_true) + t_server``, compared
    against ``(t0 + deadline) + AUDIT_TOL`` unconditionally (true-completion
    accounting is not gated on ``strict`` — only plan-side NPU audits are);
  * the estimator updates ``bps <- (1-beta)*bps + beta*sample`` with
    ``sample = nbits / t_up_true`` (0 on a dead link: the belief decays, it
    is never poisoned by ``inf``), each product wrapped in
    :func:`~repro.core.jax_sched._no_fma` so XLA cannot contract the two
    f64 multiplies into an fma and drift off the reference bits.

Only the head frame of a round ever offloads (both planners emit a single
SERVER decision at frame 0), so each round makes at most one estimator
observation pair — exactly the reference's cadence.  Policies registered
``batched_online=True`` have a planner here; ``Session.run_sweep(mode=
"online")`` falls back to per-point ``run_online`` for everything else.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .audit import AUDIT_TOL
from .bucketing import quant_bins as _quant_bins
from .jax_sched import NEG, _accuracy_dp64, _no_fma, _utility_dp64
from .profiles import ModelProfile, StreamSpec
from .registry import get_policy
from .schedule import StreamStats
from .sim_batch import (
    _UTIL_CAP,
    _UTIL_FAST_WIDTH,
    _audit_scan,
    _collect,
    _common,
    _net_arrays,
    _net_group_key,
    _offload_tables,
    _stitch,
    _trace_bw,
)
from .sweep_shard import LaneProgram

__all__ = ["OnlineScenario", "batched_online_policies", "simulate_online_batch"]


@dataclass(frozen=True)
class OnlineScenario:
    """One online grid point: the scenario a ``run_online`` call would see.

    ``bw_segments`` / ``rtt`` describe the **true** network (the same padded
    piecewise layout as :class:`~repro.core.sim_batch.BatchScenario`); the
    estimator fields describe the belief machinery.  ``init_bps=None``
    seeds the belief from the true trace at t=0 — exactly
    ``BandwidthEstimator(init_bps=trace.at(0.0).bandwidth_bps)`` in
    ``run_online`` — and the believed RTT always seeds from the true RTT
    (the reference's pre-loop ``observe_rtt(trace.at(0.0).rtt)``, which
    *replaces* the stub prior now that the first sample seeds)."""

    stream: StreamSpec = field(default_factory=StreamSpec)
    n_frames: int = 120
    params: Mapping[str, Any] = field(default_factory=dict)
    rtt: float = 0.100
    bw_segments: tuple[tuple[float, float], ...] = ((0.0, 2.5e6),)
    init_bps: float | None = None
    beta: float = 0.3
    pessimism: float = 0.9


def _install_barrier_batching() -> bool:
    """``jax.lax.optimization_barrier`` ships without a vmap batching rule on
    this JAX version; the barrier is elementwise-identity, so the rule is the
    trivial one.  Registered once, guarded so a future JAX that provides its
    own rule wins."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except Exception:  # pragma: no cover - jax internals moved
        return False
    if optimization_barrier_p not in batching.primitive_batchers:
        def _rule(args, dims):
            return optimization_barrier_p.bind(*args), dims

        batching.primitive_batchers[optimization_barrier_p] = _rule
    return True


_HAS_BARRIER = _install_barrier_batching()


def _barrier(x):
    """Identity that XLA must not optimize across (see ``_true_offload``).
    Falls back to a traced multiply-gate if the barrier primitive is ever
    unavailable — weaker (XLA may still reassociate), but never wrong by
    more than the reference's own double-rounding ulp."""
    if _HAS_BARRIER:
        return jax.lax.optimization_barrier(x)
    return x * jnp.where(x < jnp.inf, 1.0, 1.0)  # pragma: no cover


_ONLINE: dict[str, Callable[..., list[tuple[StreamStats, dict]]]] = {}


def _online(name: str):
    def deco(fn):
        _ONLINE[name] = fn
        return fn

    return deco


def batched_online_policies() -> tuple[str, ...]:
    """Policy names with an online backend (mirrors ``batched_online=True``
    in the registry; ``tests/test_online_batch.py`` asserts the sync)."""
    return tuple(sorted(_ONLINE))


def simulate_online_batch(
    policy: str,
    models: Sequence[ModelProfile],
    scenarios: Sequence[OnlineScenario],
    *,
    strict: bool = True,
) -> list[tuple[StreamStats, dict]]:
    """Run the online loop for ``policy`` over every scenario in one compiled
    program.  Returns ``(stats, meta)`` per scenario in order, where ``meta``
    carries what ``run_online`` reports: the round count and the estimator's
    final believed bandwidth (``estimated_bps``).  Raises ``ValueError`` for
    policies without an online backend — silent fallback lives in
    ``Session.run_sweep(mode="online")``.
    """
    fn = _ONLINE.get(policy)
    if fn is None:
        raise ValueError(
            f"policy {policy!r} has no batched online backend; "
            f"available: {batched_online_policies()}"
        )
    get_policy(policy)  # surface unknown-policy errors with the registry text
    if not scenarios:
        return []
    return fn(list(models), list(scenarios), bool(strict))


def _bw_at0(segments: Sequence[tuple[float, float]]) -> float:
    """True bandwidth at t=0 under ``Trace.piecewise`` semantics: the last
    segment with ``t_start <= 0`` wins; before the first segment's start the
    first value applies."""
    segs = sorted((float(t), float(v)) for t, v in segments) or [(0.0, 0.0)]
    v0 = segs[0][1]
    for t, v in segs:
        if t <= 0.0:
            v0 = v
    return v0


def _estimator_arrays(group: list[OnlineScenario]):
    """Per-lane estimator constants: beta, (1-beta) (precomputed once, the
    same f64 subtraction the reference performs per call), pessimism, and
    the belief's initial bandwidth."""
    beta = np.array([s.beta for s in group], np.float64)
    omb = 1.0 - beta
    pess = np.array([s.pessimism for s in group], np.float64)
    bps0 = np.array(
        [s.init_bps if s.init_bps is not None else _bw_at0(s.bw_segments) for s in group],
        np.float64,
    )
    return beta, omb, pess, bps0


def _with_meta(stats: list[StreamStats], bps_final, pess) -> list[tuple[StreamStats, dict]]:
    # estimator.state().bandwidth_bps == _bps * pessimism — the belief the
    # next round would have planned with.
    return [
        (st, {"rounds": int(st.schedule_calls), "estimated_bps": float(b * p)})
        for st, b, p in zip(stats, np.asarray(bps_final), np.asarray(pess))
    ]


# ---------------------------------------------------------------------------
# Shared execution phase: run_online's offload callback as array expressions.
# The planning phase above it decided use_off / r_off / j_off from the
# *belief*; this fold completes the head-frame upload on the *true* network,
# keeps the link serially occupied, and feeds the estimator back.
# ---------------------------------------------------------------------------


def _true_offload(*, active, use_off, r_off, j_off, t0, deadline, rtt, beta, omb,
                  bps, rttb, netf, acc_sum, proc, miss, offl,
                  nbits8, acc_sv, bw_t, bw_v, t_srv, rounded, rounded2):
    bw_true = _trace_bw(bw_t, bw_v, t0)  # the reference's trace.at(t0)
    tup_t = jnp.where(bw_true > 0.0, nbits8[r_off] / bw_true, jnp.inf)
    start = jnp.maximum(netf, t0)  # d.start == 0.0 for both planners' heads
    fin = ((start + tup_t) + rtt) + t_srv[j_off]
    ok = fin <= (t0 + deadline) + AUDIT_TOL  # true completion: never strict-gated
    srv_take = active & use_off & ok
    acc_sum = acc_sum + jnp.where(srv_take, acc_sv[j_off, r_off], 0.0)
    proc = proc + srv_take.astype(jnp.int32)
    offl = offl + srv_take.astype(jnp.int32)
    miss = miss + (active & use_off & ~ok).astype(jnp.int32)
    netf = jnp.where(active & use_off, start + tup_t, netf)
    # observe_upload: sample = nbits / seconds; a dead link (t_up = inf)
    # still observes — sample 0.0 decays the belief, matching the reference.
    # The denominator goes through an optimization barrier: XLA's algebraic
    # simplifier otherwise cancels nbits / (nbits / bw) back to bw, skipping
    # the double rounding the reference performs (observed: device samples
    # came back exactly 800000.0 where the host gets 799999.9999999999 for
    # an 0.8 Mbps link; select- and multiply-gates both get reassociated
    # away, only the barrier holds).  The outer barrier stops the second
    # rewrite in the chain: beta * (nbits / d) -> (beta * nbits) / d, which
    # re-rounds the EWMA increment.
    sample = _barrier(jnp.where(tup_t > 0.0, nbits8[r_off] / _barrier(tup_t), 0.0))
    # The EWMA increments are adds of two products — both must round to f64
    # before the add, so both go through _no_fma selects, and the two selects
    # MUST gate on *different* (not provably equal) predicates.  With a shared
    # predicate, LLVM instcombine folds add(select(p,a,x), select(p,b,y)) into
    # select(p, a+b, x+y) and then contracts one mul into an fma; with the
    # surrounding update-select's own predicate, XLA drops the redundant inner
    # select instead.  ``rounded``/``rounded2`` are distinct always-true
    # comparisons of the same traced value, opaque to both rewrites.
    upd = active & use_off & (tup_t > 0.0)  # the <=0 guard (never real here)
    bps = jnp.where(
        upd,
        _no_fma(omb * bps, rounded) + _no_fma(beta * sample, rounded2),
        bps,
    )
    updr = active & use_off  # observe_rtt has no guard
    rttb = jnp.where(
        updr,
        _no_fma(omb * rttb, rounded) + _no_fma(beta * rtt, rounded2),
        rttb,
    )
    return bps, rttb, netf, acc_sum, proc, miss, offl


# ---------------------------------------------------------------------------
# Max-Accuracy online: the sim_batch program's planning phase against the
# carried belief, then the true-execution fold.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _online_accuracy_program(W: int, NBINS: int, S: int, J: int, R: int, strict: bool):
    def one(gamma, deadline, rtt, grid, beta, omb, pess, bps0, n_active, n_frames,
            arr0, dl0, arr1, dl1, dur, arrivals, acc_stat,
            nbits8, acc_sv, bw_t, bw_v, t_srv, acc_dp, t_npu64):
        ks = jnp.arange(W, dtype=jnp.int32)

        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, bps, rttb, netf, acc_sum, proc, miss, offl, rounds, npu_s = c
            active = head < n_frames
            rounded = n_frames > 0  # traced, always true: _no_fma's gate
            rounded2 = n_frames > -1  # distinct gate: see _true_offload
            t0 = _no_fma(head.astype(jnp.float64) * gamma, rounded)
            npu_free = jnp.maximum(0.0, busy - t0)
            start_bin = jnp.ceil(jnp.maximum(npu_free, 0.0) / grid).astype(jnp.int32)
            # estimator.state(): the belief, not a trace lookup.
            bw_b = bps * pess
            t_up = jnp.where(bw_b > 0.0, nbits8 / bw_b, jnp.inf)  # [R]
            budget = deadline - t_up - rttb  # [R] believed RTT
            fits = t_srv[:, None] <= budget[None, :]  # [J, R]
            a_cand = jnp.where(fits, acc_sv, -jnp.inf)
            j_best = jnp.argmax(a_cand, axis=0).astype(jnp.int32)  # first max
            a_best = jnp.max(a_cand, axis=0)
            r_ok = (budget > 0.0) & jnp.any(fits, axis=0)
            n_l = jnp.floor(jnp.where(r_ok, t_up, 0.0) / gamma)
            n_l = jnp.clip(n_l, 0, W).astype(jnp.int32)  # [R]
            cho1, par1, mh1, ab1, alive1 = _accuracy_dp64(
                dur, acc_dp, arr1, dl1, start_bin, n_frames=W, nbins=NBINS
            )
            nlm1 = jnp.clip(n_l - 1, 0, W - 1)
            nb1 = jnp.ceil(
                (gamma + _no_fma((n_l.astype(jnp.float64) - 1.0) * gamma, rounded)
                 + deadline) / grid
            ).astype(jnp.int32) + 2
            dp_ok = jnp.where(n_l == 0, True, alive1[nlm1] & (start_bin < nb1))
            dp_tot = jnp.where(n_l == 0, 0.0, mh1[nlm1])
            feas = r_ok & dp_ok
            norm = jnp.where(feas, (a_best + dp_tot) / (n_l + 1).astype(jnp.float64), NEG)
            r_star = jnp.argmax(norm).astype(jnp.int32)  # first max = lowest r
            off_exists = feas[r_star]
            off_norm = norm[r_star]

            cho0, par0, mh0, ab0, alive0 = _accuracy_dp64(
                dur, acc_dp, arr0, dl0, start_bin, n_frames=W, nbins=NBINS
            )
            A = jnp.sum((alive0 & (ks < n_active)).astype(jnp.int32), dtype=jnp.int32)
            nb0 = jnp.ceil(
                (_no_fma((A.astype(jnp.float64) - 1.0) * gamma, rounded) + deadline)
                / grid
            ).astype(jnp.int32) + 2
            loc_exists = (A >= 1) & (start_bin < nb0)
            loc_norm = jnp.where(
                loc_exists, mh0[jnp.clip(A - 1, 0, W - 1)] / A.astype(jnp.float64), NEG
            )
            use_loc = loc_exists & (loc_norm > jnp.where(off_exists, off_norm, NEG))
            use_off = off_exists & ~use_loc

            nn = jnp.where(use_off, n_l[r_star], jnp.where(use_loc, A, 0))

            def backtrack(cho, par, b0, upto):
                def bt(b, k):
                    on = k < upto
                    bc = jnp.clip(b, 0, NBINS - 1)
                    pick = jnp.where(on, cho[k, bc], -1)
                    return jnp.where(on & (pick >= 0), par[k, bc], b), pick

                _, picks_rev = jax.lax.scan(
                    bt, b0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
                )
                return picks_rev[::-1]

            picks_off = backtrack(cho1, par1, ab1[nlm1[r_star]], jnp.where(use_off, nn, 0))
            picks_loc = backtrack(cho0, par0, ab0[jnp.clip(A - 1, 0, W - 1)],
                                  jnp.where(use_loc, nn, 0))
            picks = jnp.where(use_off, picks_off, picks_loc)

            # True-world execution of the head offload (decision order:
            # SERVER first, then the NPU frames of the audit fold).
            bps, rttb, netf, acc_sum, proc, miss, offl = _true_offload(
                active=active, use_off=use_off, r_off=r_star, j_off=j_best[r_star],
                t0=t0, deadline=deadline, rtt=rtt, beta=beta, omb=omb,
                bps=bps, rttb=rttb, netf=netf, acc_sum=acc_sum, proc=proc,
                miss=miss, offl=offl, nbits8=nbits8, acc_sv=acc_sv,
                bw_t=bw_t, bw_v=bw_v, t_srv=t_srv, rounded=rounded,
                rounded2=rounded2,
            )

            fa = jnp.where(use_off, gamma, 0.0)
            gate = active & (picks >= 0) & (ks < nn)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, frame_offset=jnp.where(use_off, 1, 0),
                n_frames=n_frames, n_active=n_active, arrivals=fa + arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                picks=picks, gate=gate, free0=free0, acc_sum=acc_sum,
                proc=proc, miss=miss, npu_s=npu_s, W=W, J=J, strict=strict,
            )
            busy_until = jnp.where(use_off | use_loc, free_end, npu_free)
            horizon = jnp.where(
                use_off, n_l[r_star] + 1, jnp.where(use_loc, A, 1)
            ).astype(jnp.int32)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = rounds + active.astype(jnp.int32)
            return head, busy, bps, rttb, netf, acc_sum, proc, miss, offl, rounds, npu_s

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            bps0, rtt,  # belief seeds: init_bps and the pre-loop observe_rtt
            jnp.zeros((), jnp.float64),  # true-link occupancy
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[5], out[6], out[7], out[9], out[10], out[8], out[2]

    return LaneProgram(one, (0,) * 21 + (None,) * 3)


@_online("max_accuracy")
def _run_online_max_accuracy(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        W, R = key
        c = _common(models, group, W)
        grid = np.array([float(s.params["grid"]) for s in group], np.float64)
        arr0 = np.ceil(c.arrivals / grid[:, None]).astype(np.int32)
        dl0 = np.floor((c.arrivals + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        arrivals1 = c.gamma[:, None] + c.arrivals
        arr1 = np.ceil(arrivals1 / grid[:, None]).astype(np.int32)
        dl1 = np.floor((arrivals1 + c.deadline[:, None]) / grid[:, None]).astype(np.int32)
        horizon_t = c.gamma + (c.n_active.astype(np.float64) - 1.0) * c.gamma + c.deadline
        NBINS = _quant_bins(int((np.ceil(horizon_t / grid) + 2).max()))
        with np.errstate(invalid="ignore"):
            dur_f = np.ceil(c.t_npu64[None, :] / grid[:, None])
        dur = np.where(np.isfinite(dur_f), np.minimum(dur_f, NBINS), NBINS).astype(np.int32)
        rtt, bw_t, bw_v, S = _net_arrays(group)
        nbits8, acc_sv = _offload_tables(models, group)
        beta, omb, pess, bps0 = _estimator_arrays(group)
        t0 = time.perf_counter()
        with enable_x64():
            out = _online_accuracy_program(c.W, NBINS, S, c.J, R, strict)(
                c.gamma, c.deadline, rtt, grid, beta, omb, pess, bps0,
                c.n_active, c.n_frames, arr0, dl0, arr1, dl1, dur,
                c.arrivals, c.acc_stat64, nbits8, acc_sv, bw_t, bw_v,
                t_srv, acc_dp, c.t_npu64,
            )
            out = [np.asarray(a) for a in out]
        stats = _collect(c, out[:5], time.perf_counter() - t0, offloaded=out[5])
        return _with_meta(stats, out[6], pess)

    return _stitch(scenarios, _net_group_key, run_group)


# ---------------------------------------------------------------------------
# Max-Utility online: same substitution on the sim_batch utility program,
# keeping its fast-width pass + overflow-lane rerun at the exact cap.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _online_utility_program(W: int, S: int, J: int, R: int, strict: bool, width: int):
    def one(gamma, deadline, rtt, alpha, fps, beta, omb, pess, bps0, n_w, n_frames,
            arrivals, acc_stat, nbits8, acc_sv, bw_t, bw_v, t_srv, acc_dp, t_npu64):
        ks = jnp.arange(W, dtype=jnp.int32)

        def backtrack(u_final, parents, actions):
            slot0 = jnp.argmax(u_final).astype(jnp.int32)

            def bt(s, k):
                ok = s >= 0
                sc = jnp.clip(s, 0, width - 1)
                pick = jnp.where(ok, actions[k, sc], -1)
                return jnp.where(ok, parents[k, sc], s), pick

            _, picks_rev = jax.lax.scan(
                bt, slot0, jnp.arange(W - 1, -1, -1, dtype=jnp.int32)
            )
            return picks_rev[::-1]

        def cand_stats(picks, acc0):
            def f(carry, pick):
                n, a = carry
                takes = pick >= 0
                j = jnp.clip(pick, 0, J - 1)
                return (
                    n + takes.astype(jnp.int32),
                    a + jnp.where(takes, acc_stat[j], 0.0),
                ), None

            (n, a), _ = jax.lax.scan(f, (jnp.int32(0), acc0), picks)
            return n, a

        def cond(c):
            return c[0] < n_frames

        def body(c):
            head, busy, bps, rttb, netf, acc_sum, proc, miss, offl, rounds, npu_s, ovf = c
            active = head < n_frames
            rounded = n_frames > 0  # traced, always true: _no_fma's gate
            rounded2 = n_frames > -1  # distinct gate: see _true_offload
            t0 = _no_fma(head.astype(jnp.float64) * gamma, rounded)
            npu_free = jnp.maximum(0.0, busy - t0)
            # estimator.state(): the belief, not a trace lookup.
            bw_b = bps * pess
            t_up = jnp.where(bw_b > 0.0, nbits8 / bw_b, jnp.inf)  # [R]
            feas = (t_up[:, None] + t_srv[None, :] + rttb) <= deadline  # [R, J]
            rate = jnp.minimum(1.0 / jnp.maximum(t_up, 1e-9), fps)
            score = rate[:, None] + _no_fma(
                alpha * jnp.swapaxes(acc_sv, 0, 1), rounded
            )  # [R, J]
            flat = jnp.where(feas, score, -jnp.inf).reshape(-1)
            off_exists = jnp.any(feas)
            pick_rj = jnp.argmax(flat).astype(jnp.int32)
            r0 = pick_rj // J
            j0 = pick_rj - r0 * J
            t_up0 = jnp.where(off_exists, t_up[r0], 0.0)
            n_l = jnp.clip(jnp.floor(t_up0 / gamma), 0, W).astype(jnp.int32)
            n_plan = jnp.maximum(n_l, n_w - 1)
            win1 = jnp.maximum(jnp.maximum(n_plan, 1).astype(jnp.float64) * gamma, gamma)
            (_, u1, _, _), par1, act1, ov1 = _utility_dp64(
                t_npu64, acc_dp, n_plan, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=gamma, window=win1,
            )
            win2 = jnp.maximum(n_w.astype(jnp.float64) * gamma, gamma)
            (_, u2, _, _), par2, act2, ov2 = _utility_dp64(
                t_npu64, acc_dp, n_w, n_frames=W, width=width,
                gamma=gamma, deadline=deadline, alpha=alpha, npu_free=npu_free,
                first_arrival=jnp.float64(0.0), window=win2,
            )
            ovf = ovf | (active & (ov1 | ov2))
            picks1 = backtrack(u1, par1, act1)
            picks2 = backtrack(u2, par2, act2)
            srv_acc = acc_sv[j0, r0]
            n1, a_off = cand_stats(picks1, srv_acc)
            n2, a_loc = cand_stats(picks2, jnp.float64(0.0))
            p_off = (n1 + 1).astype(jnp.float64)
            h_off = jnp.maximum(n_plan + 1, 1).astype(jnp.float64)
            u_off = jnp.where(
                off_exists, p_off / (h_off * gamma) + alpha * a_off / p_off, NEG
            )
            u_loc = jnp.where(
                n2 > 0,
                n2.astype(jnp.float64) / (n_w.astype(jnp.float64) * gamma)
                + alpha * a_loc / n2.astype(jnp.float64),
                0.0,
            )
            use_off = off_exists & (u_off >= u_loc)  # first candidate wins ties
            use_loc = ~use_off & (n2 > 0)

            nn = jnp.where(use_off, n_plan, jnp.where(use_loc, n_w, 0))
            picks = jnp.where(use_off, picks1, picks2)

            bps, rttb, netf, acc_sum, proc, miss, offl = _true_offload(
                active=active, use_off=use_off, r_off=r0, j_off=jnp.clip(j0, 0, J - 1),
                t0=t0, deadline=deadline, rtt=rtt, beta=beta, omb=omb,
                bps=bps, rttb=rttb, netf=netf, acc_sum=acc_sum, proc=proc,
                miss=miss, offl=offl, nbits8=nbits8, acc_sv=acc_sv,
                bw_t=bw_t, bw_v=bw_v, t_srv=t_srv, rounded=rounded,
                rounded2=rounded2,
            )

            fa = jnp.where(use_off, gamma, 0.0)
            gate = active & (picks >= 0) & (ks < nn)
            free0 = jnp.maximum(npu_free, 0.0)
            free_end, acc_sum, proc, miss, npu_s = _audit_scan(
                head=head, frame_offset=jnp.where(use_off, 1, 0),
                n_frames=n_frames, n_active=n_w, arrivals=fa + arrivals,
                deadline=deadline, t_npu64=t_npu64, acc_stat=acc_stat,
                picks=picks, gate=gate, free0=free0, acc_sum=acc_sum,
                proc=proc, miss=miss, npu_s=npu_s, W=W, J=J, strict=strict,
            )
            busy_until = jnp.where(use_off | use_loc, free_end, npu_free)
            horizon = jnp.where(
                use_off, n_plan + 1, jnp.where(use_loc, n_w, 1)
            ).astype(jnp.int32)
            head = jnp.where(active, head + horizon, head)
            busy = jnp.where(active, t0 + busy_until, busy)
            rounds = rounds + active.astype(jnp.int32)
            return head, busy, bps, rttb, netf, acc_sum, proc, miss, offl, rounds, npu_s, ovf

        init = (
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            bps0, rtt,  # belief seeds: init_bps and the pre-loop observe_rtt
            jnp.zeros((), jnp.float64),  # true-link occupancy
            jnp.zeros((), jnp.float64), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float64),
            jnp.zeros((), bool),
        )
        out = jax.lax.while_loop(cond, body, init)
        return out[5], out[6], out[7], out[9], out[10], out[8], out[2], out[11]

    return LaneProgram(one, (0,) * 17 + (None,) * 3)


@_online("max_utility")
def _run_online_max_utility(models, scenarios, strict):
    t_srv = np.array([m.t_server for m in models], np.float64)
    acc_dp = np.array(
        [m.acc_npu[max(m.acc_npu)] if m.acc_npu else 0.0 for m in models], np.float64
    )

    def run_group(key, group):
        W, R = key
        c = _common(models, group, W)
        alpha = np.array([float(s.params["alpha"]) for s in group], np.float64)
        fps = np.array([s.stream.fps for s in group], np.float64)
        rtt, bw_t, bw_v, S = _net_arrays(group)
        nbits8, acc_sv = _offload_tables(models, group)
        beta, omb, pess, bps0 = _estimator_arrays(group)
        lane_args = (c.gamma, c.deadline, rtt, alpha, fps, beta, omb, pess, bps0,
                     c.n_active, c.n_frames, c.arrivals, c.acc_stat64,
                     nbits8, acc_sv, bw_t, bw_v)
        t0 = time.perf_counter()
        with enable_x64():
            out = _online_utility_program(c.W, S, c.J, R, strict, _UTIL_FAST_WIDTH)(
                *lane_args, t_srv, acc_dp, c.t_npu64,
            )
            out = [np.array(a) for a in out]
            overflowed = np.nonzero(out[7])[0]
            if overflowed.size:
                # A Pareto front outgrew the fast width in these lanes: rerun
                # just them at the reference prune cap and splice back.
                sub = _online_utility_program(c.W, S, c.J, R, strict, _UTIL_CAP)(
                    *(a[overflowed] for a in lane_args), t_srv, acc_dp, c.t_npu64,
                )
                for dst, src in zip(out[:7], sub[:7]):
                    dst[overflowed] = np.asarray(src)
        stats = _collect(c, out[:5], time.perf_counter() - t0, offloaded=out[5])
        return _with_meta(stats, out[6], pess)

    return _stitch(scenarios, _net_group_key, run_group)
