"""Schedule data types shared by all scheduling policies.

A *round* is the paper's planning unit: one head frame considered for
offload plus the ``n_l`` frames that arrive while the link is busy.  Each
policy returns a ``RoundPlan``; the simulator executes plans back-to-back
and re-invokes the policy whenever the link frees up.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Sequence


class Where(enum.Enum):
    NPU = "npu"  # local quantized path
    SERVER = "server"  # edge offload
    SKIP = "skip"  # dropped (Max-Utility only)


@dataclass(frozen=True)
class Decision:
    """(i, j, r) triple from the paper, plus the execution window we planned."""

    frame: int  # i — index relative to the round's head frame
    where: Where
    model: int = -1  # j — index into the profile list; -1 for SKIP
    resolution: int = -1  # r — offload resolution; r_max implied for NPU
    start: float = 0.0  # planned processing start (round-relative seconds)
    finish: float = 0.0  # planned completion incl. network for offloads

    def is_processed(self) -> bool:
        return self.where is not Where.SKIP


@dataclass
class RoundPlan:
    """One scheduling round.  ``horizon`` = frames consumed (>= 1)."""

    decisions: list[Decision] = field(default_factory=list)
    horizon: int = 1
    expected_accuracy_sum: float = 0.0
    expected_utility: float = 0.0
    npu_busy_until: float = 0.0  # relative to round start; carried to next round
    net_busy_until: float = 0.0

    @property
    def processed(self) -> int:
        return sum(1 for d in self.decisions if d.is_processed())


@dataclass
class StreamStats:
    """Accumulated over a simulated stream; what the figures plot."""

    frames_total: int = 0
    frames_processed: int = 0
    frames_missed_deadline: int = 0
    frames_offloaded: int = 0  # subset of processed that ran on the edge
    accuracy_sum: float = 0.0
    elapsed: float = 0.0
    schedule_calls: int = 0
    schedule_time: float = 0.0
    # NPU busy-seconds, filled by engines that account occupancy on device
    # (core/sim_batch); 0.0 where untracked (the reference loops).
    npu_busy_s: float = 0.0

    @property
    def mean_accuracy(self) -> float:
        """Paper's Max-Accuracy objective: mean over *all* frames (missed = 0)."""
        if self.frames_total == 0:
            return 0.0
        return self.accuracy_sum / self.frames_total

    @property
    def processed_accuracy(self) -> float:
        if self.frames_processed == 0:
            return 0.0
        return self.accuracy_sum / self.frames_processed

    def utility(self, alpha: float) -> float:
        """Paper Eq. (9): rate + alpha * mean accuracy over processed frames."""
        if self.elapsed <= 0:
            return 0.0
        rate = self.frames_processed / self.elapsed
        return rate + alpha * self.processed_accuracy


@dataclass(frozen=True)
class PlanError:
    """One feasibility violation of a plan; ``frame`` is round-relative.

    Stringifies to the human-readable message, so audit loops can use the
    structured ``frame`` field while assertions still print useful text.
    """

    frame: int
    message: str

    def __str__(self) -> str:
        return self.message


def validate_plan(
    plan: RoundPlan,
    *,
    gamma: float,
    deadline: float,
    tol: float = 1e-9,
) -> list[PlanError]:
    """Feasibility audit used by tests and the simulator (defence in depth).

    Checks the paper's constraints (2)/(3)/(10)/(11): every processed frame
    finishes within ``arrival + deadline``; NPU decisions do not overlap;
    offloads do not overlap on the link.
    """
    errors: list[PlanError] = []
    npu_prev_end = -float("inf")
    for d in sorted(plan.decisions, key=lambda d: (d.start, d.frame)):
        if not d.is_processed():
            continue
        arrival = d.frame * gamma
        if d.finish > arrival + deadline + tol:
            errors.append(PlanError(
                d.frame, f"frame {d.frame}: finish {d.finish:.4f} > deadline {arrival + deadline:.4f}"
            ))
        if d.start + tol < arrival:
            errors.append(PlanError(
                d.frame, f"frame {d.frame}: starts {d.start:.4f} before arrival {arrival:.4f}"
            ))
        if d.where is Where.NPU:
            if d.start + tol < npu_prev_end:
                errors.append(PlanError(
                    d.frame, f"frame {d.frame}: NPU overlap ({d.start:.4f} < {npu_prev_end:.4f})"
                ))
            npu_prev_end = d.finish if d.finish > npu_prev_end else npu_prev_end
    return errors


def plan_accuracy(decisions: Sequence[Decision], models, stream) -> float:
    total = 0.0
    for d in decisions:
        if not d.is_processed():
            continue
        m = models[d.model]
        if d.where is Where.SERVER:
            total += m.accuracy(d.resolution, where="server")
        else:
            total += m.accuracy(stream.r_max, where="npu")
    return total
