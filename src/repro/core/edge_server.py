"""Multi-stream edge-server scheduling: N clients share one uplink + one edge.

The paper (and this repo's §IV/§V solvers) plan for ONE phone talking to an
idle edge server.  This module is the first step toward the ROADMAP's
"edge serving a fleet" north star: an :class:`EdgeServerScheduler` admits N
concurrent :class:`EdgeClient` streams, splits the shared uplink bandwidth and
the server's worker pool across them, and lets each client fall back to its
local NPU plan when the edge is saturated.  The per-stream Max-Accuracy /
Max-Utility solvers are reused unchanged as the inner loop — a client simply
plans against the *allocated* share of the link instead of the whole link, and
both solvers already degrade to a pure-local plan when their bandwidth is too
small to offload (see docs/scheduling.md, "Edge-server admission").

Allocation policies (``EdgeServerScheduler(policy=...)``):

  weighted_fair  static weighted share: client i may lease at most
                 ``B * w_i / sum_j w_j`` of the link, further clipped to what
                 is left unleased — so concurrent grants never exceed B.
  priority       weighted-fair with effective weight ``w_i * 2**priority_i``,
                 plus slot reservation: a client is denied an offload slot
                 while every free server worker is "spoken for" by a distinct
                 higher-priority client that holds no slot.
  fifo           the naive baseline: every client assumes it owns the whole
                 link and the server admits jobs first-come-first-served.
                 Under contention the fluid link model (simulator.simulate_multi)
                 stretches the overlapping uploads and deadlines blow up —
                 this is the strawman the coordinated policies beat.

The scheduler is deliberately *mechanism only*: it never inspects frames or
plans, just grants (bandwidth, slot) leases.  The audited ground truth —
whether an offload actually made its deadline once the shared link and the
server queue are accounted for — lives in ``simulator.simulate_multi``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .profiles import ModelProfile, NetworkState, StreamSpec
from .registry import PolicySpec

ALLOCATION_POLICIES = ("weighted_fair", "priority", "fifo")

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Pure allocation arithmetic.  These are the scheduler's numeric semantics
# stripped of all lease bookkeeping, shared verbatim by the stateful
# EdgeServerScheduler below, the fluid-link reference loop
# (simulator.simulate_multi), and — expression by expression — the
# vectorized fleet backend (core/sim_multi_batch), which re-renders them as
# f64 tensor programs.  Keep them dependency-free and side-effect-free.
# ---------------------------------------------------------------------------


def effective_weight(policy: str, weight: float, priority: int) -> float:
    """Allocation weight of one client: raw weight, or priority-boosted
    ``w * 2**p`` under the ``priority`` policy."""
    if policy == "priority":
        return weight * (2.0 ** priority)
    return weight


def fair_share(bandwidth_bps: float, w_eff: float, total_w_eff: float) -> float:
    """The static weighted-fair bandwidth share ``B * w_i / sum_j w_j``."""
    return bandwidth_bps * w_eff / total_w_eff


def fluid_rates(
    bandwidth_bps: float,
    weights: Sequence[float],
    caps: Sequence[float],
    *,
    eps: float = _EPS,
) -> list[float]:
    """Weighted max-min (water-filling) split of one link across transfers.

    Each transfer asks for its weight-proportional share but never exceeds
    its ``cap``; capped transfers return their leftover to the pool.  When
    the caps are scheduler grants summing to <= B this degenerates to
    "everyone transmits at the granted rate"; with infinite caps (fifo) it
    is plain weighted processor sharing.  This is the reference fluid model
    of ``simulator.simulate_multi`` (tested in tests/test_edge_server.py).
    """
    rates = [0.0] * len(weights)
    active = list(range(len(weights)))
    remaining = max(bandwidth_bps, 0.0)
    while active and remaining > eps:
        total_w = sum(weights[i] for i in active) or 1.0
        capped = [i for i in active if caps[i] <= remaining * weights[i] / total_w + eps]
        if not capped:
            for i in active:
                rates[i] = remaining * weights[i] / total_w
            return rates
        for i in capped:
            rates[i] = caps[i]
            remaining -= caps[i]
        remaining = max(remaining, 0.0)
        active = [i for i in active if i not in capped]
    return rates


@dataclass
class EdgeClient:
    """One tenant stream: a phone running the FastVA controller.

    ``weight`` steers weighted-fair bandwidth shares; ``priority`` (higher =
    more important) steers the ``priority`` policy.  ``policy`` picks the
    *inner* per-stream solver as a registry :class:`PolicySpec` (or a bare
    registered name); the legacy ``policy_name``/``alpha`` pair is still
    accepted when ``policy`` is left unset.
    """

    client_id: int
    stream: StreamSpec
    models: Sequence[ModelProfile]
    weight: float = 1.0
    priority: int = 0
    policy: PolicySpec | str | None = None
    policy_name: str = "max_accuracy"  # legacy; used only when policy is None
    alpha: float | None = None  # legacy; used only when policy is None

    def __post_init__(self) -> None:
        self.policy = PolicySpec.coerce(self.policy, policy_name=self.policy_name, alpha=self.alpha)
        self.policy_name = self.policy.name
        self._policy = self.policy.build()

    def plan(self, net: NetworkState, *, npu_free: float):
        """One inner-solver round against this client's allocated bandwidth."""
        return self._policy(list(self.models), self.stream, net, npu_free=npu_free)


@dataclass
class _Lease:
    """An in-flight offload: granted uplink rate + a server worker slot.

    The link portion frees when the upload completes (``release_link``); the
    worker slot frees when the server finishes the job (``release``).
    """

    client_id: int
    bps: float
    link_active: bool = True


@dataclass
class SchedulerAudit:
    """Counters the tests and benchmarks assert on (see tests/test_edge_server.py)."""

    grants: int = 0
    denials: int = 0
    max_concurrent_bps: float = 0.0  # peak sum of simultaneously leased bandwidth
    max_concurrent_jobs: int = 0


class EdgeServerScheduler:
    """Admission + bandwidth allocation for N streams sharing one edge server.

    Usage (the simulator drives this loop):

        grant_bps = sched.allocate(client_id, t, net)   # 0.0 => go local
        ... client plans against NetworkState(grant_bps, net.rtt) ...
        sched.register(client_id, grant_bps)            # if the plan offloads
        ... upload completes ...
        sched.release_link(client_id)                   # frees bandwidth
        ... server job completes ...
        sched.release(client_id)                        # frees the worker slot

    ``capacity`` is the server's worker-slot count: at most ``capacity``
    offload jobs may be in flight (uploading or executing) at once — except
    under the uncoordinated ``fifo`` policy, where admission is a no-op and
    the pain shows up as queueing delay instead.

    Server-model capacity is rationed with a backlog gate: ``register`` feeds
    each admitted job's server seconds into an aggregate busy-until estimate
    (work divided across the ``capacity`` workers), and ``allocate`` denies
    offloads while the expected queue delay exceeds ``backlog_limit`` seconds.
    Without this gate a single client at 30 fps can legally submit 69 ms jobs
    every 33 ms and build an unbounded queue that misses every deadline.
    """

    def __init__(
        self,
        clients: Sequence[EdgeClient],
        *,
        policy: str = "weighted_fair",
        capacity: int = 4,
        backlog_limit: float = 0.0,
    ):
        if policy not in ALLOCATION_POLICIES:
            raise ValueError(f"unknown allocation policy {policy!r}; want one of {ALLOCATION_POLICIES}")
        self.clients = {c.client_id: c for c in clients}
        if len(self.clients) != len(clients):
            raise ValueError("duplicate client_id in clients")
        self.policy = policy
        self.capacity = int(capacity)
        self.backlog_limit = float(backlog_limit)
        # One client may hold several leases at once (a policy that offloads
        # several frames per round, or an upload stretched past the client's
        # next round) — hence a list per client, drained FIFO.
        self.leases: dict[int, list[_Lease]] = {}
        self.server_busy_until = 0.0  # abs time the admitted server work drains
        self.audit = SchedulerAudit()

    # -- weights -----------------------------------------------------------
    def _effective_weight(self, c: EdgeClient) -> float:
        return effective_weight(self.policy, c.weight, c.priority)

    def _total_weight(self) -> float:
        return sum(self._effective_weight(c) for c in self.clients.values()) or 1.0

    # -- allocation --------------------------------------------------------
    def allocate(self, client_id: int, t: float, net: NetworkState) -> float:
        """Grant an uplink rate (bps) for one offload round; 0.0 means denied.

        A grant is only a *quote* — it reserves nothing until ``register`` is
        called (the client may plan a pure-local round and never lease).
        """
        c = self.clients[client_id]
        if self.policy == "fifo":
            # Uncoordinated: everyone believes the link is theirs.
            self.audit.grants += 1
            return net.bandwidth_bps

        # ONE of the client's own still-held leases (typically the server
        # tail of its previous round) never blocks its next request — but
        # only one, else a single client could queue unboundedly many jobs
        # past ``capacity`` whenever backlog_limit is loosened.
        own = len(self.leases.get(client_id, ()))
        effective = self._n_leases() - min(own, 1)
        backlogged = self.server_busy_until - t > self.backlog_limit
        if effective >= self.capacity or backlogged or self._slots_reserved_above(c):
            self.audit.denials += 1
            return 0.0

        used = self._link_reserved(exclude=client_id)
        available = max(net.bandwidth_bps - used, 0.0)
        share = fair_share(net.bandwidth_bps, self._effective_weight(c), self._total_weight())
        grant = min(share, available)
        if grant <= 0.0:
            self.audit.denials += 1
            return 0.0
        self.audit.grants += 1
        return grant

    def _n_leases(self) -> int:
        return sum(len(ls) for ls in self.leases.values())

    def _link_reserved(self, exclude: int | None = None) -> float:
        """Bandwidth currently reserved on the link.  A client's uplink is
        serial (the simulator transmits its oldest upload only), so its many
        leases reserve max(bps), not the sum."""
        return sum(
            max((l.bps for l in ls if l.link_active), default=0.0)
            for cid, ls in self.leases.items()
            if cid != exclude
        )

    def _slots_reserved_above(self, c: EdgeClient) -> bool:
        """Priority policy: hold free slots for higher-priority slotless clients."""
        if self.policy != "priority":
            return False
        free = self.capacity - self._n_leases()
        higher_waiting = sum(
            1
            for other in self.clients.values()
            if other.priority > c.priority and not self.leases.get(other.client_id)
        )
        return free <= higher_waiting

    # -- lease lifecycle ---------------------------------------------------
    def register(self, client_id: int, bps: float, *, t: float = 0.0, server_s: float = 0.0) -> None:
        """The client's round really does offload: consume the granted lease.

        ``server_s`` is the admitted job's server-side service time; it feeds
        the backlog gate (conservatively anchored at ``t``, i.e. as if the job
        reached the server instantly — uploads only push it later).
        """
        if self.policy != "fifo":
            self.server_busy_until = max(self.server_busy_until, t) + server_s / max(self.capacity, 1)
        self.leases.setdefault(client_id, []).append(_Lease(client_id, bps))
        self.audit.max_concurrent_jobs = max(self.audit.max_concurrent_jobs, self._n_leases())
        if self.policy != "fifo":
            self.audit.max_concurrent_bps = max(
                self.audit.max_concurrent_bps, self._link_reserved()
            )

    def release_link(self, client_id: int) -> None:
        """The client's oldest in-flight upload finished: free its bandwidth."""
        for lease in self.leases.get(client_id, []):
            if lease.link_active:
                lease.link_active = False
                return

    def release(self, client_id: int) -> None:
        """The client's oldest admitted job left the server: free its slot."""
        ls = self.leases.get(client_id)
        if ls:
            ls.pop(0)
            if not ls:
                del self.leases[client_id]

    def reset(self) -> None:
        """Forget all leases, backlog, and audit counters.

        ``simulate_multi`` calls this on entry so one scheduler can be
        replayed across runs: without it the backlog estimate
        (``server_busy_until``) from a previous run — whose clock also
        started at 0 — would deny every offload of the next one.
        """
        self.leases.clear()
        self.server_busy_until = 0.0
        self.audit = SchedulerAudit()


def make_fleet(
    n: int,
    *,
    stream: StreamSpec | None = None,
    models: Sequence[ModelProfile] | None = None,
    policy: PolicySpec | str | None = None,
    policy_name: str = "max_accuracy",
    alpha: float | None = None,
    weights: Sequence[float] | None = None,
    priorities: Sequence[int] | None = None,
) -> list[EdgeClient]:
    """Convenience: N identical tenants (benchmarks, tests, the demo)."""
    from .profiles import PAPER_MODELS, PAPER_STREAM

    stream = stream if stream is not None else PAPER_STREAM
    models = list(models) if models is not None else list(PAPER_MODELS)
    # One coercion up front so all N clients share a single validated spec.
    policy = PolicySpec.coerce(policy, policy_name=policy_name, alpha=alpha)
    return [
        EdgeClient(
            client_id=i,
            stream=stream,
            models=models,
            weight=weights[i] if weights is not None else 1.0,
            priority=priorities[i] if priorities is not None else 0,
            policy=policy,
        )
        for i in range(n)
    ]
