"""Mesh scale-out for the batched sweep engines.

Every planner program in :mod:`sim_batch` / :mod:`sim_multi_batch` has the
same calling convention: ``n_lane`` leading arguments carry the scenario
(lane) batch on axis 0 and the trailing arguments are shared tables
(``in_axes = (0,) * n_lane + (None,) * k``).  :class:`LaneProgram` wraps
``jit(vmap(one))`` once per shape bucket and routes calls through
:func:`run_sharded`:

* **single device** (or ``REPRO_SWEEP_SHARD=0``): the plain jitted program
  runs exactly as before — bit-identical to the pre-sharding engine, so
  every golden-lattice and hypothesis equivalence contract keeps holding
  without a mesh in the loop;
* **multi device**: lane args are padded on axis 0 to a multiple of the
  sweep mesh (by repeating the final lane — planner lanes are independent,
  so a duplicated lane computes a result we slice off, the same inert-
  padding argument as the W/NBINS shape buckets), the program runs under
  ``shard_map`` over the mesh's ``scenario`` axis with shared tables
  replicated, and outputs are sliced back to the true lane count.

The mesh comes from :func:`repro.launch.mesh.make_sweep_mesh` and the
partition specs from :func:`repro.sharding.rules.sweep_rules` — the rules'
divisibility guard is what certifies the padded lane count actually
shards.

Scenario lane buffers are deliberately **not** donated.  A planner never
reads a lane argument after the call, so donation looks free — but on
this jax (0.4.37/CPU) an executable compiled with ``donate_argnums`` and
*reloaded from the persistent compilation cache* returns corrupted stats
for a nondeterministic subset of lanes (reproduced and bisected to
donation by the scale bench: clean with donation off, hundreds of zeroed
lanes with it on).  The inputs are host-built numpy chunks anyway, so
donation never had an allocation to reuse here — correctness wins.
"""
from __future__ import annotations

import os
from functools import lru_cache

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..launch.mesh import make_sweep_mesh
from ..sharding.rules import MeshRules, sweep_rules

def _shard_enabled() -> bool:
    return os.environ.get("REPRO_SWEEP_SHARD", "1") != "0"


class LaneProgram:
    """One compiled planner program: ``jit(vmap(one, in_axes))`` plus the
    lane metadata :func:`run_sharded` needs to scale it across a mesh.

    ``in_axes`` must be ``(0,) * n_lane + (None,) * n_shared`` — lane args
    lead, shared tables trail.  Calling the instance dispatches through
    :func:`run_sharded`; the raw single-device executable stays reachable
    as ``.jit`` (tests use its ``_cache_size`` for compile counting).
    """

    def __init__(self, one, in_axes: tuple):
        n_lane = 0
        for ax in in_axes:
            if ax != 0:
                break
            n_lane += 1
        if any(ax is not None for ax in in_axes[n_lane:]):
            raise ValueError(
                f"lane args must lead: in_axes must be (0,)*n + (None,)*k, got {in_axes}"
            )
        self.n_lane = n_lane
        self.n_args = len(in_axes)
        self._vmapped = jax.vmap(one, in_axes=in_axes)
        # no donate_argnums: see the module docstring's persistent-cache hazard
        self.jit = jax.jit(self._vmapped)

    def __call__(self, *args):
        return run_sharded(self, *args)


@lru_cache(maxsize=None)
def _sharded_jit(prog: LaneProgram, mesh: Mesh):
    """jit(shard_map(program)) over the sweep mesh, one per (program, mesh)."""
    rules = MeshRules(mesh, sweep_rules(mesh))
    # Resolved at the mesh extent itself: padding guarantees divisibility,
    # and the rules' guard would replicate (never mis-shard) anything else.
    lane = rules._resolve((mesh.size,), ("scenario",))
    assert lane != P(), "sweep mesh must expose a scenario/batch axis"
    in_specs = tuple(lane if i < prog.n_lane else P() for i in range(prog.n_args))
    sm = shard_map(
        prog._vmapped, mesh=mesh, in_specs=in_specs,
        out_specs=lane, check_rep=False,
    )
    return jax.jit(sm)


def run_sharded(prog: LaneProgram, *args):
    """Run ``prog`` over its lane batch, sharded across the sweep mesh.

    Single-device meshes (and ``REPRO_SWEEP_SHARD=0``) take the plain
    jitted path — bit-identical to the unsharded engine.  Multi-device
    meshes pad lanes to the mesh extent by repeating the last lane, shard,
    and slice outputs back to the true batch.
    """
    mesh = make_sweep_mesh()
    if mesh.size == 1 or not _shard_enabled():
        return prog.jit(*args)
    B = int(np.shape(args[0])[0])
    pad = (-B) % mesh.size
    if pad:
        args = tuple(
            np.concatenate([np.asarray(a), np.repeat(np.asarray(a)[-1:], pad, axis=0)])
            if i < prog.n_lane else a
            for i, a in enumerate(args)
        )
    out = _sharded_jit(prog, mesh)(*args)
    return tuple(np.asarray(o)[:B] for o in out)
