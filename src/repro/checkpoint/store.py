"""Checkpointing: pytree <-> directory of .npy shards + JSON manifest.

Design goals (the 1000-node story):
  * **atomicity** — writes go to ``step_N.tmp/`` then os.rename, so a dead
    writer never leaves a half checkpoint that restore would trust;
  * **async** — ``AsyncCheckpointer`` snapshots to host memory on-thread and
    writes on a background thread, so the train loop never blocks on disk;
  * **resharding restore** — arrays are stored unsharded (gathered) with the
    logical-axes manifest, so a restart on a DIFFERENT mesh re-applies the
    sharding rules of the new mesh (elastic scaling path);
  * **manifest-checked** — structure + shapes + dtypes verified on restore.

Storage is numpy .npy per leaf (flattened path as filename).  On a real
cluster the directory would live on a parallel FS / object store; the
interface (save/restore/latest_step) is what the runtime depends on.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointMeta:
    step: int
    timestamp: float
    leaf_paths: list[str]
    shapes: list[list[int]]
    dtypes: list[str]
    extra: dict


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path).replace("'", "").replace("[", ".").replace("]", "")
        out.append((key.strip("."), leaf))
    return out, treedef


def save(directory: str | os.PathLike, step: int, tree: Any, extra: dict | None = None) -> Path:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    base = Path(directory)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(tree)
    meta = CheckpointMeta(
        step=step,
        timestamp=time.time(),
        leaf_paths=[k for k, _ in leaves],
        shapes=[list(np.shape(v)) for _, v in leaves],
        dtypes=[str(np.asarray(v).dtype) for _, v in leaves],
        extra=extra or {},
    )
    for key, leaf in leaves:
        np.save(tmp / f"{key}.npy", np.asarray(leaf))
    (tmp / "manifest.json").write_text(json.dumps(dataclasses.asdict(meta)))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str | os.PathLike) -> int | None:
    base = Path(directory)
    if not base.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in base.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def _load(directory: Path, like: Any) -> Any:
    leaves, treedef = _flatten(like)
    meta = json.loads((directory / "manifest.json").read_text())
    stored = dict(zip(meta["leaf_paths"], zip(meta["shapes"], meta["dtypes"])))
    out = []
    for key, leaf in leaves:
        if key not in stored:
            raise ValueError(f"checkpoint missing leaf {key!r}")
        shape, dtype = stored[key]
        want = list(np.shape(leaf))
        if shape != want:
            raise ValueError(f"leaf {key!r}: checkpoint shape {shape} != expected {want}")
        arr = np.load(directory / f"{key}.npy")
        out.append(arr)
    flat_leaves = [l for _, l in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    ), meta


def restore(directory: str | os.PathLike, step: int, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shapes verified)."""
    path = Path(directory) / f"step_{step:08d}"
    tree, meta = _load(path, like)
    return tree, meta["extra"]


def restore_resharded(
    directory: str | os.PathLike, step: int, like: Any, shardings: Any
) -> tuple[Any, dict]:
    """Restore and place with the NEW mesh's shardings (elastic restart)."""
    tree, extra = restore(directory, step, like)
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
        tree,
        shardings,
    )
    return placed, extra


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread checkpointing.

    ``save(step, tree)`` copies device arrays to host (the only blocking
    part), enqueues, and returns; a daemon thread persists in order.  A
    bounded queue applies back-pressure if disk cannot keep up with the
    checkpoint cadence.  ``wait()`` drains (used at shutdown and in tests).
    """

    def __init__(self, directory: str | os.PathLike, max_pending: int = 2):
        self.directory = Path(directory)
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._errors: list[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree, extra = item
            try:
                save(self.directory, step, host_tree, extra)
            except BaseException as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot
        self._q.put((step, host_tree, extra))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
