from .store import (  # noqa: F401
    AsyncCheckpointer,
    CheckpointMeta,
    latest_step,
    restore,
    restore_resharded,
    save,
)
