from .rules import MeshRules, batch_axes, serve_rules, train_rules  # noqa: F401
