"""Logical-axis -> mesh-axis rules, with divisibility and reuse guards.

One rule table serves every architecture: a rule maps a logical axis name
("mlp", "heads", "kv_seq", ...) to a mesh axis or tuple of mesh axes.  When a
spec is resolved, an axis is dropped (replicated) if (a) the dimension size
is not divisible by the mesh extent, or (b) any of its mesh axes was already
consumed by an earlier dimension of the same tensor.  This makes rules safe
to apply across 11 archs x many shapes without per-tensor case analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.common import ParamSpec

Rules = Mapping[str, Any]  # logical name -> mesh axis | tuple of axes | None


# Batch always spans the pod axis first so cross-pod traffic is pure DP.
def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def train_rules(mesh: Mesh, *, fsdp: bool = True) -> dict[str, Any]:
    b = batch_axes(mesh)
    return {
        "batch": b,
        "embed": "data" if fsdp else None,  # FSDP/ZeRO-3 shard of the non-TP dim
        "embed_tp": "model",  # input-embedding D dim (gather stays local)
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "vocab": "model",
        "expert": "model",  # EP
        "seq": None,
        "act_seq": "model",  # Megatron-SP style activation sharding between blocks
        "kv_seq": "model",
        "long_kv_seq": b[-1:] + ("model",) if b else ("model",),
        "conv_out": "model",
        "conv_in": None,
        "layers": None,
        "patch": None,
        "channels": None,
        "spatial": None,
    }


def serve_rules(mesh: Mesh) -> dict[str, Any]:
    r = train_rules(mesh, fsdp=False)
    r["embed"] = None
    return r


def sweep_rules(mesh: Mesh) -> dict[str, Any]:
    """Scenario-grid sweeps: one logical axis, the (padded) lane batch.

    On the dedicated sweep mesh this is the ``scenario`` axis; on a
    production mesh the lane batch spans the pure-DP batch axes instead, so
    the same rule table serves both topologies.  The divisibility guard in
    :meth:`MeshRules._resolve` is the enforcement point for the engines'
    padding invariant — an unpadded lane count that does not divide the
    mesh resolves to replicated, never to a wrong shard.
    """
    b = batch_axes(mesh)
    if "scenario" in mesh.axis_names:
        b = b + ("scenario",)
    return {"scenario": b}


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    rules: Mapping[str, Any]

    def _resolve(self, sizes: Sequence[int], axes: Sequence[str | None]) -> P:
        used: set[str] = set()
        out: list[Any] = []
        for size, name in zip(sizes, axes):
            entry = self.rules.get(name) if name else None
            if entry is None:
                out.append(None)
                continue
            mesh_axes = entry if isinstance(entry, tuple) else (entry,)
            mesh_axes = tuple(a for a in mesh_axes if a in self.mesh.axis_names and a not in used)
            if not mesh_axes:
                out.append(None)
                continue
            extent = int(np.prod([self.mesh.shape[a] for a in mesh_axes]))
            if extent <= 1 or size % extent != 0:
                out.append(None)
                continue
            used.update(mesh_axes)
            out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def spec_sharding(self, s: ParamSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self._resolve(s.shape, s.axes))

    def tree_shardings(self, specs) -> Any:
        return jax.tree.map(
            self.spec_sharding, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
        )

    def logical(self, sizes: Sequence[int], axes: Sequence[str | None]) -> NamedSharding:
        return NamedSharding(self.mesh, self._resolve(sizes, axes))

    def constrain(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.logical(x.shape, axes))
