from .engine import EndpointStats, FrameResult, ModelEndpoint, VideoServer, make_synthetic_video  # noqa: F401
