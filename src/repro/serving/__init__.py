from .engine import (  # noqa: F401
    BatchedEndpoint,
    BatchStats,
    EdgeBatchServer,
    EndpointStats,
    FrameResult,
    ModelEndpoint,
    OffloadRequest,
    VideoServer,
    make_synthetic_video,
)
