from .calibrate import (  # noqa: F401
    CalibratedModel,
    Calibration,
    CalibrationConfig,
    calibrate,
    load_calibration,
    save_calibration,
    train_classifier,
)
from .engine import (  # noqa: F401
    BatchedEndpoint,
    BatchStats,
    EdgeBatchServer,
    EndpointStats,
    FrameResult,
    ModelEndpoint,
    OffloadRequest,
    VideoServer,
    degrade_frame,
    make_synthetic_video,
)
