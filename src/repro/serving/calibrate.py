"""Measured-profile calibration: ModelProfiles from executing the real paths.

The paper's Table II numbers (t_npu = 52 ms for ResNet-50, etc.) were
measured on a phone NPU we don't have.  ``core.profiles.PAPER_MODELS`` keeps
them as the paper-faithful fallback; this module produces the measured
alternative for the host we DO have:

  t_npu      median wall time of the int8 variant whose matmuls execute in
             ``kernels/npu_matmul``'s w8a8 Pallas kernel (interpret mode on
             CPU, Mosaic on TPU) — real quantized arithmetic, not a constant.
  t_server   median wall time of the full-precision "edge" variant.
  acc_*      top-1 accuracy on held-out ``make_synthetic_video`` frames;
             ``acc_server[r]`` is scored on frames degraded to offload
             resolution ``r`` (``engine.degrade_frame``), so the planner's
             resolution knob trades off measured accuracy, not a typed curve.

``calibrate()`` returns both the live endpoints (so a serving run reuses the
already-trained, already-jitted variants) and a JSON artifact whose
``"models"`` entries are exactly the payload dicts ``ScenarioSpec`` accepts:

    art = json.load(open("calibration.json"))
    spec = ScenarioSpec(models=art["models"], ...)

Per-batch-size latency tables, fp32/int8 top-1 agreement, and quantization
error stats ride along under each model's ``"provenance"`` key (ignored by
the ScenarioSpec loader, consumed by benchmarks/roofline_bench.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Mapping

# Default training budget per known classifier: enough to separate the
# fp32/int8 accuracy profiles on the synthetic video distribution.
TRAIN_STEPS = {"resnet-50": 150, "squeezenet": 400}

SCHEMA = "repro/calibration@1"


@dataclasses.dataclass(frozen=True)
class CalibrationConfig:
    """Protocol knobs.  ``smoke()`` is the CI-sized variant — same code path,
    smaller training/holdout/repeat budgets."""

    model_names: tuple[str, ...] = ("resnet-50", "squeezenet")
    n_classes: int = 10
    res: int = 32  # synthetic frame H=W (smoke archs take any spatial size)
    seed: int = 0
    train_steps: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: dict(TRAIN_STEPS)
    )
    batch_sizes: tuple[int, ...] = (1, 2, 4, 8)  # serving bucket sizes to time
    warmup: int = 2  # per-shape calls before the clock starts
    repeats: int = 5  # timed calls; median reported
    holdout_frames: int = 256  # accuracy-scoring stream length
    resolutions: tuple[int, ...] | None = None  # None -> stream defaults
    r_ref: int = 224  # the paper's full offload resolution (degrade anchor)
    interpret: bool | None = None  # kernel mode; None = auto (Mosaic on TPU)

    @staticmethod
    def smoke(seed: int = 0) -> "CalibrationConfig":
        return CalibrationConfig(
            seed=seed,
            train_steps={"resnet-50": 40, "squeezenet": 120},
            batch_sizes=(1, 2),
            warmup=1,
            repeats=2,
            holdout_frames=64,
        )


@dataclasses.dataclass
class CalibratedModel:
    """One calibrated classifier: the ScenarioSpec-loadable payload plus the
    live endpoints a serving run can deploy without retraining."""

    payload: dict[str, Any]
    npu_endpoint: Any  # ModelEndpoint (int8 weights, Pallas-kernel matmuls)
    edge_endpoint: Any  # ModelEndpoint (full precision)
    forward: Callable[..., Any]  # (params, x) -> logits
    params: Any
    qparams: Any


@dataclasses.dataclass
class Calibration:
    models: list[CalibratedModel]
    artifact: dict[str, Any]  # the JSON-able result


def train_classifier(
    name: str,
    *,
    n_classes: int = 10,
    res: int = 32,
    seed: int = 0,
    steps: int | None = None,
):
    """Fit a smoke-config classifier to the synthetic video distribution so
    accuracy profiles (and the int8 drop) are real.  Returns
    ``(arch, params, state, forward, final_loss)`` with
    ``forward(params, x) -> logits`` closed over the trained state."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from .. import configs
    from ..arch import abstract_params as arch_params
    from ..arch import classifier_forward
    from ..models.common import init_tree
    from ..train import optim
    from .engine import make_synthetic_video

    steps = steps if steps is not None else TRAIN_STEPS.get(name, 150)
    arch = configs.get(name, smoke=True)
    specs, state_specs = arch_params(arch)
    params = init_tree(jax.random.key(seed), specs)
    state = init_tree(jax.random.key(seed + 1), state_specs)

    cfgopt = optim.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps, weight_decay=0.0)
    opt = optim.init_opt_state(params)
    tr_frames, tr_labels = make_synthetic_video(2048, n_classes=n_classes, res=res, seed=seed)

    def loss_fn(p, s, x, y):
        logits, ns = classifier_forward(arch, p, s, x, train=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1)), ns

    @jax.jit
    def step_fn(p, s, opt, x, y):
        (loss, ns), g = jax.value_and_grad(loss_fn, has_aux=True)(p, s, x, y)
        p2, opt2, _ = optim.adamw_update(cfgopt, p, g, opt)
        return p2, ns, opt2, loss

    rng = np.random.default_rng(7)
    loss = None
    bs = 32
    for _ in range(steps):
        idx = rng.integers(0, len(tr_frames), bs)
        params, state, opt, loss = step_fn(
            params, state, opt, jnp.asarray(tr_frames[idx]), jnp.asarray(tr_labels[idx])
        )

    def forward(p, x, *, _arch=arch, _state=state):
        return classifier_forward(_arch, p, _state, x, train=False)[0]

    return arch, params, state, forward, float(loss)


def _median_s(call: Callable[[], Any], *, warmup: int, repeats: int) -> float:
    """Median wall seconds of ``call()`` (which must block on its result)."""
    for _ in range(max(warmup, 1)):
        call()
    ts = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        call()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _top1_acc(endpoint, frames, labels, *, chunk: int = 64) -> float:
    import numpy as np

    hits = 0
    for lo in range(0, len(frames), chunk):
        logits = endpoint(frames[lo : lo + chunk])
        hits += int(np.sum(np.argmax(logits, -1) == labels[lo : lo + chunk]))
    return hits / len(frames)


def calibrate_model(name: str, cfg: CalibrationConfig) -> CalibratedModel:
    """Train one classifier, build both deployment variants, measure both."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from .. import quant
    from ..core.profiles import PAPER_RESOLUTIONS
    from .engine import ModelEndpoint, degrade_frame, make_synthetic_video

    steps = cfg.train_steps.get(name, 150)
    arch, params, state, forward, final_loss = train_classifier(
        name, n_classes=cfg.n_classes, res=cfg.res, seed=cfg.seed, steps=steps
    )
    qparams, qstats = quant.npu_variant(params)

    # The two deployment variants.  The NPU endpoint's forward is wrapped so
    # every matmul (heads, and convs via im2col) traces into the Pallas
    # kernel; the weights it multiplies are the int8 fake-quant values —
    # re-quantizing them is idempotent, so kernel int8s == deployed int8s.
    npu_fwd = quant.npu_forward(forward, interpret=cfg.interpret)
    edge = ModelEndpoint(f"{name}-edge", lambda x, p=params: forward(p, x), profile_latency_s=0)
    npu = ModelEndpoint(f"{name}-npu", lambda x, p=qparams, f=npu_fwd: f(p, x), profile_latency_s=0)

    # -- latency: per serving bucket size, warmup then median ---------------
    probe, _ = make_synthetic_video(
        max(cfg.batch_sizes), n_classes=cfg.n_classes, res=cfg.res, seed=cfg.seed + 17
    )
    t_npu_by_b: dict[str, float] = {}
    t_edge_by_b: dict[str, float] = {}
    for b in cfg.batch_sizes:
        x = jnp.asarray(probe[:b])
        t_npu_by_b[str(b)] = _median_s(
            lambda: np.asarray(npu.forward(x)), warmup=cfg.warmup, repeats=cfg.repeats
        )
        t_edge_by_b[str(b)] = _median_s(
            lambda: np.asarray(edge.forward(x)), warmup=cfg.warmup, repeats=cfg.repeats
        )
    # The profile's scalar is the per-frame (bucket 1) time; 1 ms floor keeps
    # degenerate sub-ms smoke models from planning as free.
    t_npu_s = max(t_npu_by_b[str(min(cfg.batch_sizes))], 1e-3)
    t_server_s = max(t_edge_by_b[str(min(cfg.batch_sizes))], 1e-3)

    # -- accuracy: held-out stream, per offload resolution ------------------
    hold, hold_labels = make_synthetic_video(
        cfg.holdout_frames, n_classes=cfg.n_classes, res=cfg.res, seed=99
    )
    resolutions = cfg.resolutions or PAPER_RESOLUTIONS
    acc_npu = {str(cfg.r_ref): _top1_acc(npu, jnp.asarray(hold), hold_labels)}
    acc_server: dict[str, float] = {}
    for r in resolutions:
        deg = np.stack([degrade_frame(f, r, r_ref=cfg.r_ref) for f in hold])
        acc_server[str(r)] = _top1_acc(edge, jnp.asarray(deg), hold_labels)
    agree = quant.agreement(forward, params, qparams, jnp.asarray(hold[:64]))

    payload = {
        "name": name,
        "t_npu_ms": t_npu_s * 1e3,
        "t_server_ms": t_server_s * 1e3,
        "acc_server": acc_server,
        "acc_npu": acc_npu,
        "provenance": {
            "source": "measured",
            "backend": jax.default_backend(),
            "kernel": "kernels/npu_matmul"
            + (" (interpret)" if cfg.interpret or jax.default_backend() != "tpu" else " (mosaic)"),
            "train_steps": steps,
            "final_loss": final_loss,
            "t_npu_ms_by_batch": {b: t * 1e3 for b, t in t_npu_by_b.items()},
            "t_server_ms_by_batch": {b: t * 1e3 for b, t in t_edge_by_b.items()},
            "fp32_int8_agreement": agree,
            "quant_mean_rel_err": qstats.mean_rel_err,
            "quant_max_rel_err": qstats.max_rel_err,
            "quant_leaves": qstats.leaves_quantized,
        },
    }
    return CalibratedModel(
        payload=payload,
        npu_endpoint=npu,
        edge_endpoint=edge,
        forward=forward,
        params=params,
        qparams=qparams,
    )


def calibrate(cfg: CalibrationConfig | None = None) -> Calibration:
    """Run the full pipeline over ``cfg.model_names``."""
    import jax

    cfg = cfg or CalibrationConfig()
    models = [calibrate_model(name, cfg) for name in cfg.model_names]
    artifact = {
        "schema": SCHEMA,
        "config": {
            "model_names": list(cfg.model_names),
            "n_classes": cfg.n_classes,
            "res": cfg.res,
            "seed": cfg.seed,
            "batch_sizes": list(cfg.batch_sizes),
            "warmup": cfg.warmup,
            "repeats": cfg.repeats,
            "holdout_frames": cfg.holdout_frames,
            "r_ref": cfg.r_ref,
        },
        "backend": jax.default_backend(),
        "models": [m.payload for m in models],
    }
    return Calibration(models=models, artifact=artifact)


def save_calibration(artifact: dict[str, Any], path: str | Path) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    return path


def load_calibration(path: str | Path) -> dict[str, Any]:
    """Load + sanity-check an artifact; ``["models"]`` feeds ScenarioSpec."""
    data = json.loads(Path(path).read_text())
    if data.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a calibration artifact (schema={data.get('schema')!r})")
    if not data.get("models"):
        raise ValueError(f"{path}: calibration artifact has no models")
    return data


def main(argv: list[str] | None = None) -> dict[str, Any]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized budgets")
    ap.add_argument("--out", default="calibration.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of architectures (default: resnet-50 squeezenet)")
    args = ap.parse_args(argv)

    cfg = CalibrationConfig.smoke(seed=args.seed) if args.smoke else CalibrationConfig(seed=args.seed)
    if args.models:
        cfg = dataclasses.replace(cfg, model_names=tuple(args.models))
    cal = calibrate(cfg)
    out = save_calibration(cal.artifact, args.out)
    for m in cal.artifact["models"]:
        print(
            f"{m['name']}: t_npu={m['t_npu_ms']:.1f}ms t_server={m['t_server_ms']:.1f}ms "
            f"acc_npu={max(m['acc_npu'].values()):.3f} acc_server@224={m['acc_server'].get('224', 0):.3f} "
            f"agreement={m['provenance']['fp32_int8_agreement']:.3f}",
            flush=True,
        )
    print(f"wrote {out}", flush=True)
    return cal.artifact


if __name__ == "__main__":
    main()
