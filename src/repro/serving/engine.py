"""FastVA serving runtime: real models behind the paper's scheduler.

Pieces:
  ModelEndpoint        a jitted classifier forward (full-precision "edge"
                       variant or int8 "NPU" variant) with measured latency.
  BatchedEndpoint      the multi-tenant variant: pads request batches to a
                       small set of power-of-two bucket sizes so every batch
                       shape hits an already-compiled jitted forward.
  EdgeBatchServer      coalesces offloaded frames from many clients into ONE
                       forward per model per tick (the serving half of
                       core/edge_server.py's multi-stream scheduler).
  VideoServer          consumes a frame stream; every round it asks the
                       OnlineController (Max-Accuracy / Max-Utility) where to
                       run each frame, executes the decisions on the REAL
                       models, advances a virtual clock with the profile's
                       network costs, and audits deadlines.
  make_synthetic_video labeled synthetic frames (class-prototype + noise) so
                       accuracy differences between variants are real.

Time model: inference latency and network transfer advance a virtual clock
(deterministic, testable); the actual numerics come from executing the jitted
models on this host.  On a TPU estate the same code runs with wall-clock
timing — the controller only sees (bytes, seconds) either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import OnlineController, StreamSpec
from ..core.profiles import ModelProfile, NetworkState
from ..core.schedule import Where


@dataclasses.dataclass
class EndpointStats:
    calls: int = 0
    total_s: float = 0.0


class ModelEndpoint:
    """A deployed model variant; forward: (images [B,H,W,3]) -> logits."""

    def __init__(self, name: str, forward: Callable[[jax.Array], jax.Array], *,
                 profile_latency_s: float):
        self.name = name
        self.forward = jax.jit(forward)
        self.profile_latency_s = profile_latency_s
        self.stats = EndpointStats()

    def __call__(self, images: jax.Array) -> np.ndarray:
        t0 = time.perf_counter()
        out = np.asarray(self.forward(images))
        self.stats.calls += 1
        self.stats.total_s += time.perf_counter() - t0
        return out

    def warmup(self, images: jax.Array) -> None:
        self.forward(images).block_until_ready()


@dataclasses.dataclass
class BatchStats:
    flushes: int = 0
    frames: int = 0
    padded: int = 0  # wasted rows added to reach a bucket size
    total_s: float = 0.0

    @property
    def mean_batch(self) -> float:
        return self.frames / self.flushes if self.flushes else 0.0

    @property
    def pad_fraction(self) -> float:
        submitted = self.frames + self.padded
        return self.padded / submitted if submitted else 0.0


class BatchedEndpoint:
    """A deployed model variant serving MANY clients per forward call.

    Batches are padded up to the next bucket size (powers of two up to
    ``max_batch``) so the jitted forward compiles once per bucket instead of
    once per observed batch size; the pad rows are sliced off the output.
    Oversized batches are split into ``max_batch`` chunks.
    """

    def __init__(
        self,
        name: str,
        forward: Callable[[jax.Array], jax.Array],
        *,
        profile_latency_s: float = 0.0,
        max_batch: int = 32,
    ):
        self.name = name
        self.forward = jax.jit(forward)
        self.profile_latency_s = profile_latency_s
        self.max_batch = int(max_batch)
        # max_batch itself is always a bucket: __call__ chunks by max_batch,
        # so full chunks must land on a warmed shape even when max_batch is
        # not a power of two.
        self.buckets = tuple(
            b for b in (1, 2, 4, 8, 16, 32, 64, 128, 256) if b < self.max_batch
        ) + (self.max_batch,)
        self.stats = BatchStats()

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def __call__(self, images: np.ndarray) -> np.ndarray:
        """forward over [B, H, W, C]; any B >= 1, bucket-padded internally."""
        if len(images) == 0:
            # The output feature shape is unknowable without running the
            # model, so an empty batch cannot return a consistent array.
            raise ValueError(f"{self.name}: empty batch (need B >= 1)")
        t0 = time.perf_counter()
        outs = []
        for lo in range(0, len(images), self.max_batch):
            chunk = images[lo : lo + self.max_batch]
            b = self._bucket(len(chunk))
            pad = b - len(chunk)
            x = jnp.asarray(
                np.concatenate([chunk, np.zeros((pad, *chunk.shape[1:]), chunk.dtype)])
                if pad
                else chunk
            )
            out = np.asarray(self.forward(x))
            outs.append(out[: len(chunk)])
            self.stats.padded += pad
            # One flush per FORWARD, not per __call__: an oversized batch
            # split into max_batch chunks is several forwards, and counting
            # it as one would overstate mean_batch/pad_fraction — exactly
            # the batching-efficiency stats the serving bench reports.
            self.stats.flushes += 1
        self.stats.frames += len(images)
        self.stats.total_s += time.perf_counter() - t0
        return np.concatenate(outs)

    def warmup(self, sample: np.ndarray) -> None:
        """Pre-compile every bucket shape so serving never hits a compile."""
        for b in self.buckets:
            x = np.broadcast_to(sample[None], (b, *sample.shape)).copy()
            self.forward(jnp.asarray(x)).block_until_ready()


@dataclasses.dataclass(frozen=True)
class OffloadRequest:
    """One frame a client ships to the edge (what the uplink carried)."""

    client_id: int
    frame_id: int
    model: int  # index into the shared model/profile list
    image: np.ndarray


class EdgeBatchServer:
    """Coalesces offloaded frames from N clients into one forward per model.

    ``submit`` enqueues requests as they arrive during a tick; ``flush``
    groups the queue by model, runs each group through its
    :class:`BatchedEndpoint` as a single padded batch, and returns
    ``{(client_id, frame_id): logits_row}``.  Numerics are identical to
    calling the endpoint per-frame (tests/test_edge_server.py asserts it) —
    batching only changes throughput, never answers.
    """

    def __init__(self, endpoints: dict[int, BatchedEndpoint]):
        self.endpoints = endpoints
        self.queue: list[OffloadRequest] = []

    def submit(self, req: OffloadRequest) -> None:
        if req.model not in self.endpoints:
            raise KeyError(f"no endpoint deployed for model index {req.model}")
        self.queue.append(req)

    def pending(self) -> int:
        return len(self.queue)

    def flush(self) -> dict[tuple[int, int], np.ndarray]:
        by_model: dict[int, list[OffloadRequest]] = {}
        for req in self.queue:
            by_model.setdefault(req.model, []).append(req)
        results: dict[tuple[int, int], np.ndarray] = {}
        for model, reqs in by_model.items():
            batch = np.stack([r.image for r in reqs])
            logits = self.endpoints[model](batch)
            for r, row in zip(reqs, logits):
                results[(r.client_id, r.frame_id)] = row
        # Clear only after every forward succeeded, so a mid-flush failure
        # leaves the queue intact for retry instead of dropping requests.
        self.queue = []
        return results


@dataclasses.dataclass
class FrameResult:
    frame: int
    where: str
    model: str
    correct: bool
    latency_s: float
    deadline_met: bool


def make_synthetic_video(
    n_frames: int,
    *,
    n_classes: int = 10,
    res: int = 32,
    seed: int = 0,
    drift: float = 0.05,
    proto_seed: int = 1234,
) -> tuple[np.ndarray, np.ndarray]:
    """Labeled frames: class prototypes + noise, with slow scene drift.

    ``proto_seed`` fixes the class prototypes (the "world"); ``seed`` varies
    the trajectory — so train/eval/serve streams share one label space."""
    rng = np.random.default_rng(proto_seed)
    protos = rng.standard_normal((n_classes, res, res, 3)).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = np.zeros(n_frames, np.int32)
    frames = np.zeros((n_frames, res, res, 3), np.float32)
    label = int(rng.integers(n_classes))
    for i in range(n_frames):
        if rng.uniform() < drift:
            label = int(rng.integers(n_classes))
        labels[i] = label
        frames[i] = protos[label] + 0.9 * rng.standard_normal((res, res, 3)).astype(np.float32)
    return frames, labels


def degrade_frame(frame: np.ndarray, resolution: int, *, r_ref: int = 224) -> np.ndarray:
    """Emulate offloading at resolution ``r``: resize H×W down by the
    fraction ``r / r_ref`` and back up, so the edge model sees the
    information loss of the paper's offload resize at its native input
    size.  ``r >= r_ref`` (and the NPU path, which never resizes) is the
    identity.  Shared by the calibration pipeline (``serving/calibrate``
    scores acc_server[r] on exactly this transform) and the serving loop."""
    if resolution < 0 or resolution >= r_ref:
        return frame
    h, w = frame.shape[:2]
    frac = max(int(resolution), 1) / float(r_ref)
    hh, ww = max(1, round(h * frac)), max(1, round(w * frac))
    if (hh, ww) == (h, w):
        return frame
    small = jax.image.resize(jnp.asarray(frame), (hh, ww, *frame.shape[2:]), "linear")
    big = jax.image.resize(small, frame.shape, "linear")
    return np.asarray(big, frame.dtype)


class VideoServer:
    """Drives the FastVA policy over a frame stream with real model calls.

    The controller plans against its *belief* (the EWMA estimator); this
    loop executes against the TRUE link (``trace``): upload times come from
    the trace's bandwidth at the virtual send time, the uplink is serial
    (this round's uploads queue behind the previous round's tail), and the
    measured transfer time — never the plan's own estimate — is what gets
    reported back to the estimator.  Offloaded frames are degraded to the
    decision's resolution before edge inference, so resolution choices cost
    real accuracy.  With ``edge_server`` set, edge inference coalesces into
    one :class:`BatchedEndpoint` forward per model per round.
    """

    def __init__(
        self,
        *,
        controller: OnlineController,
        npu_endpoints: dict[int, ModelEndpoint],  # model index -> NPU variant
        edge_endpoints: dict[int, ModelEndpoint] | None = None,  # -> edge variant
        stream: StreamSpec,
        trace,  # core.simulator.Trace, or a constant NetworkState
        edge_server: "EdgeBatchServer | None" = None,
    ):
        self.controller = controller
        self.npu = npu_endpoints
        self.edge = edge_endpoints or {}
        self.edge_server = edge_server
        if not self.edge and edge_server is None:
            raise ValueError("VideoServer needs edge_endpoints or an edge_server")
        self.stream = stream
        if isinstance(trace, NetworkState):
            self._net_at = lambda t, net=trace: net
        else:
            self._net_at = trace.at
        self.results: list[FrameResult] = []
        self.wall_s = 0.0
        self._net_free_abs = 0.0  # serial true-link occupancy (virtual clock)

    def run(self, frames: np.ndarray, labels: np.ndarray) -> dict:
        gamma, T = self.stream.gamma, self.stream.deadline
        models = self.controller.models
        r_max = self.stream.r_max
        n = len(frames)
        head = 0
        wall0 = time.perf_counter()
        while head < n:
            t0 = head * gamma
            plan = self.controller.next_plan(head)
            horizon = max(plan.horizon, 1)
            deferred: list[tuple[int, str, float, bool]] = []
            for d in plan.decisions:
                fi = head + d.frame
                if fi >= n:
                    continue
                if not d.is_processed():
                    continue
                prof: ModelProfile = models[d.model]
                arrival_abs = t0 + d.frame * gamma
                if d.where is Where.NPU:
                    logits = self.npu[d.model](jnp.asarray(frames[fi][None]))
                    pred = int(np.argmax(logits[0]))
                    # NPU frames never touch the network; planned times are
                    # profile-measured, so the plan's window is the audit.
                    met = d.finish <= d.frame * gamma + T + 1e-9
                    self.results.append(
                        FrameResult(
                            frame=fi,
                            where="npu",
                            model=prof.name,
                            correct=pred == int(labels[fi]),
                            latency_s=prof.t_npu,
                            deadline_met=met,
                        )
                    )
                    continue
                # Edge path: measure the transfer on the true link.
                true_net = self._net_at(arrival_abs)
                nbytes = self.stream.frame_bytes(d.resolution)
                t_up = true_net.upload_time(nbytes)
                # The estimator observes the MEASURED upload time.  (The bug
                # this replaces fed it net.upload_time() of its own belief —
                # an echo that could never converge to the true link.)
                self.controller.report_upload(nbytes, t_up)
                self.controller.report_rtt(true_net.rtt)
                if not np.isfinite(t_up):  # dead link: the frame never arrives
                    # (and must not occupy the uplink forever — leave
                    # _net_free_abs alone so a recovered trace can send)
                    self.results.append(
                        FrameResult(fi, "server", prof.name, False, float("inf"), False)
                    )
                    continue
                start = max(self._net_free_abs, t0 + max(d.start, 0.0))
                finish_abs = start + t_up + true_net.rtt + prof.t_server
                self._net_free_abs = start + t_up
                met = finish_abs <= arrival_abs + T + 1e-9
                latency = max(finish_abs - arrival_abs, 0.0)
                img = degrade_frame(frames[fi], d.resolution, r_ref=r_max)
                if self.edge_server is not None:
                    self.edge_server.submit(OffloadRequest(0, fi, d.model, img))
                    deferred.append((fi, prof.name, latency, met))
                else:
                    logits = self.edge[d.model](jnp.asarray(img[None]))
                    pred = int(np.argmax(logits[0]))
                    self.results.append(
                        FrameResult(fi, "server", prof.name, pred == int(labels[fi]), latency, met)
                    )
            if deferred:
                out = self.edge_server.flush()
                for fi, model_name, latency, met in deferred:
                    pred = int(np.argmax(out[(0, fi)]))
                    self.results.append(
                        FrameResult(fi, "server", model_name, pred == int(labels[fi]), latency, met)
                    )
            head += horizon
        self.wall_s = time.perf_counter() - wall0
        return self.summary()

    def summary(self) -> dict:
        rs = self.results
        spec = getattr(self.controller, "policy", None)
        policy = spec.to_json() if spec is not None else None
        if not rs:
            return {"frames": 0, "policy_spec": policy}
        finite = [r.latency_s for r in rs if np.isfinite(r.latency_s)]
        out = {
            "policy_spec": policy,
            "frames": len(rs),
            "accuracy": sum(r.correct for r in rs) / len(rs),
            "npu_frames": sum(r.where == "npu" for r in rs),
            "edge_frames": sum(r.where == "server" for r in rs),
            "deadline_met_frac": sum(r.deadline_met for r in rs) / len(rs),
            "mean_latency_s": sum(finite) / len(finite) if finite else 0.0,
            "wall_s": self.wall_s,
            "fps_sustained": len(rs) / self.wall_s if self.wall_s > 0 else 0.0,
            "estimated_bps": self.controller.estimator.state().bandwidth_bps,
        }
        if self.edge_server is not None:
            bs = BatchStats()
            for ep in self.edge_server.endpoints.values():
                bs.flushes += ep.stats.flushes
                bs.frames += ep.stats.frames
                bs.padded += ep.stats.padded
                bs.total_s += ep.stats.total_s
            out["batch"] = {
                "flushes": bs.flushes,
                "mean_batch": bs.mean_batch,
                "pad_fraction": bs.pad_fraction,
            }
        return out
