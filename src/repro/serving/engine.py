"""FastVA serving runtime: real models behind the paper's scheduler.

Pieces:
  ModelEndpoint        a jitted classifier forward (full-precision "edge"
                       variant or int8 "NPU" variant) with measured latency.
  VideoServer          consumes a frame stream; every round it asks the
                       OnlineController (Max-Accuracy / Max-Utility) where to
                       run each frame, executes the decisions on the REAL
                       models, advances a virtual clock with the profile's
                       network costs, and audits deadlines.
  make_synthetic_video labeled synthetic frames (class-prototype + noise) so
                       accuracy differences between variants are real.

Time model: inference latency and network transfer advance a virtual clock
(deterministic, testable); the actual numerics come from executing the jitted
models on this host.  On a TPU estate the same code runs with wall-clock
timing — the controller only sees (bytes, seconds) either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import OnlineController, StreamSpec
from ..core.profiles import ModelProfile
from ..core.schedule import Where


@dataclasses.dataclass
class EndpointStats:
    calls: int = 0
    total_s: float = 0.0


class ModelEndpoint:
    """A deployed model variant; forward: (images [B,H,W,3]) -> logits."""

    def __init__(self, name: str, forward: Callable[[jax.Array], jax.Array], *,
                 profile_latency_s: float):
        self.name = name
        self.forward = jax.jit(forward)
        self.profile_latency_s = profile_latency_s
        self.stats = EndpointStats()

    def __call__(self, images: jax.Array) -> np.ndarray:
        t0 = time.perf_counter()
        out = np.asarray(self.forward(images))
        self.stats.calls += 1
        self.stats.total_s += time.perf_counter() - t0
        return out

    def warmup(self, images: jax.Array) -> None:
        self.forward(images).block_until_ready()


@dataclasses.dataclass
class FrameResult:
    frame: int
    where: str
    model: str
    correct: bool
    latency_s: float
    deadline_met: bool


def make_synthetic_video(
    n_frames: int,
    *,
    n_classes: int = 10,
    res: int = 32,
    seed: int = 0,
    drift: float = 0.05,
    proto_seed: int = 1234,
) -> tuple[np.ndarray, np.ndarray]:
    """Labeled frames: class prototypes + noise, with slow scene drift.

    ``proto_seed`` fixes the class prototypes (the "world"); ``seed`` varies
    the trajectory — so train/eval/serve streams share one label space."""
    rng = np.random.default_rng(proto_seed)
    protos = rng.standard_normal((n_classes, res, res, 3)).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = np.zeros(n_frames, np.int32)
    frames = np.zeros((n_frames, res, res, 3), np.float32)
    label = int(rng.integers(n_classes))
    for i in range(n_frames):
        if rng.uniform() < drift:
            label = int(rng.integers(n_classes))
        labels[i] = label
        frames[i] = protos[label] + 0.9 * rng.standard_normal((res, res, 3)).astype(np.float32)
    return frames, labels


class VideoServer:
    """Drives the FastVA policy over a frame stream with real model calls."""

    def __init__(
        self,
        *,
        controller: OnlineController,
        npu_endpoints: dict[int, ModelEndpoint],  # model index -> NPU variant
        edge_endpoints: dict[int, ModelEndpoint],  # model index -> edge variant
        stream: StreamSpec,
    ):
        self.controller = controller
        self.npu = npu_endpoints
        self.edge = edge_endpoints
        self.stream = stream
        self.results: list[FrameResult] = []

    def run(self, frames: np.ndarray, labels: np.ndarray) -> dict:
        gamma, T = self.stream.gamma, self.stream.deadline
        models = self.controller.models
        n = len(frames)
        head = 0
        while head < n:
            plan = self.controller.next_plan(head)
            horizon = max(plan.horizon, 1)
            for d in plan.decisions:
                fi = head + d.frame
                if fi >= n:
                    continue
                if not d.is_processed():
                    continue
                x = jnp.asarray(frames[fi][None])
                prof: ModelProfile = models[d.model]
                if d.where is Where.NPU:
                    ep = self.npu[d.model]
                    net_cost = 0.0
                else:
                    ep = self.edge[d.model]
                    net = self.controller.estimator.state()
                    nbytes = self.stream.frame_bytes(d.resolution)
                    net_cost = net.upload_time(nbytes) + net.rtt
                    self.controller.report_upload(nbytes, net.upload_time(nbytes))
                logits = ep(x)
                pred = int(np.argmax(logits[0]))
                virtual_latency = net_cost + (
                    prof.t_npu if d.where is Where.NPU else prof.t_server
                )
                # Planned finish is round-relative; audit against the deadline.
                met = d.finish <= d.frame * gamma + T + 1e-9
                self.results.append(
                    FrameResult(
                        frame=fi,
                        where=d.where.value,
                        model=prof.name,
                        correct=pred == int(labels[fi]),
                        latency_s=virtual_latency,
                        deadline_met=met,
                    )
                )
            head += horizon
        return self.summary()

    def summary(self) -> dict:
        rs = self.results
        if not rs:
            return {"frames": 0}
        return {
            "frames": len(rs),
            "accuracy": sum(r.correct for r in rs) / len(rs),
            "npu_frames": sum(r.where == "npu" for r in rs),
            "edge_frames": sum(r.where == "server" for r in rs),
            "deadline_met_frac": sum(r.deadline_met for r in rs) / len(rs),
            "mean_latency_s": sum(r.latency_s for r in rs) / len(rs),
        }
