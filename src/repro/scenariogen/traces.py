"""Trace generators: the adversarial network conditions FastVA must survive.

Every generator returns a plain :class:`repro.session.TraceSpec` — the same
declarative, JSON-round-trippable object every engine already consumes — so a
generated scenario runs through the front door (``run_sim`` / ``run_online`` /
``run_sweep`` on any backend) with zero special-casing.  Generators are pure
functions of their parameters (``flash_crowd`` takes an explicit ``seed``), so
a scenario catalog entry pins its trace bit-for-bit.

The shapes (docs/scenarios.md has plots-in-prose for each):

  mobility_square  walking in/out of coverage: bandwidth toggles between a
                   high and a low level with a fixed period and duty cycle —
                   the canonical estimator-convergence stressor.
  mobility_ramp    drive-through handoff: staircase up to peak, hold (with a
                   short mid-hold handoff dip), staircase back down.
  diurnal          slow load curve: cosine staircase around a base level,
                   amplitude-bounded so bandwidth never goes negative.
  flash_crowd      seeded bursts of contention: n non-overlapping events
                   during which available bandwidth collapses to crowd_mbps.
"""
from __future__ import annotations

import math

import numpy as np

from ..session import TraceSpec

__all__ = ["mobility_square", "mobility_ramp", "diurnal", "flash_crowd"]


def _positive(name: str, v: float) -> float:
    v = float(v)
    if not v > 0.0:
        raise ValueError(f"{name} must be > 0, got {v!r}")
    return v


def _bandwidth(name: str, v: float) -> float:
    v = float(v)
    if v < 0.0:
        raise ValueError(f"{name} must be >= 0 Mbps, got {v!r}")
    return v


def mobility_square(
    *,
    high_mbps: float = 3.5,
    low_mbps: float = 0.8,
    period_s: float = 2.0,
    duty: float = 0.5,
    duration_s: float = 16.0,
    rtt_ms: float = 100.0,
) -> TraceSpec:
    """Square wave: ``duty`` of each period at ``high_mbps``, the rest low.

    Starts high at t=0 (the paper's mobile begins in good coverage); the
    trace holds its last level past ``duration_s``, matching ``Trace.at``.
    """
    high = _bandwidth("high_mbps", high_mbps)
    low = _bandwidth("low_mbps", low_mbps)
    period = _positive("period_s", period_s)
    duration = _positive("duration_s", duration_s)
    duty = float(duty)
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0, 1), got {duty!r}")
    points: list[tuple[float, float]] = []
    k = 0
    while k * period < duration:
        points.append((k * period, high))
        fall = k * period + duty * period
        if fall < duration:
            points.append((fall, low))
        k += 1
    return TraceSpec(kind="piecewise", points=tuple(points), rtt_ms=float(rtt_ms))


def mobility_ramp(
    *,
    low_mbps: float = 0.8,
    high_mbps: float = 4.0,
    ramp_s: float = 4.0,
    hold_s: float = 4.0,
    steps: int = 4,
    dip_mbps: float = 0.2,
    dip_s: float = 0.5,
    rtt_ms: float = 100.0,
) -> TraceSpec:
    """Staircase up, hold at peak with a mid-hold handoff dip, staircase down.

    The dip models a cell handoff at the coverage peak: ``dip_s`` seconds at
    ``dip_mbps``, centered in the hold window (it must fit inside it).  Total
    duration is ``2 * ramp_s + hold_s``.
    """
    low = _bandwidth("low_mbps", low_mbps)
    high = _bandwidth("high_mbps", high_mbps)
    dip = _bandwidth("dip_mbps", dip_mbps)
    ramp = _positive("ramp_s", ramp_s)
    hold = _positive("hold_s", hold_s)
    dip_len = _positive("dip_s", dip_s)
    steps = int(steps)
    if steps < 2:
        raise ValueError(f"steps must be >= 2, got {steps!r}")
    if dip_len >= hold:
        raise ValueError(
            f"handoff dip ({dip_len!r}s) must fit inside the hold window ({hold!r}s)"
        )
    levels = [low + (high - low) * i / (steps - 1) for i in range(steps)]
    points: list[tuple[float, float]] = []
    for i, v in enumerate(levels[:-1]):  # up-ramp; the peak opens the hold
        points.append((i * ramp / (steps - 1), v))
    dip_at = ramp + (hold - dip_len) / 2.0
    points.append((ramp, high))
    points.append((dip_at, dip))
    points.append((dip_at + dip_len, high))
    for i, v in enumerate(reversed(levels[:-1])):  # down-ramp back to low
        points.append((ramp + hold + i * ramp / (steps - 1), v))
    return TraceSpec(kind="piecewise", points=tuple(points), rtt_ms=float(rtt_ms))


def diurnal(
    *,
    base_mbps: float = 2.5,
    amplitude_mbps: float = 1.5,
    period_s: float = 24.0,
    steps: int = 12,
    duration_s: float | None = None,
    rtt_ms: float = 100.0,
) -> TraceSpec:
    """Cosine staircase: bandwidth peaks at t=0 and bottoms out mid-period
    (the network is loaded when everyone is awake).  ``steps`` levels per
    period; amplitude must not exceed the base so bandwidth stays >= 0."""
    base = _bandwidth("base_mbps", base_mbps)
    amp = float(amplitude_mbps)
    if not 0.0 <= amp <= base:
        raise ValueError(
            f"amplitude_mbps must be in [0, base_mbps={base!r}], got {amp!r}"
        )
    period = _positive("period_s", period_s)
    steps = int(steps)
    if steps < 2:
        raise ValueError(f"steps must be >= 2, got {steps!r}")
    duration = period if duration_s is None else _positive("duration_s", duration_s)
    dt = period / steps
    points: list[tuple[float, float]] = []
    k = 0
    while k * dt < duration:
        t = k * dt
        points.append((t, base + amp * math.cos(2.0 * math.pi * t / period)))
        k += 1
    return TraceSpec(kind="piecewise", points=tuple(points), rtt_ms=float(rtt_ms))


def flash_crowd(
    *,
    base_mbps: float = 3.5,
    crowd_mbps: float = 0.5,
    n_events: int = 3,
    event_s: float = 1.0,
    duration_s: float = 16.0,
    seed: int = 0,
    rtt_ms: float = 100.0,
) -> TraceSpec:
    """Seeded bursts of contention: ``n_events`` non-overlapping windows of
    ``event_s`` seconds at ``crowd_mbps``, arrival times drawn uniformly over
    the trace (``numpy.random.default_rng(seed)`` — same seed, same trace).
    Events that no longer fit after de-overlapping are dropped, never
    truncated, so every emitted event has its full duration."""
    base = _bandwidth("base_mbps", base_mbps)
    crowd = _bandwidth("crowd_mbps", crowd_mbps)
    event = _positive("event_s", event_s)
    duration = _positive("duration_s", duration_s)
    n_events = int(n_events)
    if n_events < 1:
        raise ValueError(f"n_events must be >= 1, got {n_events!r}")
    if event >= duration:
        raise ValueError(
            f"event_s ({event!r}) must be shorter than duration_s ({duration!r})"
        )
    rng = np.random.default_rng(int(seed))
    raw = sorted(float(t) for t in rng.uniform(0.0, duration - event, size=n_events))
    gap = 1e-3  # keeps restore/collapse points strictly increasing
    starts: list[float] = []
    prev_end = -math.inf
    for s in raw:
        s = max(s, prev_end + gap)
        if s + event > duration:
            break
        starts.append(s)
        prev_end = s + event
    points: dict[float, float] = {0.0: base}
    for s in starts:
        points[s] = crowd
        points[s + event] = base
    pts = tuple(sorted(points.items()))
    return TraceSpec(kind="piecewise", points=pts, rtt_ms=float(rtt_ms))
