"""Scenario generators: adversarial network + fault conditions, front-door ready.

The library closes the loop between the paper's evaluation narrative ("what
happens when the user walks out of coverage / the edge pool dies mid-run?")
and the repo's engines: every generator lowers to the same declarative
:class:`~repro.session.TraceSpec` / :class:`~repro.session.ScenarioSpec`
objects the engines already consume, so one generated scenario runs unchanged
through ``run_sim``, ``run_online``, ``run_multi`` and the batched sweep
backends (``sim_batch`` / ``sim_multi_batch`` / ``sim_online_batch``).

Catalog (docs/scenarios.md walks through each):

    >>> from repro import scenariogen
    >>> scenariogen.trace_kinds()
    ('diurnal', 'edge_failure', 'flash_crowd', 'mobility_ramp', 'mobility_square')
    >>> spec = scenariogen.make_scenario(
    ...     "mobility_square", policy="max_accuracy", period_s=2.0)
    >>> Session(spec).run_online()          # doctest: +SKIP

``make_trace(kind, **params)`` returns just the TraceSpec; ``make_scenario``
wraps it into a full ScenarioSpec.  The fault generator's richer report
(detection lag, monitor event log) is available via
:func:`scenariogen.faults.edge_failure` directly.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

from ..session import ScenarioSpec, TraceSpec
from . import faults, traces
from .faults import OutageReport, dead_edge_models, degrade, edge_failure

__all__ = [
    "OutageReport",
    "TRACE_KINDS",
    "dead_edge_models",
    "degrade",
    "edge_failure",
    "make_scenario",
    "make_trace",
    "trace_kinds",
]

#: kind name -> generator; every entry returns a plain TraceSpec.
TRACE_KINDS: Mapping[str, Callable[..., TraceSpec]] = {
    "mobility_square": traces.mobility_square,
    "mobility_ramp": traces.mobility_ramp,
    "diurnal": traces.diurnal,
    "flash_crowd": traces.flash_crowd,
    "edge_failure": lambda **params: faults.edge_failure(**params).trace,
}


def trace_kinds() -> tuple[str, ...]:
    """Registered generator kinds, sorted (the catalog's table of contents)."""
    return tuple(sorted(TRACE_KINDS))


def make_trace(kind: str, **params: Any) -> TraceSpec:
    """Build the ``kind`` generator's TraceSpec; unknown kinds raise."""
    try:
        gen = TRACE_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown scenario kind {kind!r}; registered: {trace_kinds()}"
        ) from None
    return gen(**params)


def make_scenario(
    kind: str,
    *,
    policy: Any,
    n_frames: int = 120,
    fps: float = 30.0,
    deadline_ms: float = 200.0,
    resolutions: tuple[int, ...] = (224, 320, 448),
    models: tuple = ("resnet-50", "squeezenet"),
    strict: bool = True,
    label: str = "",
    **trace_params: Any,
) -> ScenarioSpec:
    """One front-door scenario around :func:`make_trace`.

    ``policy`` is anything :class:`ScenarioSpec` accepts (a PolicySpec, a
    name, or a ``{"name": ..., "params": ...}`` payload); remaining keyword
    arguments go to the trace generator.  The result is an ordinary spec —
    JSON round-trippable, sweepable, runnable on every engine.
    """
    from ..core.profiles import StreamSpec  # local: keep import surface small

    return ScenarioSpec(
        policy=policy,
        n_frames=n_frames,
        stream=StreamSpec(
            fps=float(fps),
            deadline=float(deadline_ms) / 1e3,
            resolutions=tuple(int(r) for r in resolutions),
        ),
        models=models,
        trace=make_trace(kind, **trace_params),
        strict=strict,
        label=label or kind,
    )
