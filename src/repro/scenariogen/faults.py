"""Fault injection: mid-round edge-server failures as first-class scenarios.

The FastVA tie-in (see :mod:`repro.runtime.fault_tolerance`): the serving
tier treats an edge-pool failure like the paper treats a network outage.  Two
renderings of the same event, composable:

  * **Network view** — :func:`edge_failure` drives the *dormant*
    :class:`~repro.runtime.fault_tolerance.HeartbeatMonitor` with an injected
    clock over a deterministic heartbeat schedule, reads off when the monitor
    actually declares the pool DEAD (detection lags the crash by the dead
    grace window) and when the first post-recovery heartbeat lands, then
    splices that *detected* outage window into a bandwidth trace via
    :func:`degrade`.  The result is a plain TraceSpec: every engine —
    reference loops and the batched/online jit programs alike — replays the
    outage with no fault-specific code paths.
  * **Profile view** — :func:`dead_edge_models` degrades the model table
    instead (``t_server -> inf``), for scenarios where the edge pool is gone
    for the whole run and the schedulers must route everything to the NPU.

A degraded window defaults to a *small positive* bandwidth rather than zero:
the online engines model the uplink as serially occupied (``net_free = start
+ t_up``), so a genuinely 0-bandwidth upload pins the link busy forever —
faithful to ``run_online``, but it makes "recovery" meaningless.  Pass
``to_mbps=0.0`` only when that is the story you want to tell.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from ..core.profiles import ModelProfile
from ..runtime.fault_tolerance import HeartbeatMonitor, WorkerState
from ..session import TraceSpec

__all__ = ["OutageReport", "edge_failure", "degrade", "dead_edge_models"]


@dataclasses.dataclass(frozen=True)
class OutageReport:
    """An injected edge failure, as the monitor saw it.

    ``detected_at_s``/``recovered_at_s`` bound the *detected* outage (what
    :func:`degrade` splices into the trace); ``fail_at_s`` is when the pool
    actually crashed — the gap is the monitor's detection lag.  ``events``
    logs every state change the sweeps observed, in order.
    """

    trace: TraceSpec
    fail_at_s: float
    detected_at_s: float
    recovered_at_s: float
    events: tuple[tuple[float, str], ...]


def _value_at(points: Sequence[tuple[float, float]], t: float) -> float:
    """Piecewise-constant lookup matching ``Trace.at``: last point with
    t_start <= t wins; the first value extends backward."""
    v = points[0][1]
    for ts, val in points:
        if ts <= t:
            v = val
        else:
            break
    return v


def degrade(
    trace: TraceSpec,
    windows: Iterable[tuple[float, float]],
    *,
    to_mbps: float = 0.05,
) -> TraceSpec:
    """Splice outage windows into ``trace``: bandwidth is ``to_mbps`` during
    each ``[start, end)`` window and the base trace's own value resumes at
    ``end``.  Windows must be non-overlapping (shared endpoints are fine)."""
    if float(to_mbps) < 0.0:
        raise ValueError(f"to_mbps must be >= 0, got {to_mbps!r}")
    wins = sorted((float(a), float(b)) for a, b in windows)
    for a, b in wins:
        if not a < b:
            raise ValueError(f"degradation window must have start < end, got ({a!r}, {b!r})")
    for (_, b0), (a1, _) in zip(wins, wins[1:]):
        if a1 < b0:
            raise ValueError(
                f"degradation windows overlap: one ends at {b0!r}, next starts at {a1!r}"
            )
    base = (
        list(trace.points)
        if trace.kind == "piecewise"
        else [(0.0, float(trace.mbps))]
    )
    merged: dict[float, float] = {
        ts: v for ts, v in base if not any(a <= ts < b for a, b in wins)
    }
    for a, b in wins:
        merged[max(a, 0.0)] = float(to_mbps)
        merged[b] = _value_at(base, b)
    pts = tuple(sorted(merged.items()))
    return TraceSpec(kind="piecewise", points=pts, rtt_ms=trace.rtt_ms)


def edge_failure(
    *,
    fail_at_s: float = 4.0,
    recover_at_s: float = 8.0,
    duration_s: float = 16.0,
    base_mbps: float = 3.5,
    degraded_mbps: float = 0.05,
    rtt_ms: float = 100.0,
    interval_s: float = 0.25,
    suspect_after: float = 2.0,
    dead_after: float = 4.0,
) -> OutageReport:
    """Simulate an edge pool crashing mid-run and derive the outage trace.

    The pool heartbeats every ``interval_s`` until it crashes at
    ``fail_at_s`` and resumes at ``recover_at_s``; a deterministic injected
    clock drives :class:`HeartbeatMonitor` through the whole schedule.  The
    degraded window of the returned trace is the *detected* outage — it
    opens when the monitor declares the pool DEAD (``dead_after`` intervals
    of silence), not when the crash happened, exactly the lag a deployed
    controller would experience.
    """
    fail = float(fail_at_s)
    recover = float(recover_at_s)
    duration = float(duration_s)
    if not 0.0 <= fail < recover:
        raise ValueError(
            f"need 0 <= fail_at_s < recover_at_s, got ({fail!r}, {recover!r})"
        )
    if recover >= duration:
        raise ValueError(
            f"recover_at_s ({recover!r}) must precede duration_s ({duration!r})"
        )
    now = 0.0
    monitor = HeartbeatMonitor(
        interval_s=float(interval_s),
        suspect_after=float(suspect_after),
        dead_after=float(dead_after),
        clock=lambda: now,
    )
    monitor.register("edge-pool")
    events: list[tuple[float, str]] = []
    detected: float | None = None
    recovered: float | None = None
    k = 0
    while k * float(interval_s) <= duration:
        now = k * float(interval_s)
        alive = now < fail or now >= recover
        if alive:
            was_dead = monitor.workers["edge-pool"].state is WorkerState.DEAD
            monitor.beat("edge-pool")
            if was_dead:  # beat() is the one legitimate resurrection path
                events.append((now, "healthy"))
                if recovered is None:
                    recovered = now
        for _, state in monitor.sweep().items():
            events.append((now, state.value))
            if state is WorkerState.DEAD and detected is None:
                detected = now
        k += 1
    if detected is None or recovered is None:
        raise ValueError(
            "outage too short for the monitor to detect: widen "
            "fail_at_s..recover_at_s or lower dead_after/interval_s"
        )
    trace = degrade(
        TraceSpec(kind="constant", mbps=float(base_mbps), rtt_ms=float(rtt_ms)),
        [(detected, recovered)],
        to_mbps=float(degraded_mbps),
    )
    return OutageReport(
        trace=trace,
        fail_at_s=fail,
        detected_at_s=detected,
        recovered_at_s=recovered,
        events=tuple(events),
    )


def dead_edge_models(models: Sequence[ModelProfile]) -> tuple[ModelProfile, ...]:
    """The profile view of a dead edge pool: every model's ``t_server -> inf``
    (``runs_server`` becomes False), so the schedulers can only use the NPU
    path — the degradation :mod:`repro.runtime.fault_tolerance` describes."""
    return tuple(
        dataclasses.replace(m, t_server=float("inf")) for m in models
    )
