"""Jit'd public wrapper for flash attention.

``attention(q, k, v, causal=...)`` dispatches: Pallas Mosaic kernel on TPU,
interpret-mode kernel when REPRO_INTERPRET_KERNELS=1 (CPU validation), else
the blockwise jnp fallback (what the models use in SPMD dry-runs).
"""
from __future__ import annotations

import os

import jax

from ...models.layers import blockwise_sdpa
from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True, block_q: int = 256, block_kv: int = 512):
    if _on_tpu():
        return kernel.flash_attention(q, k, v, causal=causal, block_q=block_q, block_kv=block_kv)
    if os.environ.get("REPRO_INTERPRET_KERNELS") == "1":
        return kernel.flash_attention(
            q, k, v, causal=causal, block_q=block_q, block_kv=block_kv, interpret=True
        )
    return blockwise_sdpa(q, k, v, causal=causal, q_block=block_q, kv_block=block_kv)
