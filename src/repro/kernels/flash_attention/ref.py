"""Pure-jnp oracle for the flash attention kernel.

Two references:
  * ``sdpa_ref`` — naive O(S^2) softmax attention (ground truth).
  * ``blockwise_ref`` — the online-softmax blockwise algorithm in plain jnp
    (shared with models.layers.blockwise_sdpa); numerically equivalent.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

from ...models.layers import blockwise_sdpa as blockwise_ref  # noqa: F401


def sdpa_ref(q, k, v, *, causal: bool) -> jnp.ndarray:
    """q: [B,S,H,hd]; k/v: [B,T,KH,hd] (GQA when H > KH)."""
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / jnp.sqrt(hd).astype(
        jnp.float32
    )
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)
