"""Pallas TPU flash attention (forward), GQA-aware.

TPU-native design decisions (vs the CUDA flash-attention):
  * grid = (B * KH, n_q_blocks, n_kv_blocks) with the KV axis INNERMOST so the
    online-softmax accumulators (m, l, acc) live in VMEM scratch across the
    KV sweep — the TPU analogue of a CUDA thread-block's shared-memory state.
  * Q/K/V blocks are tiled (block_q x hd) / (block_kv x hd) in VMEM; hd is a
    full lane dimension (128 for every assigned arch), so MXU matmuls are
    (block_q x hd) @ (hd x block_kv) — both operands hardware-aligned.
  * GQA: the G query heads of one KV head are FOLDED into the q-block rows
    ((G*Sq) x hd), so grouped queries share the K/V block loads through VMEM
    instead of re-reading HBM per head — the MXU sees taller tiles, the HBM
    sees K/V once.
  * causal masking via block-index arithmetic; fully-masked blocks still run
    (Pallas TPU grids are dense) but their contribution is exactly zero.

Forward only: the backward pass uses XLA's autodiff through the jnp oracle
(models fall back to blockwise_sdpa for training).  Serving (prefill/decode)
is where the paper's latency story lives, and that is forward-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, block_q: int, block_kv: int, causal: bool, sm_scale: float,
            g: int, seq_q: int, seq_kv: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]  # [g*block_q, hd]
    k = k_ref[...]  # [block_kv, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # [g*block_q, block_kv]

    # Row/col absolute positions (rows are g query heads x block_q tokens).
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % block_q + q_i * block_q
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + kv_i * block_kv
    valid = cols < seq_kv
    if causal:
        valid &= cols <= rows + (seq_kv - seq_q)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kv_i == n_kv - 1)
    def _fin():
        out_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            out_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, T, KH, hd]
    v: jax.Array,  # [B, T, KH, hd]
    *,
    causal: bool = True,
    block_q: int = 256,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    sm_scale = 1.0 / (hd**0.5)

    bq = min(block_q, S)
    bkv = min(block_kv, T)
    pad_q = (-S) % bq
    pad_kv = (-T) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_kv
    n_q, n_kv = Sq // bq, Tk // bkv

    # Layout: fold (B, KH) into the leading grid axis; queries grouped per KV
    # head as [B*KH, n_q, G*bq, hd] so one kernel invocation sees all G heads.
    qg = q.reshape(B, Sq, KH, G, hd).transpose(0, 2, 3, 1, 4).reshape(B * KH, G, Sq, hd)
    qg = qg.reshape(B * KH, G, n_q, bq, hd).transpose(0, 2, 1, 3, 4).reshape(
        B * KH, n_q, G * bq, hd
    )
    kg = k.transpose(0, 2, 1, 3).reshape(B * KH, Tk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KH, Tk, hd)

    grid = (B * KH, n_q, n_kv)
    out = pl.pallas_call(
        functools.partial(
            _kernel, n_kv=n_kv, block_q=bq, block_kv=bkv, causal=causal,
            sm_scale=sm_scale, g=G, seq_q=S, seq_kv=T,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, G * bq, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((None, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G * bq, hd), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KH, n_q, G * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    out = out.reshape(B * KH, n_q, G, bq, hd).transpose(0, 2, 1, 3, 4).reshape(
        B, KH, G, Sq, hd
    )[:, :, :, :S]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
