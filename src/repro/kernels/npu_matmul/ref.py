"""Pure-jnp oracle for the NPU int8 matmul (w8a8, per-channel scales).

This is the semantic ground truth the Pallas kernel must match bit-for-bit
in integer accumulation (int8 x int8 -> int32) followed by f32 rescale.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_rowwise(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row int8 quantization of activations [M, K].
    Returns (q [M,K] int8, scale [M] f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_colwise(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-output-channel int8 quantization of weights [K, N]."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_matmul_ref(
    x_q: jnp.ndarray,  # [M, K] int8
    w_q: jnp.ndarray,  # [K, N] int8
    x_scale: jnp.ndarray,  # [M] f32
    w_scale: jnp.ndarray,  # [N] f32
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))  # exact int32
    return (acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]).astype(out_dtype)


def npu_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    """End-to-end fake-quant matmul: quantize both sides, int8 GEMM, dequant."""
    x2 = x.reshape(-1, x.shape[-1])
    xq, xs = quantize_rowwise(x2)
    wq, ws = quantize_colwise(w)
    out = int8_matmul_ref(xq, wq, xs, ws, out_dtype)
    return out.reshape(*x.shape[:-1], w.shape[-1])
