"""Pallas TPU kernel: w8a8 int8 matmul with per-row/per-channel scales.

This is the "NPU path" of FastVA mapped to the TPU: the paper's phone NPU
runs CNNs in 8/16-bit — here the quantized variant of every model runs its
matmuls through this kernel.  TPU-native design (not a CUDA port):

  * grid (M/bm, N/bn, K/bk); K innermost so each (i, j) tile accumulates in a
    VMEM int32 scratch across K steps — MXU-friendly int8 x int8 -> int32.
  * BlockSpecs tile x [bm, bk], w [bk, bn], out [bm, bn]; scales are tiny
    per-row/col vectors blocked along the same grid axes.
  * The f32 rescale happens ONCE, on the last K step, fused in-kernel
    (dequant epilogue) — no extra HBM round-trip for the int32 accumulator.

Block defaults (128, 128, 512) keep the working set
(bm*bk + bk*bn int8 + bm*bn i32) ~ 192 KB << 16 MB VMEM and all dims are
multiples of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = xs_ref[...][:, None] * ws_ref[...][None, :]
        out_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret")
)
def int8_matmul(
    x_q: jax.Array,  # [M, K] int8
    w_q: jax.Array,  # [K, N] int8
    x_scale: jax.Array,  # [M] f32
    w_scale: jax.Array,  # [N] f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes ({M},{K})x({K},{N}) must tile by ({bm},{bn},{bk}); pad upstream"
    )
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
