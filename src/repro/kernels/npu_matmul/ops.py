"""Jit'd public wrapper for the NPU int8 matmul.

``npu_matmul(x, w)`` quantizes on the fly (per-row activations, per-channel
weights) and runs the Pallas kernel; ``npu_matmul_prequant`` takes already
quantized weights (the serving path: weights are quantized once at load).

On non-TPU backends the kernel runs in interpret mode (the kernel body
executed by the Pallas interpreter) so CPU tests validate the real kernel
logic; on TPU it compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pow2ceil(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def npu_matmul(
    x: jax.Array, w: jax.Array, *, out_dtype=jnp.float32, interpret: bool | None = None
) -> jax.Array:
    """[..., K] x [K, N] -> [..., N] through int8 quantization (both sides)."""
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xs = ref.quantize_rowwise(x2)
    wq, ws = ref.quantize_colwise(w)
    out = npu_matmul_prequant(xq, xs, wq, ws, out_dtype=out_dtype, interpret=interpret)
    return out.reshape(*lead, w.shape[-1])


def npu_matmul_prequant(
    x_q: jax.Array,
    x_scale: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    *,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    M, K = x_q.shape
    N = w_q.shape[1]
    # Adaptive block sizes: small matmuls (the serving single-frame case —
    # M=1 head GEMMs, narrow im2col convs) shrink each block to the next
    # power of two instead of padding every dim to the full 128/512/128
    # tile.  The Mosaic (TPU) path keeps the int8 tiling minima — 32
    # sublanes on the second-minor dim, 128 lanes on the minor dim.
    bm = min(block_m, _pow2ceil(M))
    bn = min(block_n, _pow2ceil(N))
    bk = min(block_k, _pow2ceil(K))
    if not interpret:
        bm, bn, bk = max(bm, 32), max(bn, 128), max(bk, 128)
    # Pad every dim to its block multiple; slice back after.
    xq = _pad_to(_pad_to(x_q, bm, 0), bk, 1)
    wq = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    xs = _pad_to(x_scale, bm, 0)
    ws = _pad_to(w_scale, bn, 0)
    out = kernel.int8_matmul(
        xq, wq, xs, ws,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:M, :N]
