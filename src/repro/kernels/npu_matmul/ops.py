"""Jit'd public wrapper for the NPU int8 matmul.

``npu_matmul(x, w)`` quantizes on the fly (per-row activations, per-channel
weights) and runs the Pallas kernel; ``npu_matmul_prequant`` takes already
quantized weights (the serving path: weights are quantized once at load).

On non-TPU backends the kernel runs in interpret mode (the kernel body
executed by the Pallas interpreter) so CPU tests validate the real kernel
logic; on TPU it compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def npu_matmul(
    x: jax.Array, w: jax.Array, *, out_dtype=jnp.float32, interpret: bool | None = None
) -> jax.Array:
    """[..., K] x [K, N] -> [..., N] through int8 quantization (both sides)."""
    if interpret is None:
        interpret = not _on_tpu()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xq, xs = ref.quantize_rowwise(x2)
    wq, ws = ref.quantize_colwise(w)
    out = npu_matmul_prequant(xq, xs, wq, ws, out_dtype=out_dtype, interpret=interpret)
    return out.reshape(*lead, w.shape[-1])


def npu_matmul_prequant(
    x_q: jax.Array,
    x_scale: jax.Array,
    w_q: jax.Array,
    w_scale: jax.Array,
    *,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    M, K = x_q.shape
    N = w_q.shape[1]
    bm = min(block_m, M) if M % min(block_m, M) == 0 else block_m
    # Pad every dim to its block multiple; slice back after.
    xq = _pad_to(_pad_to(x_q, block_m, 0), block_k, 1)
    wq = _pad_to(_pad_to(w_q, block_k, 0), block_n, 1)
    xs = _pad_to(x_scale, block_m, 0)
    ws = _pad_to(w_scale, block_n, 0)
    out = kernel.int8_matmul(
        xq, wq, xs, ws,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:M, :N]
