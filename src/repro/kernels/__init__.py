"""Pallas TPU kernels for the perf-critical compute layers.

  npu_matmul       w8a8 int8 GEMM with fused dequant epilogue — the FastVA
                   "NPU path" (the paper's 8-bit phone NPU, TPU-native).
  flash_attention  online-softmax attention, GQA-folded tiles — kills the
                   O(S^2) HBM traffic the roofline flags on prefill cells.

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper
with backend dispatch), ref.py (pure-jnp oracle).  CPU validation runs the
kernel bodies in interpret mode; TPU compiles to Mosaic.
"""
from . import flash_attention, npu_matmul  # noqa: F401
