"""Serving driver: stand up NPU (int8-Pallas) + edge (fp32) variants of a
classifier pair, calibrate measured profiles, and run the FastVA controller
over a synthetic video.

    PYTHONPATH=src python -m repro.launch.serve --policy max_accuracy \
        --frames 200 --fps 30 --bandwidth 2.0

This is the end-to-end driver for the paper's kind (serving): batched frame
requests scheduled across the quantized local path and the full-precision
edge path under a per-frame deadline.  The CLI is a thin wrapper that builds
a declarative ``ScenarioSpec`` and routes it through ``Session.run_serving``;
``run_scenario`` is the engine that the Session facade calls back into.

Profiles come from ``serving/calibrate``: both latency tables are measured by
executing the variants (the NPU variant's matmuls run in the real
``kernels/npu_matmul`` Pallas kernel), and the per-resolution accuracy table
is scored on degraded held-out frames — nothing hand-typed.
"""
from __future__ import annotations

import argparse
from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from ..session import ScenarioSpec

# Re-exported for compatibility: the training budget now lives with the
# calibration pipeline.
from ..serving.calibrate import TRAIN_STEPS  # noqa: F401


def run_scenario(spec: "ScenarioSpec") -> dict:
    """Build the real-model serving stack for ``spec`` and run it.

    The model *names* in ``spec.models`` select architectures from
    ``repro.configs``; their profiles are re-measured live on this host
    (latency of both executed variants, accuracy per offload resolution on
    held-out frames), because serving schedules against reality, not against
    Table II.
    """
    from ..core import BandwidthEstimator, OnlineController
    from ..serving import (
        BatchedEndpoint,
        CalibrationConfig,
        EdgeBatchServer,
        VideoServer,
        calibrate,
        make_synthetic_video,
    )
    from ..session import _model_from_json

    n_classes = 10
    res = 32
    seed = spec.seed
    trace = spec.trace.build()
    net0 = trace.at(0.0)

    import dataclasses

    smoke = spec.n_frames <= 64
    cfg = CalibrationConfig.smoke(seed=seed) if smoke else CalibrationConfig(seed=seed)
    cfg = dataclasses.replace(
        cfg,
        model_names=tuple(m.name for m in spec.models),
        n_classes=n_classes,
        res=res,
        resolutions=spec.stream.resolutions,
    )
    cal = calibrate(cfg)
    models = [_model_from_json(m.payload) for m in cal.models]
    for m in cal.artifact["models"]:
        prov = m["provenance"]
        print(
            f"{m['name']}: t_npu={m['t_npu_ms']:.1f}ms t_server={m['t_server_ms']:.1f}ms "
            f"acc_npu={max(m['acc_npu'].values()):.3f} "
            f"agreement={prov['fp32_int8_agreement']:.3f} "
            f"quant_err={prov['quant_mean_rel_err']:.4f}",
            flush=True,
        )

    frames, labels = make_synthetic_video(spec.n_frames, n_classes=n_classes, res=res, seed=seed)

    npu_eps = {j: cm.npu_endpoint for j, cm in enumerate(cal.models)}
    # Edge inference goes through the batch server: one bucket-padded forward
    # per model per round, exactly like a shared edge GPU would take it.
    batched = {
        j: BatchedEndpoint(
            f"{cm.payload['name']}-edge-batch",
            lambda x, p=cm.params, f=cm.forward: f(p, x),
            max_batch=16,
        )
        for j, cm in enumerate(cal.models)
    }
    for ep in batched.values():
        ep.warmup(frames[0])
    edge_server = EdgeBatchServer(batched)

    controller = OnlineController(
        models=models,
        stream=spec.stream,
        policy=spec.policy,
        estimator=BandwidthEstimator(init_bps=net0.bandwidth_bps),
    )
    controller.estimator.observe_rtt(net0.rtt)
    server = VideoServer(
        controller=controller,
        npu_endpoints=npu_eps,
        stream=spec.stream,
        trace=trace,
        edge_server=edge_server,
    )
    summary = server.run(frames, labels)
    summary["policy"] = spec.policy.name
    summary["scheduler_rounds"] = controller.rounds
    summary["calibration"] = cal.artifact
    print(f"serve summary: { {k: v for k, v in summary.items() if k != 'calibration'} }", flush=True)
    return summary


def main(argv: list[str] | None = None) -> dict:
    from ..core.registry import PolicySpec, available_policies
    from ..session import ScenarioSpec, Session, TraceSpec

    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="max_accuracy", choices=available_policies())
    ap.add_argument("--alpha", type=float, default=200.0,
                    help="utility weight (only passed to policies that take alpha)")
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--bandwidth", type=float, default=2.0, help="Mbps")
    ap.add_argument("--rtt-ms", type=float, default=100.0)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..core import StreamSpec
    from ..core.registry import get_policy

    needs_alpha = any(p.name == "alpha" and p.required for p in get_policy(args.policy).params)
    spec = ScenarioSpec(
        policy=PolicySpec(args.policy, {"alpha": args.alpha} if needs_alpha else {}),
        n_frames=args.frames,
        stream=StreamSpec(fps=args.fps, deadline=args.deadline_ms / 1e3),
        trace=TraceSpec(mbps=args.bandwidth, rtt_ms=args.rtt_ms),
        seed=args.seed,
        label="launch.serve",
    )
    report = Session(spec).run_serving()
    return report.meta


if __name__ == "__main__":
    main()
