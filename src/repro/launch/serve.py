"""Serving driver: stand up NPU (int8) + edge (fp32) variants of a classifier
pair, profile them, and run the FastVA controller over a synthetic video.

    PYTHONPATH=src python -m repro.launch.serve --policy max_accuracy \
        --frames 200 --fps 30 --bandwidth 2.0

This is the end-to-end driver for the paper's kind (serving): batched frame
requests scheduled across the quantized local path and the full-precision
edge path under a per-frame deadline.  The CLI is a thin wrapper that builds
a declarative ``ScenarioSpec`` and routes it through ``Session.run_serving``;
``run_scenario`` is the engine that the Session facade calls back into.
"""
from __future__ import annotations

import argparse
import time
from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from ..session import ScenarioSpec

# How long each known classifier trains before profiling: enough to separate
# the fp32/int8 accuracy profiles on the synthetic video distribution.
TRAIN_STEPS = {"resnet-50": 150, "squeezenet": 400}


def run_scenario(spec: "ScenarioSpec") -> dict:
    """Build the real-model serving stack for ``spec`` and run it.

    The model *names* in ``spec.models`` select architectures from
    ``repro.configs``; their profiles are re-measured live on this host
    (latency) and on held-out synthetic frames (accuracy), because serving
    schedules against reality, not against Table II.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from .. import configs, quant
    from ..arch import classifier_forward
    from ..arch import abstract_params as arch_params
    from ..core import BandwidthEstimator, OnlineController, profile_ms
    from ..models.common import init_tree
    from ..serving import ModelEndpoint, VideoServer, make_synthetic_video

    n_classes = 10
    res = 32
    seed = spec.seed
    net0 = spec.trace.build().at(0.0)

    def quick_train(arch, params, state, *, steps=120, bs=32, lr=3e-3, seed=7):
        """Fit the classifier to the synthetic video distribution so the
        accuracy profiles (and the int8 drop) are real."""
        from ..train import optim

        cfgopt = optim.AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps, weight_decay=0.0)
        opt = optim.init_opt_state(params)
        tr_frames, tr_labels = make_synthetic_video(2048, n_classes=n_classes, res=res, seed=seed)

        def loss_fn(p, s, x, y):
            logits, ns = classifier_forward(arch, p, s, x, train=True)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1)), ns

        @jax.jit
        def step_fn(p, s, opt, x, y):
            (loss, ns), g = jax.value_and_grad(loss_fn, has_aux=True)(p, s, x, y)
            p2, opt2, _ = optim.adamw_update(cfgopt, p, g, opt)
            return p2, ns, opt2, loss

        rng = np.random.default_rng(seed)
        loss = None
        for i in range(steps):
            idx = rng.integers(0, len(tr_frames), bs)
            params, state, opt, loss = step_fn(
                params, state, opt, jnp.asarray(tr_frames[idx]), jnp.asarray(tr_labels[idx])
            )
        return params, state, float(loss)

    # The paper's model pair: accurate (resnet) vs compact (squeezenet).
    pair = []
    for m in spec.models:
        name = m.name
        tsteps = TRAIN_STEPS.get(name, 150)
        arch = configs.get(name, smoke=True)
        specs, state_specs = arch_params(arch)
        params = init_tree(jax.random.key(seed), specs)
        state = init_tree(jax.random.key(seed + 1), state_specs)
        params, state, final_loss = quick_train(arch, params, state, steps=tsteps)
        print(f"{name}: trained {tsteps} steps, loss={final_loss:.3f}", flush=True)
        qparams, qstats = quant.npu_variant(params)
        fwd = lambda p, x, a=arch, s=state: classifier_forward(a, p, s, x, train=False)[0]
        pair.append((name, arch, params, qparams, fwd, qstats))

    frames, labels = make_synthetic_video(spec.n_frames, n_classes=n_classes, res=res, seed=seed)
    x0 = jnp.asarray(frames[:1])

    # Profile both variants on this host; feed measured times + the paper's
    # accuracy table shape into the controller.
    models = []
    npu_eps, edge_eps = {}, {}
    for j, (name, arch, params, qparams, fwd, qstats) in enumerate(pair):
        npu = ModelEndpoint(f"{name}-npu", lambda x, p=qparams, f=fwd: f(p, x), profile_latency_s=0)
        edge = ModelEndpoint(f"{name}-edge", lambda x, p=params, f=fwd: f(p, x), profile_latency_s=0)
        npu.warmup(x0)
        edge.warmup(x0)
        t0 = time.perf_counter(); [npu(np.asarray(x0)) for _ in range(3)]
        t_npu = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter(); [edge(np.asarray(x0)) for _ in range(3)]
        t_edge = (time.perf_counter() - t0) / 3
        # Accuracy profile: measured agreement on held-out synthetic frames.
        hold, hold_labels = make_synthetic_video(128, n_classes=n_classes, res=res, seed=99)
        acc_fp = float(np.mean(np.argmax(edge.forward(jnp.asarray(hold)), -1) == hold_labels))
        acc_q = float(np.mean(np.argmax(npu.forward(jnp.asarray(hold)), -1) == hold_labels))
        models.append(
            profile_ms(
                name,
                t_npu_ms=max(t_npu * 1e3, 1.0),
                t_server_ms=max(t_edge * 1e3, 1.0),
                acc_server={45: acc_fp * 0.4, 90: acc_fp * 0.7, 134: acc_fp * 0.85,
                            179: acc_fp * 0.95, 224: acc_fp},
                acc_npu={224: acc_q},
            )
        )
        npu_eps[j], edge_eps[j] = npu, edge
        print(f"{name}: t_npu={t_npu*1e3:.1f}ms t_edge={t_edge*1e3:.1f}ms "
              f"acc_fp={acc_fp:.3f} acc_int8={acc_q:.3f} quant_err={qstats.mean_rel_err:.4f}",
              flush=True)

    controller = OnlineController(
        models=models,
        stream=spec.stream,
        policy=spec.policy,
        estimator=BandwidthEstimator(init_bps=net0.bandwidth_bps),
    )
    controller.estimator.observe_rtt(net0.rtt)
    server = VideoServer(
        controller=controller, npu_endpoints=npu_eps, edge_endpoints=edge_eps, stream=spec.stream
    )
    summary = server.run(frames, labels)
    summary["policy"] = spec.policy.name
    summary["scheduler_rounds"] = controller.rounds
    print(f"serve summary: {summary}", flush=True)
    return summary


def main(argv: list[str] | None = None) -> dict:
    from ..core.registry import PolicySpec, available_policies
    from ..session import ScenarioSpec, Session, TraceSpec

    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="max_accuracy", choices=available_policies())
    ap.add_argument("--alpha", type=float, default=200.0,
                    help="utility weight (only passed to policies that take alpha)")
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--bandwidth", type=float, default=2.0, help="Mbps")
    ap.add_argument("--rtt-ms", type=float, default=100.0)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..core import StreamSpec
    from ..core.registry import get_policy

    needs_alpha = any(p.name == "alpha" and p.required for p in get_policy(args.policy).params)
    spec = ScenarioSpec(
        policy=PolicySpec(args.policy, {"alpha": args.alpha} if needs_alpha else {}),
        n_frames=args.frames,
        stream=StreamSpec(fps=args.fps, deadline=args.deadline_ms / 1e3),
        trace=TraceSpec(mbps=args.bandwidth, rtt_ms=args.rtt_ms),
        seed=args.seed,
        label="launch.serve",
    )
    report = Session(spec).run_serving()
    return report.meta


if __name__ == "__main__":
    main()
