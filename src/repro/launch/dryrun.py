import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — hence the unusual module layout.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from .. import configs  # noqa: E402
from ..arch import n_params  # noqa: E402
from ..sharding.rules import MeshRules, serve_rules, train_rules  # noqa: E402
from ..train.optim import AdamWConfig  # noqa: E402
from . import analysis  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import build_cell  # noqa: E402


def _tokens_of(arch, shape) -> float:
    """Work units (tokens / patches / pixels-equivalents) for MODEL_FLOPS."""
    if arch.family == "lm":
        if shape.kind == "train":
            return shape.batch * shape.seq
        if shape.kind == "prefill":
            return shape.batch * shape.seq
        return shape.batch * 1.0  # decode: one token per sequence
    if arch.family in ("dit", "flux"):
        lat = shape.img // 8
        return shape.batch * (lat // arch.cfg.patch) ** 2
    return shape.batch * (shape.img // 16) ** 2  # vision: ~patch16 equivalents


def _active_params(arch) -> int:
    if arch.family == "lm" and arch.cfg.moe is not None:
        m = arch.cfg.moe
        full = n_params(arch)
        expert_p = 3 * m.d_model * m.d_ff_expert
        inactive = (m.n_experts - m.top_k) * expert_p * arch.cfg.n_layers
        return full - inactive
    return n_params(arch)


def run_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool,
    out_dir: Path | None,
    submesh: tuple[int, int] | None = None,
    kv_quant: bool = False,
) -> dict:
    """submesh=(data, model): serve on an N-chip replica instead of the full
    pod — the deployment lever for small-batch serving cells (per-replica
    collective cost is ~mesh-size-invariant, so K replicas = K x throughput).
    kv_quant: int8 KV cache for LM serve cells (halves the decode memory term)."""
    import dataclasses as _dc

    arch = configs.get(arch_name)
    shape = arch.shape(shape_name)
    if kv_quant and arch.family == "lm":
        arch = _dc.replace(arch, cfg=_dc.replace(arch.cfg, kv_quant=True))
    if submesh is not None:
        import jax as _jax

        mesh = _jax.make_mesh(
            submesh, ("data", "model"),
            axis_types=(_jax.sharding.AxisType.Auto,) * 2,
        )
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    is_train = "train" in shape.kind
    table = train_rules(mesh) if is_train else serve_rules(mesh)
    if arch.sharding_overrides:
        table.update(arch.sharding_overrides)
    rules = MeshRules(mesh, table)
    prog = build_cell(arch, shape_name, rules=rules, adamw=AdamWConfig())

    from ..models.layers import flash_accounting

    t0 = time.time()
    # jax.set_mesh is newer than 0.4.x; Mesh itself is a context manager.
    with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
        jitted = prog.jit()
        abstract = prog.abstract_args()
        lowered = jitted.lower(*abstract)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        # Flash-kernel variant: the attention inner body is one Pallas call
        # on TPU; XLA sees exactly the stubbed program around it.  Collectives
        # and memory for the kernel-enabled system come from THIS compile;
        # flops always from the real trace.
        with flash_accounting():
            compiled_flash = prog.jit(fresh=True).lower(*abstract).compile()

    mem = compiled.memory_analysis()
    mem_flash = compiled_flash.memory_analysis()
    hlo = compiled.as_text()
    coll = analysis.parse_collectives(hlo)
    coll_flash = analysis.parse_collectives(compiled_flash.as_text())
    jc = analysis.traced_costs(prog.fn, *abstract)
    with flash_accounting():
        jc_flash = analysis.traced_costs(prog.fn, *abstract)
    ca = compiled.cost_analysis() or {}
    # The flash kernel still needs full K/V per device when activations are
    # seq-sharded and the model is in the K/V-gather regime (2*KH*hd < D —
    # see models.lm._unshard_seq).  The stub's tiny K/V dependency lets DCE
    # drop that gather, so add it analytically (per-device result bytes).
    kv_gather_s = 0.0
    if arch.family == "lm" and shape.kind in ("prefill", "train"):
        cfg = arch.cfg
        if 2 * cfg.n_kv_heads * cfg.hd < cfg.d_model:
            traversals = 3.0 if shape.kind == "train" else 1.0
            kv_bytes = 2 * shape.seq * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers * traversals
            kv_gather_s = kv_bytes / analysis.LINK_BW
            coll_flash = dict(coll_flash)
            coll_flash["est_seconds"] = coll_flash["est_seconds"] + kv_gather_s
            coll_flash["kv_gather_s_analytic"] = kv_gather_s
    rf_noflash = analysis.roofline(jc.flops, jc.bytes, coll, chips)
    rf = analysis.roofline(jc.flops, jc_flash.bytes, coll_flash, chips)
    mf = analysis.model_flops(shape.kind, n_params(arch), _active_params(arch), _tokens_of(arch, shape))

    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": f"{submesh[0]}x{submesh[1]}" if submesh else ("2x16x16" if multi_pod else "16x16"),
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
                 - mem.alias_size_in_bytes) / 1e9, 3),
            **analysis.analytic_memory_gb(
                mem.argument_size_in_bytes, mem.output_size_in_bytes,
                mem.alias_size_in_bytes, shape.kind, mem.temp_size_in_bytes),
            "flash_peak_per_device_gb": round(
                (mem_flash.argument_size_in_bytes + mem_flash.output_size_in_bytes
                 + mem_flash.temp_size_in_bytes - mem_flash.alias_size_in_bytes) / 1e9, 3),
        },
        "flops_jaxpr": jc.flops,
        "bytes_jaxpr": jc.bytes,
        "bytes_jaxpr_flash": jc_flash.bytes,
        "xla_cost_flops": ca.get("flops", 0.0),
        "collectives": coll,
        "collectives_flash": coll_flash,
        "top_collectives": analysis.top_collective_sites(hlo),
        "top_collectives_flash": analysis.top_collective_sites(compiled_flash.as_text()),
        "top_cost_sites": analysis.top_cost_sites(prog.fn, *abstract),
        "roofline": rf,
        "roofline_no_flash_kernel": rf_noflash,
        "model_flops": mf,
        "useful_compute_ratio": mf / jc.flops if jc.flops else 0.0,
        "n_params": n_params(arch),
        "hlo_bytes": len(hlo),
    }
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"{rec['mesh']}__{arch_name}__{shape_name}.json"
        path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--submesh", default=None, help="DATAxMODEL serving replica, e.g. 4x4")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache for LM serve cells")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    submesh = None
    if args.submesh:
        d, m = args.submesh.lower().split("x")
        submesh = (int(d), int(m))

    out = Path(args.out)
    cells = configs.cells() if args.all else [(args.arch, args.shape)]
    if args.arch and not args.shape:
        cells = [(args.arch, s.name) for s in configs.get(args.arch).shapes]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    ok, failed = 0, []
    for arch_name, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_name}/{shape_name}@{args.submesh or ('2x16x16' if mp else '16x16')}"
            try:
                rec = run_cell(
                    arch_name, shape_name, multi_pod=mp, out_dir=out, submesh=submesh,
                    kv_quant=args.kv_quant,
                )
                r = rec["roofline"]
                print(
                    f"OK  {tag:55s} compile={rec['compile_s']:7.1f}s "
                    f"mem/dev={rec['memory']['peak_per_device_gb']:7.3f}GB "
                    f"compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                    f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}",
                    flush=True,
                )
                ok += 1
            except Exception as e:  # noqa: BLE001
                failed.append(tag)
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    print(f"\n{ok} cells OK, {len(failed)} failed")
    for f in failed:
        print("  FAILED:", f)
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
