"""Build lowering-ready step programs per (arch x shape) cell.

``build_cell(arch, shape_name, mesh_rules)`` returns a CellProgram with:
  fn             the step callable (train_step / prefill / decode / ...)
  abstract_args  ShapeDtypeStructs for .lower() (no allocation)
  in_shardings   NamedShardings (None entries -> replicated) when rules given
  donate         arg indices donated (train state / KV cache)

The same builder drives the multi-pod dry-run, the smoke tests (concrete
small args via init_args) and the benchmarks — one source of truth for what
"a step" means per family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .. import arch as A
from ..models import diffusion, lm
from ..models.common import ParamSpec, abstract_tree, activation_rules, init_tree
from ..sharding.rules import MeshRules
from ..train import optim


@dataclasses.dataclass
class CellProgram:
    name: str
    kind: str
    fn: Callable
    arg_specs: tuple  # pytrees of ParamSpec
    donate: tuple[int, ...] = ()
    rules: MeshRules | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    def abstract_args(self):
        return tuple(abstract_tree(s) for s in self.arg_specs)

    def shardings(self):
        if self.rules is None:
            return None
        return tuple(self.rules.tree_shardings(s) for s in self.arg_specs)

    def init_args(self, key=None):
        key = key if key is not None else jax.random.key(0)
        return tuple(init_tree(jax.random.fold_in(key, i), s) for i, s in enumerate(self.arg_specs))

    def jit(self, fresh: bool = False):
        kw: dict[str, Any] = {"donate_argnums": self.donate}
        sh = self.shardings()
        if sh is not None:
            kw["in_shardings"] = sh
        fn = (lambda *a: self.fn(*a)) if fresh else self.fn
        return jax.jit(fn, **kw)


def _cast_specs(specs, dtype):
    def cast(s: ParamSpec):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return ParamSpec(s.shape, s.axes, dtype, s.init, s.scale)
        return s

    return jax.tree.map(cast, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _shape_cfg(arch: A.Arch, shape: A.ShapeSpec) -> A.Arch:
    """Per-shape config overrides (long-context KV axis, 384px windows...)."""
    cfg = arch.cfg
    if arch.family == "lm" and shape.name.startswith("long_"):
        cfg = dataclasses.replace(cfg, kv_seq_axis="long_kv_seq")
    if arch.family == "lm" and shape.kind == "train":
        cfg = dataclasses.replace(cfg, seq_shard_acts=True)
    if arch.family == "vit" and shape.img and shape.img != cfg.img_res:
        cfg = dataclasses.replace(cfg, img_res=shape.img)
    if arch.family == "swin" and shape.img and shape.img != cfg.img_res:
        window = 12 if shape.img % (cfg.patch * 12 * 8) == 0 else cfg.window
        cfg = dataclasses.replace(cfg, img_res=shape.img, window=window)
    return dataclasses.replace(arch, cfg=cfg)


def _with_rules(rules, fn):
    def wrapped(*args):
        if rules is None:
            return fn(*args)
        with activation_rules(rules):
            return fn(*args)

    return wrapped


def build_cell(
    arch: A.Arch,
    shape_name: str,
    rules: MeshRules | None = None,
    adamw: optim.AdamWConfig | None = None,
    accum_steps: int = 1,
) -> CellProgram:
    """accum_steps > 1 splits the global batch into microbatches and
    accumulates grads before one optimizer update — the elastic-restart lever
    that preserves global-batch semantics when the data axis shrinks
    (runtime.plan_elastic_remesh's data_parallel_scale)."""
    shape = arch.shape(shape_name)
    arch = _shape_cfg(arch, shape)
    cfg = arch.cfg
    adamw = adamw or optim.AdamWConfig()
    param_specs, state_specs = A.abstract_params(arch)
    in_specs = A.input_specs(arch, shape)
    name = f"{arch.name}/{shape.name}"

    # ----- training kinds -------------------------------------------------
    if shape.kind in ("train", "denoise_train", "classify_train"):
        zeros_like_specs = jax.tree.map(
            lambda s: ParamSpec(s.shape, s.axes, jnp.float32, "zeros"),
            param_specs,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        ts_specs = {
            "params": param_specs,
            "state": state_specs,
            "opt": {
                "m": zeros_like_specs,
                "v": zeros_like_specs,
                "step": ParamSpec((), (), jnp.int32, "zeros"),
            },
        }

        if shape.kind == "train":

            def loss_fn(params, state, batch):
                loss, metrics = lm.train_loss(cfg, params, batch["tokens"], batch["labels"])
                return loss, (metrics, state)

        elif shape.kind == "denoise_train":
            if arch.family == "dit":

                def loss_fn(params, state, batch):
                    loss, m = diffusion.dit_train_loss(
                        cfg, params, batch["x"], batch["t"], batch["y"], batch["noise"]
                    )
                    return loss, (m, state)

            else:

                def loss_fn(params, state, batch):
                    loss, m = diffusion.flux_train_loss(
                        cfg, params, batch["x"], batch["txt"], batch["vec"], batch["t"], batch["noise"]
                    )
                    return loss, (m, state)

        else:  # classify_train

            def loss_fn(params, state, batch):
                logits, new_state = A.classifier_forward(
                    arch, params, state, batch["images"], train=True
                )
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
                gold = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
                loss = -jnp.mean(gold)
                return loss, ({"ce": loss}, new_state)

        def train_step(ts, batch):
            if accum_steps == 1:
                (loss, (metrics, new_state)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    ts["params"], ts["state"], batch
                )
            else:
                # Microbatch over the leading (batch) dim; grads averaged.
                micro = jax.tree.map(
                    lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                    batch,
                )

                def acc_body(carry, mb):
                    g_acc, loss_acc, state = carry
                    (loss, (metrics, state)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        ts["params"], state, mb
                    )
                    g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                    return (g_acc, loss_acc + loss, state), None

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), ts["params"])
                (grads, loss_sum, new_state), _ = jax.lax.scan(
                    acc_body, (zeros, jnp.zeros((), jnp.float32), ts["state"]), micro
                )
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
                loss = loss_sum / accum_steps
                metrics = {}
            new_params, new_opt, om = optim.adamw_update(adamw, ts["params"], grads, ts["opt"])
            out = {"params": new_params, "state": new_state, "opt": new_opt}
            return out, {"loss": loss, **metrics, **om}

        return CellProgram(
            name=name,
            kind=shape.kind,
            fn=_with_rules(rules, train_step),
            arg_specs=(ts_specs, in_specs),
            donate=(0,),
            rules=rules,
            meta={"arch": arch, "shape": shape},
        )

    # ----- serving kinds ---------------------------------------------------
    serve_params = _cast_specs(param_specs, jnp.bfloat16)
    serve_state = _cast_specs(state_specs, jnp.float32)

    if shape.kind == "prefill":

        def prefill_fn(params, batch):
            return lm.prefill(cfg, params, batch["tokens"])

        return CellProgram(
            name=name,
            kind=shape.kind,
            fn=_with_rules(rules, prefill_fn),
            arg_specs=(serve_params, in_specs),
            rules=rules,
            meta={"arch": arch, "shape": shape},
        )

    if shape.kind == "decode":
        cache = lm.cache_specs(cfg, shape.batch, shape.seq)
        # The cache arrives pre-filled to seq-1; the step appends one token.
        cache["len"] = ParamSpec((), (), jnp.int32, "zeros")

        def decode_fn(params, cache, batch):
            return lm.decode_step(cfg, params, batch["token"], cache)

        return CellProgram(
            name=name,
            kind=shape.kind,
            fn=_with_rules(rules, decode_fn),
            arg_specs=(serve_params, cache, in_specs),
            donate=(1,),
            rules=rules,
            meta={"arch": arch, "shape": shape},
        )

    if shape.kind == "denoise_step":
        if arch.family == "dit":

            def step_fn(params, batch):
                return diffusion.dit_sample_step(
                    cfg, params, batch["x"], batch["t"], batch["dt"], batch["y"]
                )

        else:

            def step_fn(params, batch):
                return diffusion.flux_sample_step(
                    cfg,
                    params,
                    batch["x"],
                    batch["txt"],
                    batch["vec"],
                    batch["t"],
                    batch["dt"],
                    batch["guidance"],
                )

        return CellProgram(
            name=name,
            kind=shape.kind,
            fn=_with_rules(rules, step_fn),
            arg_specs=(serve_params, in_specs),
            rules=rules,
            meta={"arch": arch, "shape": shape},
        )

    if shape.kind == "classify_serve":

        def serve_fn(params, state, batch):
            logits, _ = A.classifier_forward(arch, params, state, batch["images"], train=False)
            return logits

        return CellProgram(
            name=name,
            kind=shape.kind,
            fn=_with_rules(rules, serve_fn),
            arg_specs=(serve_params, serve_state, in_specs),
            rules=rules,
            meta={"arch": arch, "shape": shape},
        )

    raise ValueError(f"unhandled kind {shape.kind}")
