"""Production meshes.  A FUNCTION (not a module constant) so importing never
touches jax device state — required because smoke tests must see 1 device
while the dry-run sees 512 (XLA_FLAGS set by dryrun.py before any import).
"""
from __future__ import annotations

from functools import lru_cache

import jax
from jax.sharding import Mesh


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    # jax.sharding.AxisType landed after 0.4.x; on older jax the Auto axis
    # type is simply the (only) default, so omit the kwarg there.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (fake) host devices exist — for tests."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


@lru_cache(maxsize=None)
def make_sweep_mesh() -> Mesh:
    """1-D mesh over every local device, for scenario-parallel sweep groups.

    The sweep engines shard only the scenario (lane) axis — planner programs
    are embarrassingly parallel across lanes, so a flat mesh uses every
    device with zero cross-device traffic.  Cached: the device topology is
    fixed for the life of the process, and callers key compiled sharded
    programs on this mesh object.
    """
    return _mesh((jax.device_count(),), ("scenario",))
