"""Training driver: data pipeline -> jitted train_step -> async checkpoints,
with deterministic restart (checkpoint + data skip-ahead) and FT hooks.

    PYTHONPATH=src python -m repro.launch.train --arch resnet-50 --smoke \
        --steps 50 --batch 8 --img 32 --ckpt-dir /tmp/ckpt

On-cluster the same driver runs under the production mesh (--mesh single|
multi sets XLA device-count emulation only when requested; real pods just
see their actual devices).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="assigned shape name (full configs)")
    ap.add_argument("--smoke", action="store_true", help="reduced config + custom dims")
    ap.add_argument("--steps", type=int, default=20, help="steps to run this invocation")
    ap.add_argument(
        "--total-steps", type=int, default=None,
        help="schedule horizon (defaults to --steps); keep it FIXED across restarts",
    )
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--img", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    import jax

    from .. import configs
    from ..arch import ShapeSpec
    from ..checkpoint import AsyncCheckpointer, latest_step, restore
    from ..data import DataSpec, SyntheticStream, make_batch_iterator
    from ..runtime import StragglerMitigator
    from ..train.optim import AdamWConfig
    from .steps import build_cell

    arch = configs.get(args.arch, smoke=args.smoke)
    if args.shape and not args.smoke:
        shape_name = args.shape
        arch_run = arch
    else:
        fam = arch.family
        if fam == "lm":
            shape = ShapeSpec("cli_train", "train", args.batch, seq=args.seq)
        elif fam in ("dit", "flux"):
            shape = ShapeSpec("cli_train", "denoise_train", args.batch, img=args.img, steps=2)
        else:
            shape = ShapeSpec("cli_train", "classify_train", args.batch, img=args.img)
        arch_run = dataclasses.replace(arch, shapes=(shape,))
        shape_name = "cli_train"

    total = args.total_steps or args.steps
    adamw = AdamWConfig(lr=args.lr, warmup_steps=max(total // 10, 1), total_steps=total)
    prog = build_cell(arch_run, shape_name, adamw=adamw)
    step_fn = prog.jit()

    ts = prog.init_args(jax.random.key(args.seed))[0]
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                ts, extra = restore(args.ckpt_dir, last, ts)
                start = last
                print(f"resumed from step {start}", flush=True)

    stream = SyntheticStream(DataSpec(arch_run, arch_run.shape(shape_name), seed=args.seed))
    it = make_batch_iterator(stream, start_step=start)
    straggler = StragglerMitigator()

    losses = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        t0 = time.perf_counter()
        ts, metrics = step_fn(ts, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        straggler.observe("worker-0", dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                flush=True,
            )
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, ts, {"loss": loss})
    if ckpt:
        ckpt.save(args.steps, ts, {"loss": losses[-1]})
        ckpt.close()
    wall = time.time() - t_start
    result = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps": len(losses),
        "wall_s": wall,
    }
    print(f"done: {result}", flush=True)
    return result


if __name__ == "__main__":
    main()
