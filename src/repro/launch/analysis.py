"""Roofline analysis from dry-run artifacts.

Three data sources, each used for what it is reliable for:

1. **jaxpr walk** (``jaxpr_costs``) — exact structural FLOPs and a write-once
   bytes model.  XLA's compiled cost_analysis on the CPU backend counts while
   (scan) bodies once and loses FLOPs inside fusions, so we count dots/convs
   ourselves, multiplying scan bodies by their static length and traversing
   remat bodies as written (recompute counted where it happens).

2. **compiled HLO text** (``collective_bytes``) — per-collective result bytes,
   multiplied through the while-loop nesting using the ``known_trip_count``
   backend_config the partitioner attaches.  This is the collective-term
   source; cost_analysis has no collective view at all.

3. **compiled.memory_analysis()** — per-device bytes (argument/output/temp),
   the "does it fit" proof.

Roofline terms (TPU v5e targets):
  compute_s    = flops / chips / PEAK_FLOPS
  memory_s     = bytes / chips / HBM_BW
  collective_s = sum over ops of bytes * op_factor / LINK_BW   (per device)
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Any

import jax
import numpy as np

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link (approx, per direction)

_ELEMENTWISE_FREE = {"broadcast_in_dim", "reshape", "transpose", "convert_element_type",
                     "squeeze", "slice", "concatenate", "pad", "rev", "copy", "bitcast_convert_type"}


def _bytes_of(aval) -> int:
    try:
        return int(math.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0  # write-once model: eqn outputs + top-level inputs

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k)


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb))
    n = math.prod(s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb))
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    groups = eqn.params.get("feature_group_count", 1)
    kernel_elems = math.prod(rhs.shape)  # includes Cin/g and Cout
    spatial_out = math.prod(out.shape) / out.shape[-1] if out.ndim else 1
    # flops = 2 * out_positions * Cout * (Cin/g * prod(k)) = 2*spatial*kernel/g...
    # kernel_elems = prod(k)*Cin/g*Cout, so per-position MACs = kernel_elems/groups? No:
    # each output channel uses prod(k)*Cin/g MACs; total = spatial*Cout*prod(k)*Cin/g
    # = spatial * kernel_elems (since kernel_elems = prod(k)*(Cin/g)*Cout).
    return 2.0 * spatial_out * kernel_elems


def _sub_jaxprs(eqn):
    """Yield (closed_jaxpr, multiplier) for eqn's nested jaxprs."""
    p = eqn.params
    name = eqn.primitive.name
    if name == "scan":
        yield p["jaxpr"], float(p["length"])
        return
    if name == "while":
        yield p["body_jaxpr"], 1.0  # trip count unknown at jaxpr level
        yield p["cond_jaxpr"], 1.0
        return
    if name == "cond":
        branches = p.get("branches", ())
        if branches:
            # Upper bound: most expensive branch.
            costs = [jaxpr_costs(b) for b in branches]
            best = max(range(len(branches)), key=lambda i: costs[i].flops)
            yield branches[best], 1.0
        return
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            yield j, 1.0
            return


def jaxpr_costs(closed) -> Costs:
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    total = Costs()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        handled = False
        for sub, mult in _sub_jaxprs(eqn):
            total += jaxpr_costs(sub).scaled(mult)
            handled = True
        if handled and name in ("scan", "while", "cond", "pjit", "remat2", "checkpoint",
                                "custom_jvp_call", "custom_vjp_call", "closed_call",
                                "custom_vjp_call_jaxpr"):
            # carry/output traffic of the loop itself is negligible next to body
            continue
        out_bytes = sum(_bytes_of(v.aval) for v in eqn.outvars)
        if name == "dot_general":
            total += Costs(_dot_flops(eqn), out_bytes)
        elif name == "conv_general_dilated":
            total += Costs(_conv_flops(eqn), out_bytes)
        elif name in _ELEMENTWISE_FREE:
            total += Costs(0.0, out_bytes)
        else:
            elems = sum(int(math.prod(v.aval.shape)) for v in eqn.outvars if hasattr(v.aval, "shape"))
            total += Costs(float(elems), out_bytes)
    return total


def traced_costs(fn, *abstract_args) -> Costs:
    # Fresh wrapper per call: jax caches traces by function identity, which
    # would defeat context-dependent retraces (flash_accounting).
    closed = jax.make_jaxpr(lambda *a: fn(*a))(*abstract_args)
    c = jaxpr_costs(closed)
    c.bytes += sum(_bytes_of(v.aval) for v in closed.jaxpr.invars)
    return c


def _walk_sites(closed, mult: float, out: dict) -> None:
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        handled = False
        for sub, m in _sub_jaxprs(eqn):
            _walk_sites(sub, mult * m, out)
            handled = True
        if handled and name in ("scan", "while", "cond", "pjit", "remat2", "checkpoint",
                                "custom_jvp_call", "custom_vjp_call", "closed_call",
                                "custom_vjp_call_jaxpr"):
            continue
        out_bytes = sum(_bytes_of(v.aval) for v in eqn.outvars) * mult
        shape = tuple(eqn.outvars[0].aval.shape) if eqn.outvars else ()
        key = (name, shape)
        rec = out.setdefault(key, [0.0, 0.0, 0])
        rec[0] += out_bytes
        if name == "dot_general":
            rec[1] += _dot_flops(eqn) * mult
        elif name == "conv_general_dilated":
            rec[1] += _conv_flops(eqn) * mult
        rec[2] += 1


def top_cost_sites(fn, *abstract_args, k: int = 15) -> list[dict]:
    """Attribute the write-once bytes / flops to (primitive, shape) sites —
    the hillclimb loop's 'profile'."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    sites: dict = {}
    _walk_sites(closed, 1.0, sites)
    rows = [
        {"prim": name, "shape": list(shape), "bytes": b, "flops": f, "count": c}
        for (name, shape), (b, f, c) in sites.items()
    ]
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


def top_collective_sites(hlo_text: str, k: int = 12) -> list[dict]:
    """Largest collectives (trip-count weighted) from the compiled HLO."""
    comp_lines, edges = _computations_and_edges(hlo_text)
    mult = _propagate_multipliers(comp_lines, edges)
    rows = []
    for comp, lines in comp_lines.items():
        w = mult.get(comp, 0.0)
        if w == 0.0:
            continue
        for line in lines:
            mm = re.search(
                r"=\s+(\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(",
                line,
            )
            if mm:
                rows.append(
                    {
                        "kind": mm.group(2),
                        "type": mm.group(1)[:48],
                        "bytes": _shape_bytes(mm.group(1)) * w,
                        "trips": w,
                    }
                )
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


# ---------------------------------------------------------------------------
# Compiled-HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# Ring-algorithm data volume factors (x result/operand bytes), per device.
_OP_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _computations_and_edges(hlo_text: str):
    """Split HLO text into computations and extract reference edges
    comp -> (child, multiplier) with while trip counts."""
    comp_lines: dict[str, list[str]] = defaultdict(list)
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                current = m.group(1)
        if current is not None:
            comp_lines[current].append(line)

    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for comp, lines in comp_lines.items():
        for line in lines:
            trip = 1.0
            mt = re.search(r'known_trip_count":\{"n":"(\d+)"', line)
            mb = re.search(r"body=%([\w\.\-]+)", line)
            if mb:
                if mt:
                    trip = float(mt.group(1))
                edges[comp].append((mb.group(1), trip))
            for mm in re.finditer(r"(?:to_apply|calls)=%([\w\.\-]+)", line):
                edges[comp].append((mm.group(1), 1.0))
            mc = re.search(r"branch_computations=\{([^}]*)\}", line)
            if mc:
                for name in re.findall(r"%([\w\.\-]+)", mc.group(1)):
                    edges[comp].append((name, 1.0))
    return comp_lines, edges


def _propagate_multipliers(comp_lines, edges) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    start = next(iter(comp_lines), None)
    for comp, lines in comp_lines.items():
        if lines and lines[0].startswith("ENTRY"):
            start = comp
    stack = [(start, 1.0)]
    seen_guard = 0
    while stack and seen_guard < 100000:
        seen_guard += 1
        comp, k = stack.pop()
        mult[comp] += k
        for child, w in edges.get(comp, ()):  # conditions excluded (cheap)
            stack.append((child, k * w))
    return mult


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Sum per-device collective bytes, weighting while-body computations by
    their known_trip_count.  Returns totals by op kind + estimated seconds."""
    comp_lines, edges = _computations_and_edges(hlo_text)
    mult = _propagate_multipliers(comp_lines, edges)

    by_kind: dict[str, float] = defaultdict(float)
    count = 0
    for comp, lines in comp_lines.items():
        k = mult.get(comp, 0.0)
        if k == 0.0:
            continue
        for line in lines:
            mm = re.search(r"=\s+(\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", line)
            if not mm:
                continue
            nbytes = _shape_bytes(mm.group(1))
            by_kind[mm.group(2)] += nbytes * k
            count += 1
    seconds = sum(_OP_FACTOR[kind] * b / LINK_BW for kind, b in by_kind.items())
    return {"by_kind": dict(by_kind), "total_bytes": sum(by_kind.values()),
            "est_seconds": seconds, "op_sites": count}


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


def roofline(flops: float, bytes_: float, collective: dict, chips: int) -> dict[str, Any]:
    compute_s = flops / chips / PEAK_FLOPS
    memory_s = bytes_ / chips / HBM_BW
    collective_s = collective["est_seconds"]  # already per-device
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    return {
        **terms,
        "bottleneck": bottleneck,
        "step_s_lower_bound": step_s,
        "roofline_fraction": compute_s / step_s if step_s > 0 else 0.0,
    }


def model_flops(kind: str, n_params: int, n_active: int, tokens: float) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D forward-only."""
    n = n_active or n_params
    return (6.0 if "train" in kind else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# Analytic per-device TPU memory model
# ---------------------------------------------------------------------------
#
# compiled.memory_analysis() on the CPU backend inflates bf16 programs: the
# CPU has no native bf16 GEMM, so XLA hoists whole-weight-stack and KV-cache
# f32 conversions that a TPU (native bf16 MXU) never materializes.  The
# analytic model below counts what actually lives in TPU HBM:
#   train: params f32 + grads f32 + Adam m/v f32 (all sharded like the
#          params) + bf16 weight copies + remat stash + logits buffers
#   serve: bf16 params (TP-sharded) + KV cache / activation peak
# It is reported next to the measured number as memory.analytic_gb.


def analytic_memory_gb(arg_bytes: int, out_bytes: int, alias_bytes: int, kind: str,
                       temp_bytes: int) -> dict:
    """Conservative TPU estimate from the measured components.

    arguments+outputs are dtype-accurate (they come from our specs, not from
    CPU lowering); temp is CPU-inflated.  The TPU temp estimate strips the
    hoisted f32 copies: empirically they account for ~60-70% of CPU temp on
    bf16-heavy programs, so we bound TPU temp at 40% of CPU temp for serve
    programs (pure bf16) and 60% for train (mixed f32 master/bf16 compute).
    Both the raw and adjusted numbers are reported; the adjusted one is the
    fit-claim, the raw one the hard upper bound.
    """
    live_args = arg_bytes + out_bytes - alias_bytes
    factor = 0.6 if "train" in kind else 0.4
    return {
        "upper_bound_gb": round((live_args + temp_bytes) / 1e9, 3),
        "tpu_estimate_gb": round((live_args + factor * temp_bytes) / 1e9, 3),
    }
