"""vit-s16 [arXiv:2010.11929]: 224px patch 16, 12L d384 6H d_ff 1536."""
from ..arch import Arch
from ..models import vision
from .shapes import VISION_SHAPES

CONFIG = Arch(
    name="vit-s16",
    family="vit",
    cfg=vision.ViTConfig(
        name="vit-s16", img_res=224, patch=16, n_layers=12, d_model=384, n_heads=6, d_ff=1536
    ),
    shapes=VISION_SHAPES,
    notes="cls_384 re-inits pos-emb at the 384 grid (interpolation equivalent for dry-run).",
)

SMOKE = Arch(
    name="vit-s16-smoke",
    family="vit",
    cfg=vision.ViTConfig(
        name="vit-smoke", img_res=32, patch=8, n_layers=2, d_model=64, n_heads=4, d_ff=128, n_classes=10
    ),
    shapes=VISION_SHAPES,
)
