"""qwen3-0.6b [hf:Qwen/Qwen3-0.6B]: 28L d1024 16H (kv=8, head_dim=128)
d_ff=3072, vocab 151936, qk-norm."""
from ..arch import Arch
from ..models import lm
from .shapes import LM_SHAPES

CONFIG = Arch(
    name="qwen3-0.6b",
    family="lm",
    cfg=lm.LMConfig(
        name="qwen3-0.6b",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab=151936,
        qk_norm=True,
    ),
    shapes=LM_SHAPES,
    notes="Dense GQA with qk-norm; kv=8 heads replicate over the 16-way model axis "
    "(head_dim shards instead via the reuse-guarded rules).",
)

SMOKE = Arch(
    name="qwen3-0.6b-smoke",
    family="lm",
    cfg=lm.LMConfig(
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        qk_norm=True,
        remat=False,
    ),
    shapes=LM_SHAPES,
)
