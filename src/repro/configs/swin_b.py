"""swin-b [arXiv:2103.14030]: 224px patch 4 window 7, depths 2-2-18-2,
dims 128-256-512-1024."""
from ..arch import Arch
from ..models import vision
from .shapes import VISION_SHAPES

CONFIG = Arch(
    name="swin-b",
    family="swin",
    cfg=vision.SwinConfig(name="swin-b", img_res=224),
    shapes=VISION_SHAPES,
    notes="cls_384 uses window 12 (as Swin-B-384 does) via per-shape cfg override.",
)

SMOKE = Arch(
    name="swin-b-smoke",
    family="swin",
    cfg=vision.SwinConfig(
        name="swin-smoke",
        img_res=32,
        patch=4,
        window=4,
        depths=(2, 2),
        dims=(32, 64),
        n_heads=(2, 4),
        n_classes=10,
    ),
    shapes=VISION_SHAPES,
)
