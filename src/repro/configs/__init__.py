"""Architecture registry: --arch <id> resolves here.

10 assigned architectures + the paper's own compact model (squeezenet).
Each module exports CONFIG (the exact published config) and SMOKE (a reduced
same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from ..arch import Arch

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-0.6b": "qwen3_0_6b",
    "command-r-35b": "command_r_35b",
    "dit-xl2": "dit_xl2",
    "flux-dev": "flux_dev",
    "vit-s16": "vit_s16",
    "efficientnet-b7": "efficientnet_b7",
    "swin-b": "swin_b",
    "resnet-50": "resnet_50",
    "squeezenet": "squeezenet",
}

ASSIGNED = tuple(k for k in _MODULES if k != "squeezenet")
ALL = tuple(_MODULES)


def get(name: str, *, smoke: bool = False) -> Arch:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


def cells() -> list[tuple[str, str]]:
    """All 40 assigned (arch, shape) dry-run cells (+ squeezenet's 4 extra)."""
    out = []
    for name in ASSIGNED:
        a = get(name)
        for s in a.shapes:
            out.append((name, s.name))
    return out
