"""efficientnet-b7 [arXiv:1905.11946]: width 2.0, depth 3.1 (B0 base).
Assigned vision shapes run it at 224/384 (B7-native 600 is the arch's own
resolution; the shape grid overrides input res)."""
from ..arch import Arch
from ..models import convnets
from .shapes import VISION_SHAPES

CONFIG = Arch(
    name="efficientnet-b7",
    family="effnet",
    cfg=convnets.EfficientNetConfig(name="efficientnet-b7", width_mult=2.0, depth_mult=3.1),
    shapes=VISION_SHAPES,
)

SMOKE = Arch(
    name="efficientnet-b7-smoke",
    family="effnet",
    cfg=convnets.EfficientNetConfig(
        name="effnet-smoke", width_mult=0.25, depth_mult=0.34, n_classes=10
    ),
    shapes=VISION_SHAPES,
)
