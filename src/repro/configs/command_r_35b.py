"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified]: 40L d8192
64H (kv=8) d_ff=22528, vocab 256000, GQA, no-bias."""
from ..arch import Arch
from ..models import lm
from .shapes import LM_SHAPES

CONFIG = Arch(
    name="command-r-35b",
    family="lm",
    cfg=lm.LMConfig(
        name="command-r-35b",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab=256000,
        seq_shard_acts=True,
    ),
    shapes=LM_SHAPES,
    notes="Dense 35B; trains with FSDP(data) x TP(model) + Megatron-SP activation "
    "sharding; sequential (not parallel) block residual — documented deviation.",
)

SMOKE = Arch(
    name="command-r-35b-smoke",
    family="lm",
    cfg=lm.LMConfig(
        name="command-r-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=192,
        vocab=512,
        remat=False,
    ),
    shapes=LM_SHAPES,
)
