"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H (kv=16) expert
d_ff=1408, vocab 151936; 4 shared + 60 routed top-4.

EP note: 60 routed experts are padded to 64 so the expert dim shards over the
16-way model axis (the 4 pad experts get ~zero router mass; recorded in
DESIGN.md §Arch-applicability).
"""
from ..arch import Arch
from ..models import layers as L
from ..models import lm
from .shapes import LM_SHAPES

CONFIG = Arch(
    name="qwen2-moe-a2.7b",
    family="lm",
    cfg=lm.LMConfig(
        name="qwen2-moe-a2.7b",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=5632,
        vocab=151936,
        moe=L.MoECfg(
            d_model=2048,
            d_ff_expert=1408,
            n_experts=64,  # 60 routed + 4 pad (EP divisibility)
            top_k=4,
            n_shared=4,
            d_ff_shared=5632,
        ),
    ),
    shapes=LM_SHAPES,
    notes="MoE 60e top-4 padded to 64 for EP; 4 shared experts as dense SwiGLU.",
)

SMOKE = Arch(
    name="qwen2-moe-a2.7b-smoke",
    family="lm",
    cfg=lm.LMConfig(
        name="qwen2-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        remat=False,
        moe=L.MoECfg(d_model=64, d_ff_expert=32, n_experts=8, top_k=4, n_shared=2, d_ff_shared=128),
    ),
    shapes=LM_SHAPES,
)
