"""flux-dev [BFL tech report; unverified]: MMDiT rectified flow, 19 double +
38 single blocks, d3072 24H, 12B params, img 1024 (latent 128)."""
from ..arch import Arch
from ..models import diffusion
from .shapes import DIFFUSION_SHAPES

CONFIG = Arch(
    name="flux-dev",
    family="flux",
    cfg=diffusion.FluxConfig(name="flux-dev"),
    shapes=DIFFUSION_SHAPES,
    notes="Text stream stubbed as precomputed T5-dim embeddings (modality-stub rule); "
    "2D sincos pos instead of 3D RoPE — documented simplification.",
    # 24 heads % 16 != 0: sharding head_dim instead only buys qkv re-gathers
    # (EXPERIMENTS.md §Perf flux iteration 2) — replicate attention weights
    # (~5.7 GB bf16/dev) and TP the MLPs.
    sharding_overrides={"head_dim": None},
)

SMOKE = Arch(
    name="flux-dev-smoke",
    family="flux",
    cfg=diffusion.FluxConfig(
        name="flux-smoke",
        img_res=64,
        latent_res=8,
        patch=2,
        n_double=2,
        n_single=2,
        d_model=64,
        n_heads=4,
        in_ch=4,
        txt_len=8,
        txt_dim=32,
        vec_dim=16,
        remat=False,
    ),
    shapes=DIFFUSION_SHAPES,
)
