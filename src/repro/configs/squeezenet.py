"""squeezenet [arXiv:1602.07360] — the paper's own compact model (FastVA
Table II pairs it with ResNet-50 as the fast/low-accuracy option)."""
from ..arch import Arch
from ..models import convnets
from .shapes import VISION_SHAPES

CONFIG = Arch(
    name="squeezenet",
    family="squeezenet",
    cfg=convnets.SqueezeNetConfig(name="squeezenet"),
    shapes=VISION_SHAPES,
)

SMOKE = Arch(
    name="squeezenet-smoke",
    family="squeezenet",
    cfg=convnets.SqueezeNetConfig(name="squeezenet-smoke", n_classes=10),
    shapes=VISION_SHAPES,
)
