"""Assigned input-shape sets per family (verbatim from the assignment)."""
from ..arch import ShapeSpec

LM_SHAPES = (
    ShapeSpec("train_4k", "train", batch=256, seq=4096),
    ShapeSpec("prefill_32k", "prefill", batch=32, seq=32768),
    ShapeSpec("decode_32k", "decode", batch=128, seq=32768),
    # decode against a 512k cache: one token, linear in cache length, so it is
    # runnable for full-attention archs with a sequence-sharded KV (DESIGN §4).
    ShapeSpec("long_500k", "decode", batch=1, seq=524288),
)

DIFFUSION_SHAPES = (
    ShapeSpec("train_256", "denoise_train", batch=256, img=256, steps=1000),
    ShapeSpec("gen_1024", "denoise_step", batch=4, img=1024, steps=50),
    ShapeSpec("gen_fast", "denoise_step", batch=16, img=512, steps=4),
    ShapeSpec("train_1024", "denoise_train", batch=32, img=1024, steps=1000),
)

VISION_SHAPES = (
    ShapeSpec("cls_224", "classify_train", batch=256, img=224),
    ShapeSpec("cls_384", "classify_train", batch=64, img=384),
    ShapeSpec("serve_b1", "classify_serve", batch=1, img=224),
    ShapeSpec("serve_b128", "classify_serve", batch=128, img=224),
)
