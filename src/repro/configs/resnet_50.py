"""resnet-50 [arXiv:1512.03385]: depths 3-4-6-3, width 64, bottleneck x4.
Also the paper's own accurate model (FastVA Table II)."""
from ..arch import Arch
from ..models import convnets
from .shapes import VISION_SHAPES

CONFIG = Arch(
    name="resnet-50",
    family="resnet",
    cfg=convnets.ResNetConfig(name="resnet-50"),
    shapes=VISION_SHAPES,
    notes="Sync-BN via global-batch jnp.mean under SPMD.",
)

SMOKE = Arch(
    name="resnet-50-smoke",
    family="resnet",
    cfg=convnets.ResNetConfig(name="resnet-smoke", depths=(1, 1), width=8, n_classes=10),
    shapes=VISION_SHAPES,
)
