"""dit-xl2 [arXiv:2212.09748]: img 256, patch 2 (on /8 VAE latents), 28L
d1152 16H."""
from ..arch import Arch
from ..models import diffusion
from .shapes import DIFFUSION_SHAPES

CONFIG = Arch(
    name="dit-xl2",
    family="dit",
    cfg=diffusion.DiTConfig(
        name="dit-xl2", img_res=256, patch=2, n_layers=28, d_model=1152, n_heads=16, remat=True
    ),
    shapes=DIFFUSION_SHAPES,
    notes="adaLN-Zero DiT; gen shapes use larger latents (pos-emb is sincos, computed per shape).",
)

SMOKE = Arch(
    name="dit-xl2-smoke",
    family="dit",
    cfg=diffusion.DiTConfig(
        name="dit-smoke", img_res=64, patch=2, n_layers=2, d_model=64, n_heads=4, remat=False
    ),
    shapes=DIFFUSION_SHAPES,
)
