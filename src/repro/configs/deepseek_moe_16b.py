"""deepseek-moe-16b [arXiv:2401.06066]: 28L d2048 16H (kv=16) expert
d_ff=1408, vocab 102400; 2 shared + 64 routed top-6 (fine-grained)."""
from ..arch import Arch
from ..models import layers as L
from ..models import lm
from .shapes import LM_SHAPES

CONFIG = Arch(
    name="deepseek-moe-16b",
    family="lm",
    cfg=lm.LMConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=2816,
        vocab=102400,
        moe=L.MoECfg(
            d_model=2048,
            d_ff_expert=1408,
            n_experts=64,
            top_k=6,
            n_shared=2,
            d_ff_shared=2816,
        ),
    ),
    shapes=LM_SHAPES,
    notes="Fine-grained MoE: 64 routed top-6 + 2 shared experts.",
)

SMOKE = Arch(
    name="deepseek-moe-16b-smoke",
    family="lm",
    cfg=lm.LMConfig(
        name="deepseek-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        remat=False,
        moe=L.MoECfg(d_model=64, d_ff_expert=32, n_experts=8, top_k=6, n_shared=2, d_ff_shared=64),
    ),
    shapes=LM_SHAPES,
)
