"""Convolutional classifiers: ResNet-50, EfficientNet-B7, SqueezeNet.

BatchNorm models carry a separate mutable ``state`` tree (running mean/var);
``forward(..., train=True)`` returns (logits, new_state).  Stages scan their
repeated identical blocks (stacked params) so the 512-device SPMD compile of
EfficientNet-B7's 55 blocks stays tractable.
"""
from __future__ import annotations

import dataclasses
import math


import jax
import jax.numpy as jnp

from .common import current_matmul, matmul, shard, spec
from .lm import _stack

BN_MOMENTUM = 0.9


def conv_spec(kh, kw, cin, cout, name_in="conv_in", name_out="conv_out"):
    return spec((kh, kw, cin, cout), (None, None, name_in, name_out), init="conv")


def conv(p, x, stride=1, padding="SAME", groups=1):
    if current_matmul() is not None and groups == 1:
        return _conv_via_matmul(p, x, stride, padding)
    return jax.lax.conv_general_dilated(
        x,
        p.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _conv_via_matmul(p, x, stride, padding):
    """im2col lowering: the conv as ONE [B*H'*W', KH*KW*Cin] x [., Cout] GEMM
    through the active matmul backend — how NPUs (and the int8 Pallas path)
    actually execute convolutions.  Depthwise convs (groups > 1) stay on
    lax.conv: they are channel-parallel scalar products, not GEMMs."""
    kh, kw, cin, cout = p.shape
    w2d = p.astype(x.dtype)
    if (kh, kw) == (1, 1) and stride == 1:  # pointwise: a matmul over channels
        return matmul(x, w2d.reshape(cin, cout))
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H', W', Cin*KH*KW] with Cin slowest (lax patch order)
    w2d = jnp.transpose(w2d, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    return matmul(patches, w2d)


def bn_specs(ch):
    return {
        "scale": spec((ch,), ("channels",), init="ones"),
        "bias": spec((ch,), ("channels",), init="zeros"),
    }


def bn_state_specs(ch):
    return {
        "mean": spec((ch,), ("channels",), init="zeros"),
        "var": spec((ch,), ("channels",), init="ones"),
    }


def batchnorm(p, s, x, train: bool, eps=1e-5):
    """Returns (y, new_state).  In SPMD training the jnp.mean over the global
    batch/space dims is what produces the cross-device all-reduce (sync-BN)."""
    if train:
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32, axis=(0, 1, 2))
        var = jnp.var(x32, axis=(0, 1, 2))
        new_s = {
            "mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    inv = jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    y = (x.astype(jnp.float32) - mean) * inv + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_s


def maxpool(x, window=3, stride=2, padding="SAME"):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, stride, stride, 1), padding
    )


# ---------------------------------------------------------------------------
# ResNet-50
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str
    depths: tuple[int, ...] = (3, 4, 6, 3)
    width: int = 64
    n_classes: int = 1000
    expansion: int = 4


def _bottleneck_specs(cin, cmid, cout, stride):
    s = {
        "conv1": conv_spec(1, 1, cin, cmid),
        "bn1": bn_specs(cmid),
        "conv2": conv_spec(3, 3, cmid, cmid),
        "bn2": bn_specs(cmid),
        "conv3": conv_spec(1, 1, cmid, cout),
        "bn3": bn_specs(cout),
    }
    if stride != 1 or cin != cout:
        s["proj"] = conv_spec(1, 1, cin, cout)
        s["bn_proj"] = bn_specs(cout)
    return s


def _bottleneck_state(cin, cmid, cout, stride):
    s = {"bn1": bn_state_specs(cmid), "bn2": bn_state_specs(cmid), "bn3": bn_state_specs(cout)}
    if stride != 1 or cin != cout:
        s["bn_proj"] = bn_state_specs(cout)
    return s


def resnet_abstract(c: ResNetConfig) -> tuple[dict, dict]:
    params: dict = {"stem": {"conv": conv_spec(7, 7, 3, c.width), "bn": bn_specs(c.width)}}
    state: dict = {"stem": {"bn": bn_state_specs(c.width)}}
    cin = c.width
    for i, depth in enumerate(c.depths):
        cmid = c.width * (2**i)
        cout = cmid * c.expansion
        stride = 1 if i == 0 else 2
        params[f"stage{i}_first"] = _bottleneck_specs(cin, cmid, cout, stride)
        state[f"stage{i}_first"] = _bottleneck_state(cin, cmid, cout, stride)
        if depth > 1:
            params[f"stage{i}_rest"] = _stack(_bottleneck_specs(cout, cmid, cout, 1), depth - 1)
            state[f"stage{i}_rest"] = _stack(_bottleneck_state(cout, cmid, cout, 1), depth - 1)
        cin = cout
    params["head"] = {
        "w": spec((cin, c.n_classes), ("embed", "vocab")),
        "b": spec((c.n_classes,), ("vocab",), init="zeros"),
    }
    return params, state


def _bottleneck(p, s, x, stride, train):
    ns = {}
    h, ns["bn1"] = batchnorm(p["bn1"], s["bn1"], conv(p["conv1"], x), train)
    h = jax.nn.relu(h)
    h, ns["bn2"] = batchnorm(p["bn2"], s["bn2"], conv(p["conv2"], h, stride=stride), train)
    h = jax.nn.relu(h)
    h, ns["bn3"] = batchnorm(p["bn3"], s["bn3"], conv(p["conv3"], h), train)
    if "proj" in p:
        sc, ns["bn_proj"] = batchnorm(p["bn_proj"], s["bn_proj"], conv(p["proj"], x, stride=stride), train)
    else:
        sc = x
    return jax.nn.relu(h + sc), ns


def resnet_forward(c: ResNetConfig, params, state, images, *, train: bool = False):
    x = images.astype(jnp.bfloat16)
    ns: dict = {"stem": {}}
    x = conv(params["stem"]["conv"], x, stride=2)
    x, ns["stem"]["bn"] = batchnorm(params["stem"]["bn"], state["stem"]["bn"], x, train)
    x = maxpool(jax.nn.relu(x))
    for i, depth in enumerate(c.depths):
        stride = 1 if i == 0 else 2
        x, ns[f"stage{i}_first"] = _bottleneck(
            params[f"stage{i}_first"], state[f"stage{i}_first"], x, stride, train
        )
        if depth > 1:

            def body(x, ps):
                p, s = ps
                y, s2 = _bottleneck(p, s, x, 1, train)
                return y, s2

            x, ns[f"stage{i}_rest"] = jax.lax.scan(
                body, x, (params[f"stage{i}_rest"], state[f"stage{i}_rest"])
            )
        x = shard(x, "batch", None, None, None)
    h = x.mean(axis=(1, 2))
    logits = matmul(h, params["head"]["w"].astype(h.dtype)) + params["head"]["b"].astype(h.dtype)
    return logits.astype(jnp.float32), ns


# ---------------------------------------------------------------------------
# EfficientNet (B0 base scaled by width/depth multipliers; B7 = 2.0 / 3.1)
# ---------------------------------------------------------------------------

EFFNET_B0_BLOCKS = (  # (expand, channels, repeats, stride, kernel)
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
)


def _round_filters(ch: float, mult: float, divisor: int = 8) -> int:
    ch *= mult
    new = max(divisor, int(ch + divisor / 2) // divisor * divisor)
    if new < 0.9 * ch:
        new += divisor
    return int(new)


@dataclasses.dataclass(frozen=True)
class EfficientNetConfig:
    name: str
    width_mult: float = 1.0
    depth_mult: float = 1.0
    n_classes: int = 1000
    se_ratio: float = 0.25

    def stages(self):
        out = []
        for expand, ch, reps, stride, k in EFFNET_B0_BLOCKS:
            out.append(
                (
                    expand,
                    _round_filters(ch, self.width_mult),
                    int(math.ceil(reps * self.depth_mult)),
                    stride,
                    k,
                )
            )
        return out

    @property
    def stem_ch(self) -> int:
        return _round_filters(32, self.width_mult)

    @property
    def head_ch(self) -> int:
        return _round_filters(1280, self.width_mult)


def _mbconv_specs(cin, cout, expand, k, se_ratio):
    cmid = cin * expand
    s: dict = {}
    if expand != 1:
        s["expand"] = conv_spec(1, 1, cin, cmid)
        s["bn_e"] = bn_specs(cmid)
    s["dw"] = spec((k, k, 1, cmid), (None, None, None, "conv_out"), init="conv")
    s["bn_d"] = bn_specs(cmid)
    cse = max(1, int(cin * se_ratio))
    s["se_r"] = {"w": conv_spec(1, 1, cmid, cse), "b": spec((cse,), (None,), init="zeros")}
    s["se_e"] = {"w": conv_spec(1, 1, cse, cmid), "b": spec((cmid,), (None,), init="zeros")}
    s["project"] = conv_spec(1, 1, cmid, cout)
    s["bn_p"] = bn_specs(cout)
    return s


def _mbconv_state(cin, cout, expand):
    cmid = cin * expand
    s: dict = {"bn_d": bn_state_specs(cmid), "bn_p": bn_state_specs(cout)}
    if expand != 1:
        s["bn_e"] = bn_state_specs(cmid)
    return s


def effnet_abstract(c: EfficientNetConfig) -> tuple[dict, dict]:
    params: dict = {"stem": {"conv": conv_spec(3, 3, 3, c.stem_ch), "bn": bn_specs(c.stem_ch)}}
    state: dict = {"stem": {"bn": bn_state_specs(c.stem_ch)}}
    cin = c.stem_ch
    for i, (expand, cout, reps, stride, k) in enumerate(c.stages()):
        params[f"stage{i}_first"] = _mbconv_specs(cin, cout, expand, k, c.se_ratio)
        state[f"stage{i}_first"] = _mbconv_state(cin, cout, expand)
        if reps > 1:
            params[f"stage{i}_rest"] = _stack(_mbconv_specs(cout, cout, expand, k, c.se_ratio), reps - 1)
            state[f"stage{i}_rest"] = _stack(_mbconv_state(cout, cout, expand), reps - 1)
        cin = cout
    params["head_conv"] = {"conv": conv_spec(1, 1, cin, c.head_ch), "bn": bn_specs(c.head_ch)}
    state["head_conv"] = {"bn": bn_state_specs(c.head_ch)}
    params["head"] = {
        "w": spec((c.head_ch, c.n_classes), ("embed", "vocab")),
        "b": spec((c.n_classes,), ("vocab",), init="zeros"),
    }
    return params, state


def _mbconv(p, s, x, stride, k, train):
    ns: dict = {}
    h = x
    if "expand" in p:
        h, ns["bn_e"] = batchnorm(p["bn_e"], s["bn_e"], conv(p["expand"], h), train)
        h = jax.nn.silu(h)
    cmid = h.shape[-1]
    h2 = conv(p["dw"], h, stride=stride, groups=cmid)
    h, ns["bn_d"] = batchnorm(p["bn_d"], s["bn_d"], h2, train)
    h = jax.nn.silu(h)
    # Squeeze-and-excitation.
    z = h.mean(axis=(1, 2), keepdims=True)
    z = jax.nn.silu(conv(p["se_r"]["w"], z) + p["se_r"]["b"].astype(z.dtype))
    z = jax.nn.sigmoid(conv(p["se_e"]["w"], z) + p["se_e"]["b"].astype(z.dtype))
    h = h * z
    h, ns["bn_p"] = batchnorm(p["bn_p"], s["bn_p"], conv(p["project"], h), train)
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h, ns


def effnet_forward(c: EfficientNetConfig, params, state, images, *, train: bool = False):
    x = images.astype(jnp.bfloat16)
    ns: dict = {"stem": {}, "head_conv": {}}
    x = conv(params["stem"]["conv"], x, stride=2)
    x, ns["stem"]["bn"] = batchnorm(params["stem"]["bn"], state["stem"]["bn"], x, train)
    x = jax.nn.silu(x)
    for i, (expand, cout, reps, stride, k) in enumerate(c.stages()):
        x, ns[f"stage{i}_first"] = _mbconv(
            params[f"stage{i}_first"], state[f"stage{i}_first"], x, stride, k, train
        )
        if reps > 1:

            def body(x, ps, k=k):
                p, s = ps
                y, s2 = _mbconv(p, s, x, 1, k, train)
                return y, s2

            x, ns[f"stage{i}_rest"] = jax.lax.scan(
                body, x, (params[f"stage{i}_rest"], state[f"stage{i}_rest"])
            )
        x = shard(x, "batch", None, None, None)
    x = conv(params["head_conv"]["conv"], x)
    x, ns["head_conv"]["bn"] = batchnorm(params["head_conv"]["bn"], state["head_conv"]["bn"], x, train)
    h = jax.nn.silu(x).mean(axis=(1, 2))
    logits = matmul(h, params["head"]["w"].astype(h.dtype)) + params["head"]["b"].astype(h.dtype)
    return logits.astype(jnp.float32), ns


# ---------------------------------------------------------------------------
# SqueezeNet v1.1 (the paper's compact model)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SqueezeNetConfig:
    name: str = "squeezenet"
    n_classes: int = 1000


FIRE_CFG = (  # (squeeze, expand) after each pool stage
    ((16, 64), (16, 64)),
    ((32, 128), (32, 128)),
    ((48, 192), (48, 192), (64, 256), (64, 256)),
)


def _fire_specs(cin, sq, ex):
    return {
        "squeeze": {"w": conv_spec(1, 1, cin, sq), "b": spec((sq,), (None,), init="zeros")},
        "e1": {"w": conv_spec(1, 1, sq, ex), "b": spec((ex,), (None,), init="zeros")},
        "e3": {"w": conv_spec(3, 3, sq, ex), "b": spec((ex,), (None,), init="zeros")},
    }


def squeezenet_abstract(c: SqueezeNetConfig) -> tuple[dict, dict]:
    params: dict = {
        "stem": {"w": conv_spec(3, 3, 3, 64), "b": spec((64,), (None,), init="zeros")}
    }
    cin = 64
    for gi, group in enumerate(FIRE_CFG):
        for fi, (sq, ex) in enumerate(group):
            params[f"fire{gi}_{fi}"] = _fire_specs(cin, sq, ex)
            cin = 2 * ex
    params["classifier"] = {
        "w": conv_spec(1, 1, cin, c.n_classes),
        "b": spec((c.n_classes,), (None,), init="zeros"),
    }
    return params, {}


def _fire(p, x):
    s = jax.nn.relu(conv(p["squeeze"]["w"], x) + p["squeeze"]["b"].astype(x.dtype))
    e1 = conv(p["e1"]["w"], s) + p["e1"]["b"].astype(x.dtype)
    e3 = conv(p["e3"]["w"], s) + p["e3"]["b"].astype(x.dtype)
    return jax.nn.relu(jnp.concatenate([e1, e3], axis=-1))


def squeezenet_forward(c: SqueezeNetConfig, params, state, images, *, train: bool = False):
    x = images.astype(jnp.bfloat16)
    x = jax.nn.relu(conv(params["stem"]["w"], x, stride=2) + params["stem"]["b"].astype(x.dtype))
    for gi, group in enumerate(FIRE_CFG):
        x = maxpool(x)
        for fi, _ in enumerate(group):
            x = _fire(params[f"fire{gi}_{fi}"], x)
    x = conv(params["classifier"]["w"], x) + params["classifier"]["b"].astype(x.dtype)
    logits = jax.nn.relu(x).mean(axis=(1, 2))
    return logits.astype(jnp.float32), {}
