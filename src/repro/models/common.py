"""Model substrate foundations: abstract parameter specs, logical sharding
axes, and materialization — the contract every model family implements.

A model family provides:
  abstract_params(cfg) -> dict[str, ParamSpec]      (nested dicts allowed)
  apply(cfg, params, *inputs) -> outputs            (pure function)

ParamSpec carries the *logical* axis names of each dimension; the sharding
rules (sharding/rules.py) map logical names -> mesh axes, skipping any axis
whose size does not divide the mesh extent and never assigning the same mesh
axis twice within one spec.  That one guard is what lets a single rule table
cover 11 architectures x 3 shapes x 2 meshes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | conv | scaled
    scale: float | None = None  # override fan-in scaling

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape: Iterable[int], axes: Iterable[str | None], **kw) -> ParamSpec:
    return ParamSpec(tuple(int(s) for s in shape), tuple(axes), **kw)


def _fan_in(shape: tuple[int, ...], init: str) -> float:
    if len(shape) == 1:
        return 1.0
    if init == "conv":  # HWIO
        rf = math.prod(shape[:-2]) if len(shape) > 2 else 1
        return float(rf * shape[-2])
    if init == "embed":
        return 1.0
    return float(shape[-2]) if len(shape) >= 2 else float(shape[0])


def init_param(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    scale = s.scale if s.scale is not None else 1.0 / math.sqrt(max(_fan_in(s.shape, s.init), 1.0))
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)


def init_tree(key: jax.Array, specs: Pytree) -> Pytree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [init_param(k, s) for k, s in zip(keys, leaves)])


def abstract_tree(specs: Pytree) -> Pytree:
    """ShapeDtypeStructs for .lower() without allocating anything."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_count(specs: Pytree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs: Pytree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) * np.dtype(s.dtype).itemsize for s in leaves)


# ---------------------------------------------------------------------------
# dtype policy (mixed precision)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    output_dtype: Any = jnp.float32

    def cast(self, tree: Pytree) -> Pytree:
        c = self.compute_dtype
        return jax.tree.map(lambda x: x.astype(c) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


TRAIN_POLICY = Policy()
SERVE_POLICY = Policy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16, output_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Activation sharding helper — models call shard(x, "batch", "seq", "embed")
# and the active MeshRules (set by launch/train/serve) resolves it.  Outside
# a mesh context it is the identity, so smoke tests never see 512 devices.
# ---------------------------------------------------------------------------

_ACTIVE_RULES: list[Any] = []


class activation_rules:
    """Context manager installing a MeshRules for shard() calls."""

    def __init__(self, rules: Any):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    if not _ACTIVE_RULES:
        return x
    rules = _ACTIVE_RULES[-1]
    return rules.constrain(x, axes)


def current_rules():
    return _ACTIVE_RULES[-1] if _ACTIVE_RULES else None


# ---------------------------------------------------------------------------
# Matmul backend hook — the "NPU execution" seam.  Model families lower their
# GEMMs (classifier heads, and convolutions via im2col) through matmul(); an
# installed backend replaces the plain jnp contraction — quant/npu_exec uses
# this to route every matmul of the int8 variant through the Pallas
# kernels/npu_matmul kernel (interpret mode on CPU, Mosaic on TPU).  Outside
# a backend context matmul() is exactly ``x @ w``, so training and the fp32
# "edge" path are untouched.
# ---------------------------------------------------------------------------

_ACTIVE_MATMUL: list[Any] = []


class matmul_backend:
    """Context manager installing fn(x2d [M, K], w2d [K, N]) -> [M, N] for
    every matmul() call (active at trace time, so it composes with jit)."""

    def __init__(self, fn: Any):
        self.fn = fn

    def __enter__(self):
        _ACTIVE_MATMUL.append(self.fn)
        return self.fn

    def __exit__(self, *exc):
        _ACTIVE_MATMUL.pop()


def current_matmul():
    return _ACTIVE_MATMUL[-1] if _ACTIVE_MATMUL else None


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """[..., K] x [K, N] through the active backend (plain ``@`` if none)."""
    if not _ACTIVE_MATMUL:
        return x @ w
    fn = _ACTIVE_MATMUL[-1]
    lead = x.shape[:-1]
    out = fn(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)
