"""Shared neural layers: norms, RoPE, GQA attention (train/prefill/decode),
SwiGLU MLP, and capacity-dispatched MoE (shared + routed experts, EP-ready).

Everything is a pure function over (cfg-like args, params dict, inputs); the
param layout for each layer is defined by the matching *_specs() helper so
abstract_params stays in lock-step with apply.
"""
from __future__ import annotations

import dataclasses
from functools import partial


import jax
import jax.numpy as jnp

from .common import shard, spec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(dim: int, axis: str = "embed") -> dict:
    return {"scale": spec((dim,), (axis,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_specs(dim: int, axis: str = "embed") -> dict:
    return {"scale": spec((dim,), (axis,), init="ones"), "bias": spec((dim,), (axis,), init="zeros")}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


def modulate(x, shift, scale):
    """adaLN modulation (DiT)."""
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 1e6) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e6) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm; supports full/causal + KV cache decode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    causal: bool = True
    rope: bool = True
    rope_theta: float = 1e6
    bias: bool = False


def attention_specs(c: AttnCfg) -> dict:
    d, H, KH, hd = c.d_model, c.n_heads, c.n_kv_heads, c.head_dim
    s = {
        "wq": spec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": spec((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": spec((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if c.bias:
        s["bq"] = spec((H, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = spec((KH, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = spec((KH, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bo"] = spec((d,), ("embed",), init="zeros")
    if c.qk_norm:
        s["q_norm"] = rmsnorm_specs(c.head_dim, axis="head_dim")
        s["k_norm"] = rmsnorm_specs(c.head_dim, axis="head_dim")
    return s


def _qkv(c: AttnCfg, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if c.bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if c.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if c.rope:
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
    return q, k, v


def _sdpa(c: AttnCfg, q, k, v, mask=None):
    """q: [B,S,H,hd]; k/v: [B,T,KH,hd] — GQA via head grouping."""
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    q = q.reshape(B, S, KH, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) / jnp.sqrt(hd).astype(
        jnp.float32
    )
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


def blockwise_sdpa(q, k, v, *, causal: bool, q_block: int = 512, kv_block: int = 1024):
    """Memory-safe attention: online-softmax over KV blocks inside a map over
    Q blocks — O(S * kv_block) workspace instead of O(S^2).  This is also the
    pure-jnp oracle for the Pallas flash kernel (kernels/flash_attention).

    q: [B,S,H,hd]; k/v: [B,T,KH,hd].
    """
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    pad_q = (-S) % q_block
    pad_k = (-T) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_k
    nq, nk = Sq // q_block, Tk // kv_block

    qp = qp.reshape(B, nq, q_block, KH, G, hd)
    kp = kp.reshape(B, nk, kv_block, KH, hd)
    vp = vp.reshape(B, nk, kv_block, KH, hd)

    def q_block_fn(i):
        qi = qp[:, i]  # [B, qb, KH, G, hd]
        q_pos = i * q_block + jnp.arange(q_block)

        def kv_step(carry, j):
            acc, m, denom = carry
            kj = kp[:, j]
            vj = vp[:, j]
            logits = (
                jnp.einsum("bqkgd,btkd->bkgqt", qi, kj).astype(jnp.float32) * scale
            )  # [B,KH,G,qb,kvb]
            kv_pos = j * kv_block + jnp.arange(kv_block)
            valid = kv_pos[None, :] < T
            if causal:
                valid = valid & (kv_pos[None, :] <= q_pos[:, None])
            logits = jnp.where(valid[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(logits - m_new[..., None])
            denom = denom * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", pexp.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, KH, G, q_block, hd), jnp.float32)
        m0 = jnp.full((B, KH, G, q_block), -1e30, jnp.float32)
        d0 = jnp.zeros((B, KH, G, q_block), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0), jnp.arange(nk))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return out  # [B,KH,G,qb,hd]

    out = jax.lax.map(q_block_fn, jnp.arange(nq))  # [nq,B,KH,G,qb,hd]
    out = jnp.moveaxis(out, 0, 3).reshape(B, KH, G, Sq, hd)[:, :, :, :S]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, KH * G, hd).astype(q.dtype)
    return out


# Above this sequence length, attention() switches to the blockwise path so
# prefill_32k-scale shapes never materialize an S x S score matrix.
BLOCKWISE_THRESHOLD = 4096


# --- flash-kernel accounting -------------------------------------------------
# On TPU the Pallas flash kernel (kernels/flash_attention) keeps all score/
# softmax intermediates in VMEM; their HBM bytes do not exist.  The roofline
# byte model measures that by re-tracing the model with the attention inner
# body replaced by a shape-correct phantom (flops are taken from the REAL
# trace; only bytes come from the phantom trace).  See launch/analysis.
_FLASH_ACCOUNTING: list[bool] = []


class flash_accounting:
    def __enter__(self):
        _FLASH_ACCOUNTING.append(True)
        return self

    def __exit__(self, *exc):
        _FLASH_ACCOUNTING.pop()


def _flash_stub(q, k, v):
    """Phantom attention: correct output shape/dtype + data deps on k/v,
    ~zero intermediate bytes (models the in-VMEM kernel)."""
    dep = (jnp.sum(k[:, :1, :1, :1]) + jnp.sum(v[:, :1, :1, :1])) * 0.0
    return q * (1.0 + dep).astype(q.dtype)


def attention(c: AttnCfg, p, x, *, positions=None, mask=None):
    """Full (training/prefill) attention. x: [B,S,D]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _qkv(c, p, x, positions)
    if _FLASH_ACCOUNTING:
        out = _flash_stub(q, k, v)
    elif S > BLOCKWISE_THRESHOLD and mask is None:
        out = blockwise_sdpa(q, k, v, causal=c.causal)
    else:
        if c.causal and mask is None:
            mask = jnp.tril(jnp.ones((S, S), bool))[None, None, None, :, :]
        out = _sdpa(c, q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if c.bias:
        y = y + p["bo"].astype(x.dtype)
    return y, (k, v)


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 quantization of K/V [..., KH, hd]."""
    t32 = t.astype(jnp.float32)
    amax = jnp.max(jnp.abs(t32), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(t32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(
    c: AttnCfg, p, x, cache_k, cache_v, cache_len, *, kv_seq_axis="kv_seq",
    k_scale=None, v_scale=None,
):
    """One-token decode against a KV cache.

    x: [B,1,D]; cache_k/v: [B,T,KH,hd] (pre-filled up to cache_len);
    cache_len: [] or [B] current length — the new token writes at cache_len.
    With k_scale/v_scale [B,T,KH] the cache is int8 (paper-aligned: the
    low-precision path applied to the decode bandwidth bottleneck); the TPU
    kernel reads int8 + dequantizes in VMEM (modeled by flash accounting).
    Returns (y [B,1,D], new caches [+ new scales when quantized]).
    """
    B, S, _ = x.shape
    assert S == 1
    T = cache_k.shape[1]
    quantized = k_scale is not None
    pos = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1, 1), (B, 1))
    q, k_new, v_new = _qkv(c, p, x, pos)
    # Write the new token into the cache (dynamic index on the seq dim).
    idx = jnp.asarray(cache_len, jnp.int32).reshape(())
    if quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, kq, idx, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, vq, idx, axis=1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, idx, axis=1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, idx, axis=1)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), idx, axis=1
        )
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), idx, axis=1
        )
    cache_k = shard(cache_k, "batch", kv_seq_axis, "kv_heads", "head_dim")
    cache_v = shard(cache_v, "batch", kv_seq_axis, "kv_heads", "head_dim")
    if _FLASH_ACCOUNTING:
        # The kernel reads the cache at its STORED width (int8 when quantized).
        out = _flash_stub(q, cache_k, cache_v)
    else:
        if quantized:
            k_full = dequantize_kv(cache_k, k_scale, q.dtype)
            v_full = dequantize_kv(cache_v, v_scale, q.dtype)
        else:
            k_full, v_full = cache_k.astype(q.dtype), cache_v.astype(q.dtype)
        valid = (jnp.arange(T)[None, :] <= idx)[:, None, None, None, :]  # [B,1,1,1,T]
        out = _sdpa(c, q, k_full, v_full, valid)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if c.bias:
        y = y + p["bo"].astype(x.dtype)
    if quantized:
        return y, cache_k, cache_v, k_scale, v_scale
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_specs(d_model: int, d_ff: int, embed_axis: str = "embed") -> dict:
    return {
        "w_gate": spec((d_model, d_ff), (embed_axis, "mlp")),
        "w_up": spec((d_model, d_ff), (embed_axis, "mlp")),
        "w_down": spec((d_ff, d_model), ("mlp", embed_axis)),
    }


def swiglu(p, x):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def mlp_specs(d_model: int, d_ff: int, out_dim: int | None = None) -> dict:
    out = out_dim or d_model
    return {
        "w1": spec((d_model, d_ff), ("embed", "mlp")),
        "b1": spec((d_ff,), ("mlp",), init="zeros"),
        "w2": spec((d_ff, out), ("mlp", "embed")),
        "b2": spec((out,), ("embed",), init="zeros"),
    }


def mlp(p, x, act=jax.nn.gelu):
    h = act(jnp.einsum("...d,df->...f", x, p["w1"].astype(x.dtype)) + p["b1"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", h, p["w2"].astype(x.dtype)) + p["b2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts — gather-based capacity dispatch (EP over "expert")
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff_expert: int
    n_experts: int  # routed experts (padded to a shardable count by config)
    top_k: int
    n_shared: int = 0
    d_ff_shared: int = 0  # total shared width (already multiplied)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


def moe_specs(c: MoECfg) -> dict:
    s = {
        "router": spec((c.d_model, c.n_experts), ("embed", "expert"), scale=0.02),
        "experts": {
            "w_gate": spec((c.n_experts, c.d_model, c.d_ff_expert), ("expert", "embed", "mlp")),
            "w_up": spec((c.n_experts, c.d_model, c.d_ff_expert), ("expert", "embed", "mlp")),
            "w_down": spec((c.n_experts, c.d_ff_expert, c.d_model), ("expert", "mlp", "embed")),
        },
    }
    if c.n_shared > 0:
        s["shared"] = swiglu_specs(c.d_model, c.d_ff_shared)
    return s


def _dispatch_indices(eid_flat: jax.Array, n_experts: int, capacity: int):
    """Per-row dispatch plan from flat expert assignments.

    eid_flat: [N] int32 expert ids (token-major: token t's k-th choice at
    t*K+k).  Returns (token_idx [E, C], slot_valid [E, C], pos [N], kept [N]):
    slot (e, c) reads flat token token_idx[e, c]; token n lands in slot
    (eid[n], pos[n]) iff kept[n].
    """
    N = eid_flat.shape[0]
    order = jnp.argsort(eid_flat, stable=True)  # [N]
    sorted_eid = eid_flat[order]
    arange = jnp.arange(N, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_eid[1:] != sorted_eid[:-1]])
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, arange, 0))
    pos_sorted = arange - group_start  # position within expert group
    # Inverse permutation: pos for each original flat token.
    inv = jnp.argsort(order, stable=True)
    pos = pos_sorted[inv]
    kept = pos < capacity
    # Slot -> token mapping via group offsets.
    group_offset = jnp.searchsorted(sorted_eid, jnp.arange(n_experts, dtype=eid_flat.dtype))
    counts = (
        jnp.searchsorted(sorted_eid, jnp.arange(n_experts, dtype=eid_flat.dtype), side="right")
        - group_offset
    )
    slot_c = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    gather_pos = jnp.clip(group_offset[:, None] + slot_c, 0, N - 1)
    token_idx = order[gather_pos]  # [E, C]
    slot_valid = slot_c < counts[:, None]
    return token_idx, slot_valid, pos, kept


def moe(c: MoECfg, p, x):
    """x: [B, S, D] -> [B, S, D].  Gather-based capacity dispatch:

      router -> top-k -> per-batch-row sort-derived slot plan -> gather tokens
      into an [E, B, C, D] buffer (E sharded over "model" = EP) -> batched
      expert SwiGLU -> gather back per (token, k) and weighted-sum.

    Two gathers, no scatter: both directions partition well under SPMD.
    Overflow tokens (slot >= capacity) drop, standard capacity semantics.
    """
    B, S, D = x.shape
    K, E = c.top_k, c.n_experts
    N = S * K
    capacity = int(max(1, round(N / E * c.capacity_factor)))

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)  # [B, S, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    eid_flat = top_e.reshape(B, N).astype(jnp.int32)
    token_idx, slot_valid, pos, kept = jax.vmap(
        partial(_dispatch_indices, n_experts=E, capacity=capacity)
    )(eid_flat)
    # token_idx: [B, E, C] flat indices into S*K; map to source token s = i // K.
    src_tok = token_idx // K
    buf = jnp.take_along_axis(
        x[:, :, None, :], src_tok.reshape(B, -1, 1, 1).astype(jnp.int32), axis=1
    ).reshape(B, E, capacity, D)
    buf = jnp.where(slot_valid[..., None], buf, 0.0)
    buf = jnp.swapaxes(buf, 0, 1)  # [E, B, C, D]
    buf = shard(buf, "expert", "batch", None, None)

    w = p["experts"]
    g = jnp.einsum("ebcd,edf->ebcf", buf, w["w_gate"].astype(buf.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", buf, w["w_up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ebcf,efd->ebcd", h, w["w_down"].astype(buf.dtype))
    out_buf = shard(out_buf, "expert", "batch", None, None)

    # Combine by scatter-add into [B, S, D] straight from the E-sharded
    # buffer: each expert shard contributes its slots locally and the
    # partitioner all-reduces the (much smaller) output — the psum
    # formulation.  (Reshaping (E,B,C,D)->(B,E*C,D) and gathering instead
    # makes SPMD materialize the full buffer; §Perf iteration 2.)
    # slot weight: the routing weight of the token occupying slot (b, e, c).
    top_w_flat = top_w.reshape(B, N)  # aligned with eid_flat
    slot_w = jnp.take_along_axis(top_w_flat, token_idx.reshape(B, -1), axis=1).reshape(
        B, E, capacity
    )
    slot_w = jnp.where(slot_valid, slot_w, 0.0)
    upd = jnp.swapaxes(out_buf, 0, 1) * slot_w[..., None].astype(out_buf.dtype)  # [B,E,C,D]

    def combine_one(upd_b, src_b):  # [E,C,D], [E,C] -> [S,D]
        return jnp.zeros((S, D), upd_b.dtype).at[src_b.reshape(-1)].add(
            upd_b.reshape(-1, D), mode="drop"
        )

    y = jax.vmap(combine_one)(upd, src_tok)
    y = shard(y, "batch", None, None)

    if c.n_shared > 0:
        y = y + swiglu(p["shared"], x)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))  # mean router prob per expert
    ce = jnp.zeros((E,), jnp.float32).at[eid_flat.reshape(-1)].add(1.0) / float(B * N)
    aux = c.router_aux_weight * E * jnp.sum(me * jax.lax.stop_gradient(ce))
    return y, aux
