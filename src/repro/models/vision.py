"""Vision transformers: ViT (plain) and Swin (windowed, shifted).

Both are encoder-only classifiers: forward(cfg, params, images) -> logits.
Patch embedding IS part of the model (per the assignment: vision archs embed
their own stem, unlike the LM pool's VLM stubs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .common import shard, spec
from .lm import _stack

# ---------------------------------------------------------------------------
# ViT
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    remat: bool = False

    @property
    def n_patches(self) -> int:
        return (self.img_res // self.patch) ** 2

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_heads,
            head_dim=self.d_model // self.n_heads,
            causal=False,
            rope=False,
            bias=True,
        )


def _vit_block_specs(c: ViTConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(c.d_model),
        "attn": L.attention_specs(c.attn_cfg()),
        "ln2": L.layernorm_specs(c.d_model),
        "mlp": L.mlp_specs(c.d_model, c.d_ff),
    }


def vit_abstract_params(c: ViTConfig) -> dict:
    return {
        "patch_embed": {
            "w": spec((c.patch, c.patch, 3, c.d_model), (None, None, "conv_in", "embed"), init="conv"),
            "b": spec((c.d_model,), ("embed",), init="zeros"),
        },
        "cls": spec((1, 1, c.d_model), (None, None, "embed"), scale=0.02),
        "pos": spec((1, c.n_patches + 1, c.d_model), (None, None, "embed"), scale=0.02),
        "blocks": _stack(_vit_block_specs(c), c.n_layers),
        "ln_f": L.layernorm_specs(c.d_model),
        "head": {
            "w": spec((c.d_model, c.n_classes), ("embed", "vocab")),
            "b": spec((c.n_classes,), ("vocab",), init="zeros"),
        },
    }


def _vit_block(c: ViTConfig, p, x):
    a, _ = L.attention(c.attn_cfg(), p["attn"], L.layernorm(p["ln1"], x))
    x = shard(x + a, "batch", None, None)
    f = L.mlp(p["mlp"], L.layernorm(p["ln2"], x))
    return shard(x + f, "batch", None, None)


def vit_forward(c: ViTConfig, params, images):
    """images: [B, H, W, 3] -> logits [B, n_classes]."""
    B = images.shape[0]
    w = params["patch_embed"]["w"].astype(jnp.bfloat16)
    x = jax.lax.conv_general_dilated(
        images.astype(jnp.bfloat16),
        w,
        window_strides=(c.patch, c.patch),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    x = x.reshape(B, -1, c.d_model) + params["patch_embed"]["b"].astype(jnp.bfloat16)
    cls = jnp.broadcast_to(params["cls"].astype(x.dtype), (B, 1, c.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"].astype(x.dtype)
    x = shard(x, "batch", None, None)

    def body(x, blk):
        fn = partial(_vit_block, c)
        if c.remat:
            fn = jax.checkpoint(fn)
        return fn(blk, x), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.layernorm(params["ln_f"], x)
    h = x[:, 0]
    logits = h @ params["head"]["w"].astype(h.dtype) + params["head"]["b"].astype(h.dtype)
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Swin
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SwinConfig:
    name: str
    img_res: int
    patch: int = 4
    window: int = 7
    depths: tuple[int, ...] = (2, 2, 18, 2)
    dims: tuple[int, ...] = (128, 256, 512, 1024)
    n_heads: tuple[int, ...] = (4, 8, 16, 32)
    mlp_ratio: int = 4
    n_classes: int = 1000
    remat: bool = False


def _swin_attn_cfg(dim: int, heads: int) -> L.AttnCfg:
    return L.AttnCfg(
        d_model=dim,
        n_heads=heads,
        n_kv_heads=heads,
        head_dim=dim // heads,
        causal=False,
        rope=False,
        bias=True,
    )


def _swin_block_specs(c: SwinConfig, dim: int, heads: int) -> dict:
    w = c.window
    return {
        "ln1": L.layernorm_specs(dim),
        "attn": L.attention_specs(_swin_attn_cfg(dim, heads)),
        "rel_bias": spec(((2 * w - 1) * (2 * w - 1), heads), (None, "heads"), scale=0.02),
        "ln2": L.layernorm_specs(dim),
        "mlp": L.mlp_specs(dim, dim * c.mlp_ratio),
    }


def swin_abstract_params(c: SwinConfig) -> dict:
    p: dict = {
        "patch_embed": {
            "w": spec((c.patch, c.patch, 3, c.dims[0]), (None, None, "conv_in", "embed"), init="conv"),
            "b": spec((c.dims[0],), ("embed",), init="zeros"),
            "ln": L.layernorm_specs(c.dims[0]),
        }
    }
    for i, (depth, dim, heads) in enumerate(zip(c.depths, c.dims, c.n_heads)):
        stage: dict = {"blocks": _stack(_swin_block_specs(c, dim, heads), depth)}
        if i < len(c.depths) - 1:
            stage["merge"] = {
                "ln": L.layernorm_specs(4 * dim),
                "w": spec((4 * dim, c.dims[i + 1]), ("embed", "mlp")),
            }
        p[f"stage{i}"] = stage
    p["ln_f"] = L.layernorm_specs(c.dims[-1])
    p["head"] = {
        "w": spec((c.dims[-1], c.n_classes), ("embed", "vocab")),
        "b": spec((c.n_classes,), ("vocab",), init="zeros"),
    }
    return p


def _rel_bias_index(w: int) -> np.ndarray:
    coords = np.stack(np.meshgrid(np.arange(w), np.arange(w), indexing="ij"), 0).reshape(2, -1)
    rel = coords[:, :, None] - coords[:, None, :]
    rel = rel.transpose(1, 2, 0) + (w - 1)
    return (rel[..., 0] * (2 * w - 1) + rel[..., 1]).astype(np.int32)  # [w*w, w*w]


def _window_attention(c: SwinConfig, dim: int, heads: int, p, x, H: int, W: int, shift: int):
    """x: [B, H*W, dim] -> same, windowed MSA with optional cyclic shift."""
    B = x.shape[0]
    w = c.window
    xs = x.reshape(B, H, W, dim)
    if shift:
        xs = jnp.roll(xs, shift=(-shift, -shift), axis=(1, 2))
    nh, nw = H // w, W // w
    xw = xs.reshape(B, nh, w, nw, w, dim).transpose(0, 1, 3, 2, 4, 5).reshape(B * nh * nw, w * w, dim)

    bias = p["rel_bias"][_rel_bias_index(w).reshape(-1)].reshape(w * w, w * w, heads)
    bias = bias.transpose(2, 0, 1)[None, :, None, :, :]  # [1, KH, 1(G), S, T]
    mask = None
    if shift:
        img_mask = np.zeros((1, H, W, 1), np.int32)
        cnt = 0
        for hsl in (slice(0, -w), slice(-w, -shift), slice(-shift, None)):
            for wsl in (slice(0, -w), slice(-w, -shift), slice(-shift, None)):
                img_mask[:, hsl, wsl, :] = cnt
                cnt += 1
        mw = img_mask.reshape(1, nh, w, nw, w, 1).transpose(0, 1, 3, 2, 4, 5).reshape(nh * nw, w * w)
        attn_mask = mw[:, None, :] == mw[:, :, None]  # [nW, S, T]
        mask = jnp.asarray(attn_mask)[:, None, None, :, :]  # [nW,1,1,S,T]
        mask = jnp.tile(mask, (B, 1, 1, 1, 1))

    ac = _swin_attn_cfg(dim, heads)
    q, k, v = L._qkv(ac, p, xw, jnp.zeros(xw.shape[:2], jnp.int32))
    BW, S, H_, hd = q.shape
    qg = q.reshape(BW, S, heads, 1, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / np.sqrt(hd)
    logits = logits + bias.astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", attn, v).reshape(BW, S, heads, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(xw.dtype)) + p["bo"].astype(xw.dtype)

    ys = y.reshape(B, nh, nw, w, w, dim).transpose(0, 1, 3, 2, 4, 5).reshape(B, H, W, dim)
    if shift:
        ys = jnp.roll(ys, shift=(shift, shift), axis=(1, 2))
    return ys.reshape(B, H * W, dim)


def swin_forward(c: SwinConfig, params, images):
    """images: [B, H, W, 3] -> logits [B, n_classes]."""
    B = images.shape[0]
    pe = params["patch_embed"]
    x = jax.lax.conv_general_dilated(
        images.astype(jnp.bfloat16),
        pe["w"].astype(jnp.bfloat16),
        window_strides=(c.patch, c.patch),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    H = W = c.img_res // c.patch
    x = x.reshape(B, H * W, c.dims[0]) + pe["b"].astype(jnp.bfloat16)
    x = L.layernorm(pe["ln"], x)

    for i, (depth, dim, heads) in enumerate(zip(c.depths, c.dims, c.n_heads)):
        stage = params[f"stage{i}"]

        def body(carry, sblk, dim=dim, heads=heads, H=H, W=W):
            x, idx = carry

            def blk_fn(p, x, shift):
                a = _window_attention(c, dim, heads, p["attn"] | {"rel_bias": p["rel_bias"]},
                                      L.layernorm(p["ln1"], x), H, W, shift)
                x = shard(x + a, "batch", None, None)
                f = L.mlp(p["mlp"], L.layernorm(p["ln2"], x))
                return shard(x + f, "batch", None, None)

            # Canonical Swin: no shift when one window covers the feature map.
            shift_amt = c.window // 2 if H > c.window else 0
            if shift_amt:
                x = jax.lax.cond(
                    idx % 2 == 1,
                    lambda x: blk_fn(sblk, x, shift_amt),
                    lambda x: blk_fn(sblk, x, 0),
                    x,
                )
            else:
                x = blk_fn(sblk, x, 0)
            return (x, idx + 1), None

        (x, _), _ = jax.lax.scan(body, (x, jnp.asarray(0)), stage["blocks"])

        if i < len(c.depths) - 1:
            # Patch merging: 2x2 neighborhood concat + linear down-projection.
            xs = x.reshape(B, H, W, dim)
            xs = xs.reshape(B, H // 2, 2, W // 2, 2, dim).transpose(0, 1, 3, 2, 4, 5)
            xs = xs.reshape(B, (H // 2) * (W // 2), 4 * dim)
            xs = L.layernorm(stage["merge"]["ln"], xs)
            x = jnp.einsum("bsd,dk->bsk", xs, stage["merge"]["w"].astype(xs.dtype))
            H, W = H // 2, W // 2

    x = L.layernorm(params["ln_f"], x)
    h = x.mean(axis=1)
    logits = h @ params["head"]["w"].astype(h.dtype) + params["head"]["b"].astype(h.dtype)
    return logits.astype(jnp.float32)
