"""Decoder-only LM family (dense + MoE): qwen3, command-r, qwen2-moe,
deepseek-moe.  Layers are scanned (stacked params, one compiled block) so
512-device SPMD compiles stay fast; remat is a config flag.

Entry points (all pure):
  abstract_params(cfg)                      parameter ParamSpec tree
  train_loss(cfg, params, tokens, labels)   next-token CE (+ MoE aux)
  prefill(cfg, params, tokens)              logits[:, -1] + stacked KV cache
  decode_step(cfg, params, token, cache)    one-token decode
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .common import ParamSpec, shard, spec


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    moe: L.MoECfg | None = None
    remat: bool = True
    # Shard the sequence dim of residual activations over "model" between
    # blocks (Megatron-SP style) — set per-shape by the launcher.
    seq_shard_acts: bool = False
    # KV cache sequence-dim logical axis ("kv_seq" or "long_kv_seq").
    kv_seq_axis: str = "kv_seq"
    # int8 KV cache (per-token/head scales): halves the decode memory term.
    kv_quant: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.hd,
            qk_norm=self.qk_norm,
            causal=True,
            rope=True,
            rope_theta=self.rope_theta,
        )


def _stack(specs: Any, n: int) -> Any:
    """Add a leading scanned 'layers' dim to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.axes), s.dtype, s.init, s.scale),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _block_specs(c: LMConfig) -> dict:
    s = {
        "ln1": L.rmsnorm_specs(c.d_model),
        "attn": L.attention_specs(c.attn_cfg()),
        "ln2": L.rmsnorm_specs(c.d_model),
    }
    if c.moe is not None:
        s["moe"] = L.moe_specs(c.moe)
    else:
        s["ffn"] = L.swiglu_specs(c.d_model, c.d_ff)
    return s


def abstract_params(c: LMConfig) -> dict:
    return {
        "embed": spec((c.vocab, c.d_model), (None, "embed_tp"), init="embed", scale=0.02),
        "blocks": _stack(_block_specs(c), c.n_layers),
        "ln_f": L.rmsnorm_specs(c.d_model),
        "head": spec((c.d_model, c.vocab), ("embed", "vocab"), scale=0.02),
    }


def _res_shard(c: LMConfig, x):
    return shard(x, "batch", "act_seq" if c.seq_shard_acts else "seq", None)


def _unshard_seq(c: LMConfig, h):
    """Megatron-SP gather point: with seq-sharded residuals, materialize the
    full sequence ONCE per sublayer (one bf16 all-gather) instead of letting
    the partitioner gather each of K/V/dispatch separately.

    Only a win when gathering x is cheaper than gathering K+V, i.e. when
    2 * n_kv * head_dim >= d_model.  For strongly-grouped GQA (command-r:
    KV dims = d_model/8) the partitioner's K/V gathers move 8x fewer bytes
    than an x gather would — leave those alone (§Perf iteration 3)."""
    if c.seq_shard_acts and 2 * c.n_kv_heads * c.hd >= c.d_model:
        return shard(h, "batch", None, None)
    return h


def _block_train(c: LMConfig, p, x):
    h = _unshard_seq(c, L.rmsnorm(p["ln1"], x, c.norm_eps))
    a, _kv = L.attention(c.attn_cfg(), p["attn"], h)
    x = _res_shard(c, x + a)
    h = _unshard_seq(c, L.rmsnorm(p["ln2"], x, c.norm_eps))
    if c.moe is not None:
        f, aux = L.moe(c.moe, p["moe"], h)
    else:
        f, aux = L.swiglu(p["ffn"], h), 0.0
    return _res_shard(c, x + f), jnp.asarray(aux, jnp.float32)


def forward(c: LMConfig, params, tokens):
    """tokens [B,S] -> (hidden [B,S,D], aux loss)."""
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    x = _res_shard(c, x)

    def body(carry, blk):
        x = carry
        fn = partial(_block_train, c)
        if c.remat:
            fn = jax.checkpoint(fn)
        x, aux = fn(blk, x)
        return x, aux

    x, auxes = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["ln_f"], x, c.norm_eps)
    return x, jnp.sum(auxes)


def logits_fn(c: LMConfig, params, hidden):
    out = jnp.einsum("bsd,dv->bsv", hidden, params["head"].astype(hidden.dtype))
    return shard(out, "batch", None, "vocab")


def train_loss(c: LMConfig, params, tokens, labels):
    """Mean next-token cross-entropy; labels = tokens shifted by the pipeline.
    Label id < 0 masks the position out."""
    hidden, aux = forward(c, params, tokens)
    logits = logits_fn(c, params, hidden).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked KV cache
# ---------------------------------------------------------------------------


def make_cache(c: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    shape = (c.n_layers, batch, max_len, c.n_kv_heads, c.hd)
    if c.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.ones(shape[:-1], jnp.float32),
            "v_scale": jnp.ones(shape[:-1], jnp.float32),
            "len": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(c: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    shape = (c.n_layers, batch, max_len, c.n_kv_heads, c.hd)
    axes = ("layers", "batch", c.kv_seq_axis, "kv_heads", "head_dim")
    if c.kv_quant:
        return {
            "k": spec(shape, axes, dtype=jnp.int8, init="zeros"),
            "v": spec(shape, axes, dtype=jnp.int8, init="zeros"),
            "k_scale": spec(shape[:-1], axes[:-1], dtype=jnp.float32, init="ones"),
            "v_scale": spec(shape[:-1], axes[:-1], dtype=jnp.float32, init="ones"),
            "len": spec((), (), dtype=jnp.int32, init="zeros"),
        }
    return {
        "k": spec(shape, axes, dtype=dtype, init="zeros"),
        "v": spec(shape, axes, dtype=dtype, init="zeros"),
        "len": spec((), (), dtype=jnp.int32, init="zeros"),
    }


def prefill(c: LMConfig, params, tokens, max_len: int | None = None):
    """Full forward over the prompt; returns (last-token logits, cache)."""
    B, S = tokens.shape
    max_len = max_len or S
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    x = _res_shard(c, x)

    def body(x, blk):
        h = _unshard_seq(c, L.rmsnorm(blk["ln1"], x, c.norm_eps))
        a, (k, v) = L.attention(c.attn_cfg(), blk["attn"], h)
        x = _res_shard(c, x + a)
        h = _unshard_seq(c, L.rmsnorm(blk["ln2"], x, c.norm_eps))
        if c.moe is not None:
            f, _ = L.moe(c.moe, blk["moe"], h)
        else:
            f = L.swiglu(blk["ffn"], h)
        x = _res_shard(c, x + f)
        return x, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm(params["ln_f"], x, c.norm_eps)
    logits = logits_fn(c, params, x[:, -1:, :])
    pad = max_len - S
    if pad > 0:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    ks = shard(ks, "layers", "batch", c.kv_seq_axis, "kv_heads", "head_dim")
    vs = shard(vs, "layers", "batch", c.kv_seq_axis, "kv_heads", "head_dim")
    if c.kv_quant:
        kq, ksc = L.quantize_kv(ks)
        vq, vsc = L.quantize_kv(vs)
        cache = {
            "k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc,
            "len": jnp.asarray(S, jnp.int32),
        }
    else:
        cache = {"k": ks, "v": vs, "len": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(c: LMConfig, params, token, cache):
    """token [B,1] int32; cache from make_cache/prefill.  Returns
    (logits [B,1,V], new cache)."""
    x = params["embed"].astype(jnp.bfloat16)[token]
    x = shard(x, "batch", None, None)
    quant = c.kv_quant

    def body(x, blk_and_cache):
        if quant:
            blk, ck, cv, ks, vs = blk_and_cache
            h = L.rmsnorm(blk["ln1"], x, c.norm_eps)
            a, nk, nv, nks_, nvs_ = L.attention_decode(
                c.attn_cfg(), blk["attn"], h, ck, cv, cache["len"],
                kv_seq_axis=c.kv_seq_axis, k_scale=ks, v_scale=vs,
            )
            extra = (nk, nv, nks_, nvs_)
        else:
            blk, ck, cv = blk_and_cache
            h = L.rmsnorm(blk["ln1"], x, c.norm_eps)
            a, nk, nv = L.attention_decode(
                c.attn_cfg(), blk["attn"], h, ck, cv, cache["len"], kv_seq_axis=c.kv_seq_axis
            )
            extra = (nk, nv)
        x = x + a
        h = L.rmsnorm(blk["ln2"], x, c.norm_eps)
        if c.moe is not None:
            f, _ = L.moe(c.moe, blk["moe"], h)
        else:
            f = L.swiglu(blk["ffn"], h)
        return x + f, extra

    if quant:
        xs = (params["blocks"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
        x, (nks, nvs, nkss, nvss) = jax.lax.scan(body, x, xs)
        new_cache = {
            "k": nks, "v": nvs, "k_scale": nkss, "v_scale": nvss, "len": cache["len"] + 1
        }
    else:
        x, (nks, nvs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": nks, "v": nvs, "len": cache["len"] + 1}
    x = L.rmsnorm(params["ln_f"], x, c.norm_eps)
    logits = logits_fn(c, params, x)
    return logits, new_cache
