"""Diffusion backbones: DiT (adaLN-Zero) and Flux-style MMDiT (double-stream
joint attention + single-stream blocks, rectified flow).

Both operate on VAE latents (stub frontend: input_specs provides latents
directly; the VAE is out of scope, as the assignment's modality-stub rule
dictates).  One call = ONE denoising step; samplers loop around it.

  dit_forward(cfg, params, x_t, t, y)            -> prediction (noise, 2C ch)
  flux_forward(cfg, params, img, txt, vec, t, g) -> velocity prediction
  *_train_loss                                    DDPM eps-MSE / RF v-MSE
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .common import shard, spec
from .lm import _stack


def timestep_embedding(t: jax.Array, dim: int, max_period: float = 10000.0) -> jax.Array:
    """t: [B] float in [0, 1] or integer steps -> [B, dim] sinusoidal."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def sincos_2d(d: int, h: int, w: int) -> np.ndarray:
    """Fixed 2D sin-cos positional embedding [h*w, d] (DiT uses this)."""

    def one(dim, pos):
        omega = 1.0 / 10000 ** (np.arange(dim // 2) / (dim // 2))
        out = pos[:, None] * omega[None, :]
        return np.concatenate([np.sin(out), np.cos(out)], axis=1)

    gh, gw = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    return np.concatenate([one(d // 2, gh.reshape(-1)), one(d // 2, gw.reshape(-1))], axis=1).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# DiT
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int = 256  # pixel space; latent = img_res // 8
    patch: int = 2
    n_layers: int = 28
    d_model: int = 1152
    n_heads: int = 16
    in_ch: int = 4
    n_classes: int = 1000
    mlp_ratio: int = 4
    remat: bool = False

    @property
    def latent(self) -> int:
        return self.img_res // 8

    @property
    def tokens(self) -> int:
        return (self.latent // self.patch) ** 2

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_heads,
            head_dim=self.d_model // self.n_heads,
            causal=False,
            rope=False,
            bias=True,
        )


def _dit_block_specs(c: DiTConfig) -> dict:
    d = c.d_model
    return {
        "ln1": L.layernorm_specs(d),
        "attn": L.attention_specs(c.attn_cfg()),
        "ln2": L.layernorm_specs(d),
        "mlp": L.mlp_specs(d, d * c.mlp_ratio),
        "adaln": {
            "w": spec((d, 6 * d), ("embed", "mlp"), init="zeros"),
            "b": spec((6 * d,), ("mlp",), init="zeros"),
        },
    }


def dit_abstract_params(c: DiTConfig) -> dict:
    d = c.d_model
    pdim = c.patch * c.patch * c.in_ch
    return {
        "x_embed": {"w": spec((pdim, d), (None, "embed")), "b": spec((d,), ("embed",), init="zeros")},
        "t_embed": L.mlp_specs(256, d, out_dim=d),
        "y_embed": spec((c.n_classes + 1, d), (None, "embed"), init="embed", scale=0.02),
        "blocks": _stack(_dit_block_specs(c), c.n_layers),
        "final": {
            "ln": L.layernorm_specs(d),
            "adaln": {
                "w": spec((d, 2 * d), ("embed", "mlp"), init="zeros"),
                "b": spec((2 * d,), ("mlp",), init="zeros"),
            },
            "proj": {
                "w": spec((d, c.patch * c.patch * 2 * c.in_ch), ("embed", None), init="zeros"),
                "b": spec((c.patch * c.patch * 2 * c.in_ch,), (None,), init="zeros"),
            },
        },
    }


def _patchify(x, p):
    B, H, W, C = x.shape
    x = x.reshape(B, H // p, p, W // p, p, C).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, (H // p) * (W // p), p * p * C)


def _unpatchify(x, p, h, w, c_out):
    B = x.shape[0]
    x = x.reshape(B, h, w, p, p, c_out).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, h * p, w * p, c_out)


def _dit_block(c: DiTConfig, p, x, cond):
    mod = cond @ p["adaln"]["w"].astype(cond.dtype) + p["adaln"]["b"].astype(cond.dtype)
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    h = L.modulate(L.layernorm(p["ln1"], x), sh1, sc1)
    a, _ = L.attention(c.attn_cfg(), p["attn"], h)
    x = shard(x + g1[:, None, :] * a, "batch", None, None)
    h = L.modulate(L.layernorm(p["ln2"], x), sh2, sc2)
    f = L.mlp(p["mlp"], h)
    return shard(x + g2[:, None, :] * f, "batch", None, None)


def dit_forward(c: DiTConfig, params, x_t, t, y):
    """x_t: [B, L, L, C] latent; t: [B]; y: [B] int labels.
    Returns [B, L, L, 2C] (noise prediction + sigma channels)."""
    B, H, W, _ = x_t.shape
    p = c.patch
    x = _patchify(x_t.astype(jnp.bfloat16), p)
    x = x @ params["x_embed"]["w"].astype(x.dtype) + params["x_embed"]["b"].astype(x.dtype)
    pos = jnp.asarray(sincos_2d(c.d_model, H // p, W // p))[None]
    x = x + pos.astype(x.dtype)
    x = shard(x, "batch", None, None)

    temb = L.mlp(params["t_embed"], timestep_embedding(t, 256).astype(jnp.bfloat16), act=jax.nn.silu)
    yemb = params["y_embed"].astype(jnp.bfloat16)[y]
    cond = jax.nn.silu(temb + yemb)

    def body(x, blk):
        fn = _dit_block
        if c.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        return fn(c, blk, x, cond), None

    x, _ = jax.lax.scan(body, x, params["blocks"])

    fin = params["final"]
    mod = cond @ fin["adaln"]["w"].astype(cond.dtype) + fin["adaln"]["b"].astype(cond.dtype)
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = L.modulate(L.layernorm(fin["ln"], x), sh, sc)
    x = x @ fin["proj"]["w"].astype(x.dtype) + fin["proj"]["b"].astype(x.dtype)
    return _unpatchify(x.astype(jnp.float32), p, H // p, W // p, 2 * c.in_ch)


def dit_train_loss(c: DiTConfig, params, x0, t, y, noise):
    """DDPM eps-prediction MSE at cosine-schedule timestep t in [0,1]."""
    a = jnp.cos(0.5 * jnp.pi * t).astype(jnp.float32)[:, None, None, None]
    s = jnp.sin(0.5 * jnp.pi * t).astype(jnp.float32)[:, None, None, None]
    x_t = a * x0 + s * noise
    pred = dit_forward(c, params, x_t, t * 1000.0, y)
    eps = pred[..., : c.in_ch]
    return jnp.mean((eps - noise) ** 2), {}


def dit_sample_step(c: DiTConfig, params, x_t, t, dt, y):
    """One DDIM-style step from t to t - dt (cosine schedule)."""
    pred = dit_forward(c, params, x_t, t * 1000.0, y)
    eps = pred[..., : c.in_ch].astype(jnp.float32)
    a_t = jnp.cos(0.5 * jnp.pi * t)[:, None, None, None]
    s_t = jnp.sin(0.5 * jnp.pi * t)[:, None, None, None]
    x0 = (x_t - s_t * eps) / jnp.maximum(a_t, 1e-4)
    t2 = jnp.maximum(t - dt, 0.0)
    a2 = jnp.cos(0.5 * jnp.pi * t2)[:, None, None, None]
    s2 = jnp.sin(0.5 * jnp.pi * t2)[:, None, None, None]
    return a2 * x0 + s2 * eps


# ---------------------------------------------------------------------------
# Flux-style MMDiT
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FluxConfig:
    name: str
    img_res: int = 1024
    latent_res: int = 128
    patch: int = 2
    n_double: int = 19
    n_single: int = 38
    d_model: int = 3072
    n_heads: int = 24
    in_ch: int = 16
    txt_len: int = 256
    txt_dim: int = 4096
    vec_dim: int = 768
    mlp_ratio: int = 4
    guidance: bool = True
    remat: bool = True

    @property
    def tokens(self) -> int:
        return (self.latent_res // self.patch) ** 2

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_heads,
            head_dim=self.d_model // self.n_heads,
            causal=False,
            rope=False,
            bias=True,
            qk_norm=True,
        )


def _mod_specs(d: int, n: int) -> dict:
    return {"w": spec((d, n * d), ("embed", "mlp"), init="zeros"), "b": spec((n * d,), ("mlp",), init="zeros")}


def _double_block_specs(c: FluxConfig) -> dict:
    d = c.d_model
    stream = lambda: {
        "mod": _mod_specs(d, 6),
        "ln1": L.layernorm_specs(d),
        "attn": L.attention_specs(c.attn_cfg()),
        "ln2": L.layernorm_specs(d),
        "mlp": L.mlp_specs(d, d * c.mlp_ratio),
    }
    return {"img": stream(), "txt": stream()}


def _single_block_specs(c: FluxConfig) -> dict:
    d = c.d_model
    h = d * c.mlp_ratio
    return {
        "mod": _mod_specs(d, 3),
        "ln": L.layernorm_specs(d),
        "attn": L.attention_specs(c.attn_cfg()),
        "mlp_in": spec((d, h), ("embed", "mlp")),
        "mlp_out": spec((h, d), ("mlp", "embed")),
    }


def flux_abstract_params(c: FluxConfig) -> dict:
    d = c.d_model
    pdim = c.patch * c.patch * c.in_ch
    return {
        "img_in": {"w": spec((pdim, d), (None, "embed")), "b": spec((d,), ("embed",), init="zeros")},
        "txt_in": {"w": spec((c.txt_dim, d), (None, "embed")), "b": spec((d,), ("embed",), init="zeros")},
        "vec_in": L.mlp_specs(c.vec_dim, d, out_dim=d),
        "t_embed": L.mlp_specs(256, d, out_dim=d),
        "g_embed": L.mlp_specs(256, d, out_dim=d),
        "double": _stack(_double_block_specs(c), c.n_double),
        "single": _stack(_single_block_specs(c), c.n_single),
        "final": {
            "ln": L.layernorm_specs(d),
            "adaln": _mod_specs(d, 2),
            "proj": {
                "w": spec((d, pdim), ("embed", None), init="zeros"),
                "b": spec((pdim,), (None,), init="zeros"),
            },
        },
    }


def _mod(p, vec, n):
    m = vec @ p["w"].astype(vec.dtype) + p["b"].astype(vec.dtype)
    return jnp.split(m, n, axis=-1)


def _pin_replicated(*ts):
    """Stop the partitioner from back-propagating the residual's seq-sharding
    into attention internals (it would re-gather K/V per block otherwise)."""
    return tuple(shard(t, "batch", None, None, None) for t in ts)


def _joint_attention(c: FluxConfig, p_img, p_txt, img, txt):
    """Compute q/k/v per stream, attend jointly over [txt; img]."""
    ac = c.attn_cfg()
    zero = lambda x: jnp.zeros(x.shape[:2], jnp.int32)
    qi, ki, vi = _pin_replicated(*L._qkv(ac, p_img, img, zero(img)))
    qt, kt, vt = _pin_replicated(*L._qkv(ac, p_txt, txt, zero(txt)))
    q = jnp.concatenate([qt, qi], axis=1)
    k = jnp.concatenate([kt, ki], axis=1)
    v = jnp.concatenate([vt, vi], axis=1)
    q, k, v = _pin_replicated(q, k, v)
    S = q.shape[1]
    if L._FLASH_ACCOUNTING:
        out = L._flash_stub(q, k, v)
    elif S > L.BLOCKWISE_THRESHOLD:
        out = L.blockwise_sdpa(q, k, v, causal=False)
    else:
        out = L._sdpa(ac, q, k, v, None)
    ot, oi = out[:, : txt.shape[1]], out[:, txt.shape[1] :]
    yi = jnp.einsum("bshk,hkd->bsd", oi, p_img["wo"].astype(img.dtype)) + p_img["bo"].astype(img.dtype)
    yt = jnp.einsum("bshk,hkd->bsd", ot, p_txt["wo"].astype(txt.dtype)) + p_txt["bo"].astype(txt.dtype)
    return yi, yt


def _double_block(c: FluxConfig, p, img, txt, vec):
    mi = _mod(p["img"]["mod"], vec, 6)
    mt = _mod(p["txt"]["mod"], vec, 6)
    # Gather the seq-sharded residual ONCE per sublayer (bf16) — the SPMD
    # partitioner otherwise all-gathers q/k/v separately (§Perf iteration).
    hi = shard(L.modulate(L.layernorm(p["img"]["ln1"], img), mi[0], mi[1]), "batch", None, None)
    ht = L.modulate(L.layernorm(p["txt"]["ln1"], txt), mt[0], mt[1])
    ai, at = _joint_attention(c, p["img"]["attn"], p["txt"]["attn"], hi, ht)
    img = shard(img + mi[2][:, None] * ai, "batch", "act_seq", None)
    txt = txt + mt[2][:, None] * at
    hi2 = shard(L.modulate(L.layernorm(p["img"]["ln2"], img), mi[3], mi[4]), "batch", None, None)
    fi = L.mlp(p["img"]["mlp"], hi2)
    ft = L.mlp(p["txt"]["mlp"], L.modulate(L.layernorm(p["txt"]["ln2"], txt), mt[3], mt[4]))
    img = shard(img + mi[5][:, None] * fi, "batch", "act_seq", None)
    txt = txt + mt[5][:, None] * ft
    return img, txt


def _single_block(c: FluxConfig, p, x, vec):
    sh, sc, g = _mod(p["mod"], vec, 3)
    h = shard(L.modulate(L.layernorm(p["ln"], x), sh, sc), "batch", None, None)
    ac = c.attn_cfg()
    q, k, v = L._qkv(ac, p["attn"], h, jnp.zeros(h.shape[:2], jnp.int32))
    q, k, v = _pin_replicated(q, k, v)
    if L._FLASH_ACCOUNTING:
        o = L._flash_stub(q, k, v)
    elif q.shape[1] > L.BLOCKWISE_THRESHOLD:
        o = L.blockwise_sdpa(q, k, v, causal=False)
    else:
        o = L._sdpa(ac, q, k, v, None)
    a = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype)) + p["attn"]["bo"].astype(x.dtype)
    f = jax.nn.gelu(h @ p["mlp_in"].astype(h.dtype)) @ p["mlp_out"].astype(h.dtype)
    # attn and MLP share the residual: one fused partial-sum, one reshard.
    return shard(x + g[:, None] * (a + f), "batch", "act_seq", None)


def flux_forward(c: FluxConfig, params, img_lat, txt, vec, t, guidance=None):
    """img_lat: [B, R, R, C]; txt: [B, T, txt_dim]; vec: [B, vec_dim];
    t: [B] in [0,1]; guidance: [B] scale.  Returns velocity [B, R, R, C]."""
    B, H, W, _ = img_lat.shape
    p = c.patch
    img = _patchify(img_lat.astype(jnp.bfloat16), p)
    img = img @ params["img_in"]["w"].astype(img.dtype) + params["img_in"]["b"].astype(img.dtype)
    pos = jnp.asarray(sincos_2d(c.d_model, H // p, W // p))[None]
    img = shard(img + pos.astype(img.dtype), "batch", "act_seq", None)
    txt = txt.astype(jnp.bfloat16) @ params["txt_in"]["w"].astype(jnp.bfloat16) + params["txt_in"][
        "b"
    ].astype(jnp.bfloat16)

    cond = L.mlp(params["t_embed"], timestep_embedding(t * 1000.0, 256).astype(jnp.bfloat16), act=jax.nn.silu)
    cond = cond + L.mlp(params["vec_in"], vec.astype(jnp.bfloat16), act=jax.nn.silu)
    if c.guidance and guidance is not None:
        cond = cond + L.mlp(
            params["g_embed"], timestep_embedding(guidance * 1000.0, 256).astype(jnp.bfloat16), act=jax.nn.silu
        )
    cond = jax.nn.silu(cond)

    def dbody(carry, blk):
        img, txt = carry
        fn = _double_block
        if c.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        img, txt = fn(c, blk, img, txt, cond)
        return (img, txt), None

    (img, txt), _ = jax.lax.scan(dbody, (img, txt), params["double"])

    x = jnp.concatenate([txt, img], axis=1)

    def sbody(x, blk):
        fn = _single_block
        if c.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        return fn(c, blk, x, cond), None

    x, _ = jax.lax.scan(sbody, x, params["single"])
    img = x[:, c.txt_len :]

    fin = params["final"]
    sh, sc = _mod(fin["adaln"], cond, 2)
    img = L.modulate(L.layernorm(fin["ln"], img), sh, sc)
    img = img @ fin["proj"]["w"].astype(img.dtype) + fin["proj"]["b"].astype(img.dtype)
    return _unpatchify(img.astype(jnp.float32), p, H // p, W // p, c.in_ch)


def flux_train_loss(c: FluxConfig, params, x0, txt, vec, t, noise):
    """Rectified-flow v-prediction: x_t = (1-t) x0 + t eps, v* = eps - x0."""
    tt = t.astype(jnp.float32)[:, None, None, None]
    x_t = (1 - tt) * x0 + tt * noise
    g = jnp.full(t.shape, 4.0, jnp.float32) if c.guidance else None
    v = flux_forward(c, params, x_t, txt, vec, t, g)
    return jnp.mean((v - (noise - x0)) ** 2), {}


def flux_sample_step(c: FluxConfig, params, x_t, txt, vec, t, dt, guidance):
    """One rectified-flow Euler step: x_{t-dt} = x_t - dt * v(x_t, t)."""
    v = flux_forward(c, params, x_t, txt, vec, t, guidance)
    return x_t - dt[:, None, None, None] * v
