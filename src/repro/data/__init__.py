from .pipeline import DataSpec, SyntheticStream, make_batch_iterator  # noqa: F401
