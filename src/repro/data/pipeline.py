"""Deterministic synthetic data pipeline with restart skip-ahead.

Batches are a pure function of (seed, step) — counter-mode generation — so:
  * restart at step N reproduces the exact stream without replaying N steps;
  * elastic restarts re-slice the same global batch across a new mesh;
  * prefetch is a bounded background thread (host-side), overlapping batch
    synthesis with device compute.

Real deployments swap SyntheticStream for a storage-backed reader with the
same (seed, step) -> batch contract; everything above the contract (train
driver, checkpoint cadence, FT restart) is unchanged.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from ..arch import Arch, ShapeSpec, input_specs


@dataclasses.dataclass(frozen=True)
class DataSpec:
    arch: Arch
    shape: ShapeSpec
    seed: int = 0


class SyntheticStream:
    """Counter-mode synthetic batches matching input_specs(arch, shape)."""

    def __init__(self, spec: DataSpec):
        self.spec = spec
        self._specs = input_specs(spec.arch, spec.shape)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.uint64(self.spec.seed) + np.uint64(step) * np.uint64(2654435761))
        out: dict[str, np.ndarray] = {}
        arch = self.spec.arch
        for name, s in sorted(self._specs.items()):
            if np.issubdtype(np.dtype(s.dtype), np.integer):
                hi = arch.cfg.vocab if arch.family == "lm" else getattr(arch.cfg, "n_classes", 1000)
                out[name] = rng.integers(0, hi, size=s.shape, dtype=np.int32)
            elif name == "t":
                out[name] = rng.uniform(0.02, 0.98, size=s.shape).astype(np.float32)
            elif name == "dt":
                out[name] = np.full(s.shape, 0.02, np.float32)
            elif name == "guidance":
                out[name] = np.full(s.shape, 4.0, np.float32)
            else:
                out[name] = rng.standard_normal(size=s.shape).astype(np.float32)
        return out


def make_batch_iterator(
    stream: SyntheticStream, *, start_step: int = 0, prefetch: int = 2
) -> Iterator[dict[str, np.ndarray]]:
    """Prefetching iterator starting at ``start_step`` (restart skip-ahead)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker() -> None:
        step = start_step
        while not stop.is_set():
            try:
                q.put(stream.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
