"""NPU execution: run the int8 variant's matmuls through the real kernel.

``quantize.py`` makes the *weights* real int8 (fake-quant round-off); this
module makes the *arithmetic* real: inside an :class:`npu_execution` context
every GEMM a model family lowers through ``models.common.matmul()`` —
classifier heads, and convolutions via im2col (``models/convnets.py``) —
executes as ``kernels/npu_matmul``'s w8a8 Pallas kernel (interpret mode on
CPU, Mosaic on TPU) instead of a float contraction.  Per-row activation and
per-output-channel weight scales match ``quantize._fake_quant``'s scheme, so
quantizing the already fake-quant weights is idempotent: the int8 values the
kernel multiplies are exactly the deployed NPU weights.

``serving/calibrate.py`` builds its measured t_npu/accuracy profiles on top
of this; nothing here is serving-specific.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from ..kernels.npu_matmul import ops as npu_ops
from ..models import common


def npu_dense(x2d: jax.Array, w2d: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """One NPU-path GEMM: quantize both sides to int8 and run the Pallas
    kernel (adaptive block sizes keep small serving shapes un-padded)."""
    return npu_ops.npu_matmul(x2d, w2d, interpret=interpret)


class npu_execution(common.matmul_backend):
    """Context manager: every ``models.common.matmul()`` call (and every conv
    lowered through it) routes through ``kernels/npu_matmul`` while active.
    Active at trace time, so it composes with ``jax.jit``."""

    def __init__(self, *, interpret: bool | None = None):
        super().__init__(lambda x, w: npu_dense(x, w, interpret=interpret))


def npu_forward(forward: Callable[..., Any], *, interpret: bool | None = None) -> Callable[..., Any]:
    """Wrap a classifier forward so its matmuls execute on the NPU path.

    The wrapper installs the backend around every invocation (including the
    jit trace), so ``jax.jit(npu_forward(f))`` compiles the kernel-routed
    graph while ``f`` itself stays the full-precision edge variant.
    """

    def fwd(*args, **kwargs):
        with npu_execution(interpret=interpret):
            return forward(*args, **kwargs)

    return fwd
