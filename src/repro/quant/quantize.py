"""The "NPU variant" factory: int8 fake-quantization of model weights.

FastVA's phone NPU runs CNNs in 8/16-bit and loses accuracy in a
model-dependent way (paper §III.A: VGG barely, ResNet ~20%, YOLO badly).
Here every architecture gets a quantized variant whose error is REAL int8
round-off (symmetric per-output-channel, matching the Pallas kernel's
scheme), so the scheduler's accuracy/latency tradeoff is grounded in actual
arithmetic rather than assumed constants.  On TPU the quantized variant's
matmuls run through kernels/npu_matmul; fake-quant params make CPU tests and
profile calibration backend-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class QuantStats:
    leaves_quantized: int = 0
    leaves_kept: int = 0
    mean_rel_err: float = 0.0
    max_rel_err: float = 0.0


def _fake_quant(w: jax.Array) -> jax.Array:
    """Symmetric per-output-channel (last dim) int8 quantize-dequantize."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w32 / scale), -127, 127)
    return (q * scale).astype(w.dtype)


def fake_quant_tree(params: Any, *, min_ndim: int = 2) -> Any:
    """Quantize every floating leaf with ndim >= min_ndim (weights/embeddings);
    biases and norm scales stay exact, matching real NPU toolchains."""

    def q(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= min_ndim:
            return _fake_quant(x)
        return x

    return jax.tree.map(q, params)


def quant_error_stats(params: Any, qparams: Any) -> QuantStats:
    stats = QuantStats()
    rels = []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(qparams)):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            stats.leaves_kept += 1  # int/bool leaves pass through unquantized
            continue
        if a.shape == b.shape and bool(jnp.any(a != b)):
            denom = float(jnp.linalg.norm(a.astype(jnp.float32))) or 1.0
            rel = float(jnp.linalg.norm((a - b).astype(jnp.float32))) / denom
            rels.append(rel)
            stats.leaves_quantized += 1
        else:
            stats.leaves_kept += 1
    if rels:
        stats.mean_rel_err = sum(rels) / len(rels)
        stats.max_rel_err = max(rels)
    return stats


def npu_variant(params: Any) -> tuple[Any, QuantStats]:
    """The deployable NPU-path weights: int8 fake-quant + stats."""
    q = fake_quant_tree(params)
    return q, quant_error_stats(params, q)


def agreement(
    forward: Callable[[Any, jax.Array], jax.Array],
    params_fp: Any,
    params_q: Any,
    inputs: jax.Array,
) -> float:
    """Top-1 agreement between full-precision and quantized variants — the
    measurable analogue of the paper's NPU accuracy drop (Fig. 1b)."""
    a = jnp.argmax(forward(params_fp, inputs), axis=-1)
    b = jnp.argmax(forward(params_q, inputs), axis=-1)
    return float(jnp.mean((a == b).astype(jnp.float32)))
