from .npu_exec import (  # noqa: F401
    npu_dense,
    npu_execution,
    npu_forward,
)
from .quantize import (  # noqa: F401
    QuantStats,
    agreement,
    fake_quant_tree,
    npu_variant,
    quant_error_stats,
)
