"""Fleet-sweep engine benchmark: reference loop vs batched jit/vmap backend.

Two ladders run the same grids through both ``Session.run_sweep`` backends
at {10, 100, 1000} points and report wall-clock plus the equivalence bit
(integer stats exact; accuracy sums within ``AUDIT_TOL`` — the speedup is
worthless otherwise):

  * the **jax ladder** (``jax_accuracy``/``jax_utility``): network-aware
    (bandwidth × deadline × fps × rtt) grids — the axes parameterize the
    scenario; these local-only policies ignore the network, and their
    per-round reference pays a jitted-kernel dispatch, which is what the
    vectorized engine amortizes.  **Acceptance bar: >= 10x warm at the
    1000-point grid** (tracked since PR 3, now on a network-aware grid).
  * the **network ladder** (``max_accuracy``/``max_utility``): the paper's
    offload-capable planners on network-aware grids — piecewise traces with
    an rtt axis at 10/100 points, a low-bandwidth (bandwidth × deadline ×
    fps × rtt) grid at 1000.  Their *reference* is plain numpy/Python (no
    per-round jit dispatch), so on a small-CPU host the batched engine
    roughly breaks even — the recorded ``speedup_warm`` is the honest
    number, gated on equivalence only (the row exists to track the perf
    trajectory on parallel hardware, where the lanes are free).

Every cell also reports **compile counts** (via jax monitoring — real XLA
builds vs persistent-cache loads) and **peak host RSS** (a sampler thread
over ``/proc/self/statm``), so the caching and streaming wins are measured,
not inferred.

The **scale cell** is the headline: a >= 100k-point network-aware
``max_utility`` grid streamed through ``run_sweep(chunk_size=...,
keep_points=False)`` with the persistent compilation cache enabled — run
cold (compiles), warm (all caches hot), then again "cold" after dropping
every in-process executable (fresh-process simulation: compiled programs
reload from the disk cache).  The acceptance gate is that this cache-warm
cold path lands within 2x of the warm run, i.e. compilation is amortized
away.  A 100-point corner of the same grid is spot-checked exactly against
the reference loop (full-grid equivalence is impossible at 10^5 but chunk
invariance is golden-tested in tests/test_sweep_scale.py).

Results land in ``BENCH_sweep.json`` so CI can track the trajectory:

    PYTHONPATH=src python benchmarks/sweep_bench.py            # full ladders + 100k scale cell
    PYTHONPATH=src python benchmarks/sweep_bench.py --smoke    # 10-point grids + 10k scale cell
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import PolicySpec  # noqa: E402
from repro.core import sim_batch, sim_multi_batch, sweep_shard  # noqa: E402
from repro.core.audit import AUDIT_TOL  # noqa: E402
from repro.core.compile_cache import CompileCounter  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid, TraceSpec  # noqa: E402

N_FRAMES = 120
POLICIES = (("jax_accuracy", {}), ("jax_utility", {"alpha": 200.0}))
NET_POLICIES = (("max_accuracy", {}), ("max_utility", {"alpha": 200.0}))
SIZES = (10, 100, 1000)
DEFAULT_OUT = "BENCH_sweep.json"
DEFAULT_CACHE_DIR = ".jax_cache/sweep_bench"

# The scale cell: the paper's offload-capable utility planner on a short
# clip, streamed.  2.0 ms/point warm on a 1-core host — 100k points is a
# ~3.5 min warm pass, and nothing but one 2500-point chunk plus the running
# summary ever lives on the host.
SCALE_POLICY = ("max_utility", {"alpha": 200.0})
SCALE_N_FRAMES = 24
SCALE_CHUNK = 2500

_PAGE_BYTES = os.sysconf("SC_PAGE_SIZE")


def _rss_bytes() -> int:
    try:
        with open("/proc/self/statm") as fh:
            return int(fh.read().split()[1]) * _PAGE_BYTES
    except (OSError, IndexError, ValueError):  # non-procfs host
        return 0


class _RssSampler:
    """Peak host RSS over a measured region, polled from /proc/self/statm.

    A daemon thread samples at ~20 Hz — cheap enough to leave running for a
    multi-minute sweep, and it catches transient peaks (a chunk's worth of
    lane arrays materializing) that an end-of-run snapshot would miss.
    """

    def __init__(self, interval_s: float = 0.05):
        self.interval_s = interval_s
        self.peak_bytes = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._poll, daemon=True)

    def _poll(self):
        while not self._stop.is_set():
            self.peak_bytes = max(self.peak_bytes, _rss_bytes())
            self._stop.wait(self.interval_s)

    def __enter__(self):
        self.peak_bytes = _rss_bytes()
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join()
        self.peak_bytes = max(self.peak_bytes, _rss_bytes())
        return False

    @property
    def peak_mib(self) -> float:
        return self.peak_bytes / (1024 * 1024)


def _clear_compiled() -> None:
    """Drop every in-process executable: the engines' jitted-program
    factories, the shard_map wrapper cache, and jax's trace/compile caches.
    The next sweep then behaves like a fresh process — programs re-trace,
    and XLA binaries come from the persistent compilation cache (when
    enabled) instead of a full recompile."""
    for mod in (sim_batch, sim_multi_batch):
        for name in dir(mod):
            obj = getattr(mod, name)
            if callable(getattr(obj, "cache_clear", None)):
                obj.cache_clear()
    sweep_shard._sharded_jit.cache_clear()
    jax.clear_caches()

PIECEWISE = TraceSpec(
    kind="piecewise", points=((0.0, 3.0), (0.3, 0.8), (0.9, 6.0)), rtt_ms=60.0
)


def make_grid(size: int) -> SweepGrid:
    """A network-aware grid with exactly ``size`` points (jax ladder)."""
    if size == 10:
        return SweepGrid(deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), fps=(20.0, 40.0))
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(1.0, 2.5),
        )
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(120.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(0.5, 1.0, 2.0, 4.0, 8.0),
            rtt_ms=(40.0, 70.0, 100.0, 130.0),
        )
    raise ValueError(f"no predefined grid of size {size}")


def make_net_grid(size: int) -> tuple[SweepGrid, TraceSpec]:
    """Network ladder: grid + base trace for the paper's planners.

    10/100-point grids replay a *piecewise* trace on device (deadline ×
    fps × rtt axes preserve it); the 1000-point grid sweeps a constant
    low-bandwidth regime where offload/local candidate selection really
    flips per point.
    """
    if size == 10:
        return SweepGrid(
            deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), rtt_ms=(50.0, 100.0)
        ), PIECEWISE
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            rtt_ms=(50.0, 100.0),
        ), PIECEWISE
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(240.0 + 16.0 * i for i in range(10)),
            fps=(30.0, 48.0, 50.0, 56.0, 60.0),
            bandwidth_mbps=(0.3, 0.5, 0.8, 1.1, 1.4),
            rtt_ms=(40.0, 70.0, 100.0, 130.0),
        ), TraceSpec(mbps=1.0)
    raise ValueError(f"no predefined network grid of size {size}")


def _stats_equiv(a, b) -> bool:
    """The certified cross-backend contract: ints exact, floats in tol."""
    return (
        abs(a.accuracy_sum - b.accuracy_sum) <= AUDIT_TOL
        and a.frames_processed == b.frames_processed
        and a.frames_missed_deadline == b.frames_missed_deadline
        and a.frames_offloaded == b.frames_offloaded
        and a.frames_total == b.frames_total
    )


def bench_cell(policy: str, params: dict, size: int, *, net: bool = False) -> dict:
    if net:
        grid, trace = make_net_grid(size)
    else:
        grid, trace = make_grid(size), TraceSpec(mbps=2.5)
    session = Session(
        ScenarioSpec(policy=PolicySpec(policy, params), n_frames=N_FRAMES,
                     trace=trace, label=f"sweep_bench/{policy}/{size}")
    )
    with _RssSampler() as rss:
        t0 = time.perf_counter()
        ref = session.run_sweep(grid, backend="reference")
        reference_s = time.perf_counter() - t0
        with CompileCounter() as cc:
            t0 = time.perf_counter()
            session.run_sweep(grid, backend="batched")
            batched_cold_s = time.perf_counter() - t0
        with CompileCounter() as cw:
            t0 = time.perf_counter()
            bat = session.run_sweep(grid, backend="batched")
            batched_warm_s = time.perf_counter() - t0
    assert bat.backend == "batched", bat.meta
    exact = all(
        _stats_equiv(pr.stats, pb.stats) for pr, pb in zip(ref.points, bat.points)
    )
    return {
        "policy": policy,
        "ladder": "network" if net else "jax",
        "trace": trace.kind,
        "grid_points": len(grid),
        "n_frames": N_FRAMES,
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "compiles_cold": cc.compiles,
        "compiles_warm": cw.compiles,
        "peak_rss_mib": round(rss.peak_mib, 1),
        "exact_match": exact,
    }


def make_scale_grid(points: int) -> SweepGrid:
    """A network-aware grid with exactly ``points`` points: deadline (20) x
    fps (5) x bandwidth (20) x rtt (points/2000).  Growing the grid only
    stretches the rtt axis, so every size hits the same shape buckets."""
    n_rtt, rem = divmod(points, 2000)
    if rem or n_rtt < 1:
        raise ValueError(f"scale grid size must be a positive multiple of 2000, got {points}")
    return SweepGrid(
        deadline_ms=tuple(150.0 + 10.0 * i for i in range(20)),
        fps=(24.0, 30.0, 48.0, 50.0, 60.0),
        bandwidth_mbps=tuple(0.3 + 0.2 * i for i in range(20)),
        rtt_ms=tuple(30.0 + 4.0 * i for i in range(n_rtt)),
    )


def bench_scale_cell(points: int, cache_dir: str) -> dict:
    """The streaming + persistent-cache headline (module docstring).

    Protocol: spot-check a 16-point corner against the reference loop, then
    run the full grid three times — cold (compiles, populates the disk
    cache), warm (everything hot), and cold-again after
    :func:`_clear_compiled` (fresh-process simulation: executables reload
    from the persistent cache).  Gate: cached-cold within 2x of warm, and
    zero XLA compiles on both the warm and cached-cold passes.
    """
    grid = make_scale_grid(points)
    pol, params = SCALE_POLICY
    session = Session(
        ScenarioSpec(policy=PolicySpec(pol, params), n_frames=SCALE_N_FRAMES,
                     trace=TraceSpec(mbps=2.5), label=f"sweep_bench/scale/{points}")
    )
    sub = SweepGrid(
        deadline_ms=grid.deadline_ms[:2], fps=grid.fps[:2],
        bandwidth_mbps=grid.bandwidth_mbps[:2], rtt_ms=grid.rtt_ms[:2],
    )
    ref = session.run_sweep(sub, backend="reference")
    bat = session.run_sweep(sub, backend="batched")
    spot_ok = all(
        _stats_equiv(a.stats, b.stats) for a, b in zip(ref.points, bat.points)
    )

    run_kw = dict(backend="batched", chunk_size=SCALE_CHUNK,
                  keep_points=False, compile_cache=cache_dir)
    _clear_compiled()  # the spot check must not pre-warm the cold pass
    with _RssSampler() as rss:
        with CompileCounter() as c1:
            t0 = time.perf_counter()
            rep1 = session.run_sweep(grid, **run_kw)
            cold_s = time.perf_counter() - t0
        with CompileCounter() as cw:
            t0 = time.perf_counter()
            rep2 = session.run_sweep(grid, **run_kw)
            warm_s = time.perf_counter() - t0
        _clear_compiled()
        with CompileCounter() as c2:
            t0 = time.perf_counter()
            rep3 = session.run_sweep(grid, **run_kw)
            cached_cold_s = time.perf_counter() - t0
    assert rep1.meta["summary"] == rep2.meta["summary"] == rep3.meta["summary"]
    assert rep1.meta["points_streamed"] == points
    return {
        "policy": pol,
        "ladder": "scale",
        "trace": "constant",
        "grid_points": len(grid),
        "n_frames": SCALE_N_FRAMES,
        "chunk_size": SCALE_CHUNK,
        "chunks": rep1.meta["chunks"],
        "compile_cache": cache_dir,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "cached_cold_s": cached_cold_s,
        "cached_cold_over_warm": cached_cold_s / warm_s if warm_s > 0 else 0.0,
        "cached_cold_within_2x_warm": cached_cold_s <= 2.0 * warm_s,
        "compiles_cold": c1.compiles,
        "compiles_warm": cw.compiles,
        "compiles_cached_cold": c2.compiles,
        "cache_hits_cached_cold": c2.cache_hits,
        "peak_rss_mib": round(rss.peak_mib, 1),
        "spot_check_exact": spot_ok,
        "summary": rep1.meta["summary"],
    }


def run(sizes=SIZES) -> dict:
    cells = [bench_cell(pol, params, size) for size in sizes for pol, params in POLICIES]
    cells += [
        bench_cell(pol, params, size, net=True)
        for size in sizes
        for pol, params in NET_POLICIES
    ]
    return {"bench": "sweep", "n_frames": N_FRAMES, "cells": cells}


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladder is a
# manual / CI-artifact run — see main()).
def sweep_backend_smoke():
    rows = []
    for cell in run(sizes=(10,))["cells"]:
        name = f"sweep/{cell['policy']}/n{cell['grid_points']}"
        rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6, cell["speedup_warm"]))
        rows.append((f"{name}/exact", cell["reference_s"] * 1e6, float(cell["exact_match"])))
    return rows


ALL = [sweep_backend_smoke]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smallest grids + 10k scale cell (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    ap.add_argument("--scale-points", type=int, default=None,
                    help="scale-cell grid size (default 10000 smoke / 100000 full; 0 skips it)")
    ap.add_argument("--cache-dir", default=None,
                    help=f"persistent compile-cache dir for the scale cell "
                         f"(default $REPRO_COMPILE_CACHE or {DEFAULT_CACHE_DIR})")
    args = ap.parse_args(argv)

    scale_points = args.scale_points
    if scale_points is None:
        scale_points = 10_000 if args.smoke else 100_000
    cache_dir = args.cache_dir or os.environ.get("REPRO_COMPILE_CACHE") or DEFAULT_CACHE_DIR

    result = run(sizes=(10,) if args.smoke else SIZES)
    if scale_points:
        result["cells"].append(bench_scale_cell(scale_points, cache_dir))
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'ladder':>8} {'policy':>14} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} "
          f"{'warm (s)':>9} {'speedup':>8} {'rss MiB':>8} {'exact':>6}")
    ok = True
    for c in result["cells"]:
        if c["ladder"] == "scale":
            continue
        print(f"{c['ladder']:>8} {c['policy']:>14} {c['grid_points']:>7} "
              f"{c['reference_s']:>9.2f} {c['batched_cold_s']:>9.2f} "
              f"{c['batched_warm_s']:>9.2f} {c['speedup_warm']:>7.1f}x "
              f"{c['peak_rss_mib']:>8.0f} {str(c['exact_match']):>6}")
        ok &= c["exact_match"]
        # the >= 10x acceptance bar applies to the jax ladder's 1000-point
        # network-aware cells (see module docstring for the network
        # ladder's honest-CPU-number rationale).
        if c["ladder"] == "jax" and c["grid_points"] >= 1000:
            ok &= c["speedup_warm"] >= 10.0
    for c in result["cells"]:
        if c["ladder"] != "scale":
            continue
        print(f"\nscale {c['policy']} {c['grid_points']} pts in {c['chunks']} chunks of "
              f"{c['chunk_size']}: cold {c['cold_s']:.1f}s ({c['compiles_cold']} compiles), "
              f"warm {c['warm_s']:.1f}s, cached-cold {c['cached_cold_s']:.1f}s "
              f"({c['cached_cold_over_warm']:.2f}x warm, {c['cache_hits_cached_cold']} cache "
              f"hits, {c['compiles_cached_cold']} compiles), peak RSS {c['peak_rss_mib']:.0f} MiB")
        ok &= c["spot_check_exact"]
        ok &= c["cached_cold_within_2x_warm"]
        ok &= c["compiles_warm"] == 0 and c["compiles_cached_cold"] == 0
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
