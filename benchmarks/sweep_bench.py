"""Fleet-sweep engine benchmark: reference loop vs batched jit/vmap backend.

Two ladders run the same grids through both ``Session.run_sweep`` backends
at {10, 100, 1000} points and report wall-clock plus the equivalence bit
(integer stats exact; accuracy sums within ``AUDIT_TOL`` — the speedup is
worthless otherwise):

  * the **jax ladder** (``jax_accuracy``/``jax_utility``): network-aware
    (bandwidth × deadline × fps × rtt) grids — the axes parameterize the
    scenario; these local-only policies ignore the network, and their
    per-round reference pays a jitted-kernel dispatch, which is what the
    vectorized engine amortizes.  **Acceptance bar: >= 10x warm at the
    1000-point grid** (tracked since PR 3, now on a network-aware grid).
  * the **network ladder** (``max_accuracy``/``max_utility``): the paper's
    offload-capable planners on network-aware grids — piecewise traces with
    an rtt axis at 10/100 points, a low-bandwidth (bandwidth × deadline ×
    fps × rtt) grid at 1000.  Their *reference* is plain numpy/Python (no
    per-round jit dispatch), so on a small-CPU host the batched engine
    roughly breaks even — the recorded ``speedup_warm`` is the honest
    number, gated on equivalence only (the row exists to track the perf
    trajectory on parallel hardware, where the lanes are free).

Results land in ``BENCH_sweep.json`` so CI can track the trajectory:

    PYTHONPATH=src python benchmarks/sweep_bench.py            # full ladders
    PYTHONPATH=src python benchmarks/sweep_bench.py --smoke    # 10-point grids
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.core.audit import AUDIT_TOL  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid, TraceSpec  # noqa: E402

N_FRAMES = 120
POLICIES = (("jax_accuracy", {}), ("jax_utility", {"alpha": 200.0}))
NET_POLICIES = (("max_accuracy", {}), ("max_utility", {"alpha": 200.0}))
SIZES = (10, 100, 1000)
DEFAULT_OUT = "BENCH_sweep.json"

PIECEWISE = TraceSpec(
    kind="piecewise", points=((0.0, 3.0), (0.3, 0.8), (0.9, 6.0)), rtt_ms=60.0
)


def make_grid(size: int) -> SweepGrid:
    """A network-aware grid with exactly ``size`` points (jax ladder)."""
    if size == 10:
        return SweepGrid(deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), fps=(20.0, 40.0))
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(1.0, 2.5),
        )
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(120.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(0.5, 1.0, 2.0, 4.0, 8.0),
            rtt_ms=(40.0, 70.0, 100.0, 130.0),
        )
    raise ValueError(f"no predefined grid of size {size}")


def make_net_grid(size: int) -> tuple[SweepGrid, TraceSpec]:
    """Network ladder: grid + base trace for the paper's planners.

    10/100-point grids replay a *piecewise* trace on device (deadline ×
    fps × rtt axes preserve it); the 1000-point grid sweeps a constant
    low-bandwidth regime where offload/local candidate selection really
    flips per point.
    """
    if size == 10:
        return SweepGrid(
            deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), rtt_ms=(50.0, 100.0)
        ), PIECEWISE
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            rtt_ms=(50.0, 100.0),
        ), PIECEWISE
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(240.0 + 16.0 * i for i in range(10)),
            fps=(30.0, 48.0, 50.0, 56.0, 60.0),
            bandwidth_mbps=(0.3, 0.5, 0.8, 1.1, 1.4),
            rtt_ms=(40.0, 70.0, 100.0, 130.0),
        ), TraceSpec(mbps=1.0)
    raise ValueError(f"no predefined network grid of size {size}")


def _stats_equiv(a, b) -> bool:
    """The certified cross-backend contract: ints exact, floats in tol."""
    return (
        abs(a.accuracy_sum - b.accuracy_sum) <= AUDIT_TOL
        and a.frames_processed == b.frames_processed
        and a.frames_missed_deadline == b.frames_missed_deadline
        and a.frames_offloaded == b.frames_offloaded
        and a.frames_total == b.frames_total
    )


def bench_cell(policy: str, params: dict, size: int, *, net: bool = False) -> dict:
    if net:
        grid, trace = make_net_grid(size)
    else:
        grid, trace = make_grid(size), TraceSpec(mbps=2.5)
    session = Session(
        ScenarioSpec(policy=PolicySpec(policy, params), n_frames=N_FRAMES,
                     trace=trace, label=f"sweep_bench/{policy}/{size}")
    )
    t0 = time.perf_counter()
    ref = session.run_sweep(grid, backend="reference")
    reference_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    session.run_sweep(grid, backend="batched")
    batched_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = session.run_sweep(grid, backend="batched")
    batched_warm_s = time.perf_counter() - t0
    assert bat.backend == "batched", bat.meta
    exact = all(
        _stats_equiv(pr.stats, pb.stats) for pr, pb in zip(ref.points, bat.points)
    )
    return {
        "policy": policy,
        "ladder": "network" if net else "jax",
        "trace": trace.kind,
        "grid_points": len(grid),
        "n_frames": N_FRAMES,
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "exact_match": exact,
    }


def run(sizes=SIZES) -> dict:
    cells = [bench_cell(pol, params, size) for size in sizes for pol, params in POLICIES]
    cells += [
        bench_cell(pol, params, size, net=True)
        for size in sizes
        for pol, params in NET_POLICIES
    ]
    return {"bench": "sweep", "n_frames": N_FRAMES, "cells": cells}


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladder is a
# manual / CI-artifact run — see main()).
def sweep_backend_smoke():
    rows = []
    for cell in run(sizes=(10,))["cells"]:
        name = f"sweep/{cell['policy']}/n{cell['grid_points']}"
        rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6, cell["speedup_warm"]))
        rows.append((f"{name}/exact", cell["reference_s"] * 1e6, float(cell["exact_match"])))
    return rows


ALL = [sweep_backend_smoke]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smallest grids only (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    result = run(sizes=(10,) if args.smoke else SIZES)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'ladder':>8} {'policy':>14} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} "
          f"{'warm (s)':>9} {'speedup':>8} {'exact':>6}")
    ok = True
    for c in result["cells"]:
        print(f"{c['ladder']:>8} {c['policy']:>14} {c['grid_points']:>7} "
              f"{c['reference_s']:>9.2f} {c['batched_cold_s']:>9.2f} "
              f"{c['batched_warm_s']:>9.2f} {c['speedup_warm']:>7.1f}x "
              f"{str(c['exact_match']):>6}")
        ok &= c["exact_match"]
        # the >= 10x acceptance bar applies to the jax ladder's 1000-point
        # network-aware cells (see module docstring for the network
        # ladder's honest-CPU-number rationale).
        if c["ladder"] == "jax" and c["grid_points"] >= 1000:
            ok &= c["speedup_warm"] >= 10.0
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
