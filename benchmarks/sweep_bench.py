"""Fleet-sweep engine benchmark: reference loop vs batched jit/vmap backend.

Runs the same (deadline x fps x bandwidth) scenario grid through both
``Session.run_sweep`` backends at grid sizes {10, 100, 1000} and reports
wall-clock plus an exactness check (the batched backend must reproduce the
reference stats bit-for-bit — the speedup is worthless otherwise).  Results
land in ``BENCH_sweep.json`` so CI can track the perf trajectory:

    PYTHONPATH=src python benchmarks/sweep_bench.py            # full ladder
    PYTHONPATH=src python benchmarks/sweep_bench.py --smoke    # 10-point grid

Acceptance criterion tracked here: at the 1000-point grid the batched
backend is >= 10x faster than the reference loop (warm, i.e. compiled;
``batched_cold_s`` includes jit compilation and is reported alongside).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid  # noqa: E402

N_FRAMES = 120
POLICIES = (("jax_accuracy", {}), ("jax_utility", {"alpha": 200.0}))
SIZES = (10, 100, 1000)
DEFAULT_OUT = "BENCH_sweep.json"


def make_grid(size: int) -> SweepGrid:
    """A (deadline x fps x bandwidth) grid with exactly ``size`` points."""
    if size == 10:
        return SweepGrid(deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), fps=(20.0, 40.0))
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(1.0, 2.5),
        )
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(120.0 + 10.0 * i for i in range(20)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=tuple(0.5 * (i + 1) for i in range(10)),
        )
    raise ValueError(f"no predefined grid of size {size}")


def _stats_equal(a, b) -> bool:
    return (
        a.accuracy_sum == b.accuracy_sum
        and a.frames_processed == b.frames_processed
        and a.frames_missed_deadline == b.frames_missed_deadline
        and a.frames_offloaded == b.frames_offloaded
        and a.frames_total == b.frames_total
    )


def bench_cell(policy: str, params: dict, size: int) -> dict:
    grid = make_grid(size)
    session = Session(
        ScenarioSpec(policy=PolicySpec(policy, params), n_frames=N_FRAMES,
                     label=f"sweep_bench/{policy}/{size}")
    )
    t0 = time.perf_counter()
    ref = session.run_sweep(grid, backend="reference")
    reference_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    session.run_sweep(grid, backend="batched")
    batched_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = session.run_sweep(grid, backend="batched")
    batched_warm_s = time.perf_counter() - t0
    exact = all(
        _stats_equal(pr.stats, pb.stats) for pr, pb in zip(ref.points, bat.points)
    )
    return {
        "policy": policy,
        "grid_points": len(grid),
        "n_frames": N_FRAMES,
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "exact_match": exact,
    }


def run(sizes=SIZES, policies=POLICIES) -> dict:
    cells = [bench_cell(pol, params, size) for size in sizes for pol, params in policies]
    return {"bench": "sweep", "n_frames": N_FRAMES, "cells": cells}


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladder is a
# manual / CI-artifact run — see main()).
def sweep_backend_smoke():
    rows = []
    for cell in run(sizes=(10,))["cells"]:
        name = f"sweep/{cell['policy']}/n{cell['grid_points']}"
        rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6, cell["speedup_warm"]))
        rows.append((f"{name}/exact", cell["reference_s"] * 1e6, float(cell["exact_match"])))
    return rows


ALL = [sweep_backend_smoke]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smallest grid only (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    result = run(sizes=(10,) if args.smoke else SIZES)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'policy':>14} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} "
          f"{'warm (s)':>9} {'speedup':>8} {'exact':>6}")
    ok = True
    for c in result["cells"]:
        print(f"{c['policy']:>14} {c['grid_points']:>7} {c['reference_s']:>9.2f} "
              f"{c['batched_cold_s']:>9.2f} {c['batched_warm_s']:>9.2f} "
              f"{c['speedup_warm']:>7.1f}x {str(c['exact_match']):>6}")
        ok &= c["exact_match"]
        if c["grid_points"] >= 1000:
            ok &= c["speedup_warm"] >= 10.0
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
