"""Multi-stream edge-server benchmark: N clients sharing one uplink + edge.

Two halves:

1. **Backend ladders** (the default; emits ``BENCH_multistream.json``):
   (bandwidth x deadline x n_clients x allocation) fleet grids of
   interacting clients run through both ``Session.run_sweep`` backends —
   the reference ``simulate_multi`` event loop and the vectorized
   ``core/sim_multi_batch`` engine — at grid sizes {10, 100, 1000}, once
   for the ``offload`` policy and once for the ``max_accuracy`` DP
   planner (per-client dynamic programming over granted bandwidth, the
   planner-fleet ladder).  Every cell asserts equivalence (integer stats
   exact, float stats within ``sim_multi_batch.MULTI_TOL``; bit-equality
   is recorded as ``exact_match``).  Acceptance criteria tracked here: at
   the 1000-point grid the batched engine is >= 10x (offload) / >= 5x
   (planner) faster than the reference loop warm (``batched_cold_s``
   includes jit compilation).

2. **Fleet behaviour tables** (``--tables``): per (bandwidth, policy,
   client-count) cell, fleet aggregate accuracy, worst per-client
   deadline-miss rate, edge frames, and server utilization — the
   multi-tenant subsystem's original acceptance numbers (coordinated
   policies stay bounded while naive FIFO collapses under contention).

    PYTHONPATH=src python benchmarks/multistream_bench.py           # full ladder
    PYTHONPATH=src python benchmarks/multistream_bench.py --smoke   # 10+100 (CI)
    PYTHONPATH=src python benchmarks/multistream_bench.py --tables  # + tables
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.core import sim_multi_batch  # noqa: E402
from repro.core.sim_multi_batch import EQUIV_INT_FIELDS, MULTI_TOL  # noqa: E402
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec  # noqa: E402

N_FRAMES = 60
CLIENT_COUNTS = (1, 2, 4, 8)
POLICIES = ("weighted_fair", "fifo")
BANDWIDTHS_MBPS = (6.0, 12.0)
CAPACITY = 4

# Backend-ladder knobs (half 1).
LADDER_FRAMES = 30
# The DP planner reference loop costs far more per round than offload's
# closed-form scoring; a shorter horizon and a coarser DP grid keep the
# 1000-point reference run in CI-friendly territory without changing what
# is measured (per-point planning + shared-link contention).
PLANNER_FRAMES = 12
PLANNER_PARAMS = {"grid": 10e-3}
SIZES = (10, 100, 1000)
DEFAULT_OUT = "BENCH_multistream.json"


def _run(mbps: float, allocation: str, n: int, *, capacity: int = CAPACITY,
         priorities=None):
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=N_FRAMES,
        trace=TraceSpec(mbps=mbps),
        fleet=FleetSpec(n_clients=n, allocation=allocation, capacity=capacity,
                        priorities=priorities),
        label=f"multistream/B{mbps}/{allocation}/n{n}",
    )
    return Session(spec).run_multi()


def _cells(policies=POLICIES, bandwidths=BANDWIDTHS_MBPS, counts=CLIENT_COUNTS):
    """Yield (mbps, allocation, n, SweepPoint) for every lattice cell, in the
    legacy bandwidth > policy > count display order."""
    base = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=N_FRAMES,
        fleet=FleetSpec(capacity=CAPACITY),
        label="multistream",
    )
    grid = SweepGrid(
        bandwidth_mbps=bandwidths, n_clients=counts, allocation=policies
    )
    report = Session(base).run_sweep(grid)
    by_cell = {
        (p.overrides["bandwidth_mbps"], p.overrides["allocation"], p.overrides["n_clients"]): p
        for p in report
    }
    for mbps in bandwidths:
        for pol in policies:
            for n in counts:
                yield mbps, pol, n, by_cell[(mbps, pol, n)]


def multistream_scaling():
    """Fleet accuracy + worst-client miss rate vs client count and policy."""
    rows = []
    for mbps, pol, n, rep in _cells():
        us = sum(s.schedule_time for s in rep.streams) / max(
            sum(s.schedule_calls for s in rep.streams), 1
        ) * 1e6
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/agg_acc", us, rep.aggregate_accuracy))
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/max_miss", 0.0, rep.max_miss_rate))
        rows.append(
            (
                f"multistream/B{mbps}/{pol}/n{n}/edge_frames",
                0.0,
                float(sum(s.frames_offloaded for s in rep.streams)),
            )
        )
    return rows


def multistream_priority():
    """Two priority classes, one server slot: high class keeps the edge."""
    rows = []
    priorities = (0, 0, 2, 2)
    rep = _run(12.0, "priority", 4, capacity=1, priorities=priorities)
    for cid, (p, s) in enumerate(zip(priorities, rep.streams)):
        rows.append(
            (
                f"multistream/priority/p{p}/c{cid}/acc",
                0.0,
                s.accuracy_sum / max(s.frames_total, 1),
            )
        )
        rows.append(
            (f"multistream/priority/p{p}/c{cid}/edge_frames", 0.0, float(s.frames_offloaded))
        )
    return rows


# ---------------------------------------------------------------------------
# Half 1: reference vs batched fleet engine (BENCH_multistream.json)
# ---------------------------------------------------------------------------

def make_fleet_grid(size: int, *, counts=(4, 8)) -> SweepGrid:
    """A (bandwidth x deadline x n_clients x allocation) fleet grid with
    exactly ``size`` points — every point an *interacting* fleet."""
    if size == 10:
        return SweepGrid(
            bandwidth_mbps=(2.0, 4.0, 6.0, 9.0, 12.0),
            n_clients=counts[:1],
            allocation=("weighted_fair", "fifo"),
        )
    if size == 100:
        return SweepGrid(
            bandwidth_mbps=(1.0, 2.5, 6.0, 9.0, 12.0),
            deadline_ms=(150.0, 175.0, 200.0, 250.0, 350.0),
            n_clients=counts,
            allocation=("weighted_fair", "fifo"),
        )
    if size == 1000:
        return SweepGrid(
            bandwidth_mbps=tuple(1.0 + 0.5 * i for i in range(25)),
            deadline_ms=tuple(120.0 + 25.0 * i for i in range(10)),
            n_clients=counts,
            allocation=("weighted_fair", "fifo"),
        )
    raise ValueError(f"no predefined fleet grid of size {size}")


def _compare_points(ref, bat) -> tuple[bool, bool, float]:
    """(equivalent within MULTI_TOL, bit-exact floats, max abs float diff)."""
    ints_ok, exact = True, True
    max_diff = 0.0
    for pr, pb in zip(ref.points, bat.points):
        for sr, sb in zip(pr.streams, pb.streams):
            ints_ok &= all(getattr(sr, f) == getattr(sb, f) for f in EQUIV_INT_FIELDS)
            d = abs(sr.accuracy_sum - sb.accuracy_sum)
            max_diff = max(max_diff, d)
            exact &= sr.accuracy_sum == sb.accuracy_sum
        for key in ("server_jobs", "grants", "denials"):
            ints_ok &= pr.meta.get(key) == pb.meta.get(key)
    return ints_ok and max_diff <= MULTI_TOL, exact and ints_ok, max_diff


# Per-policy ladder knobs: (params, frames, fleet sizes, required warm
# speedup at the 1000-point grid).
LADDERS = {
    "offload": ({}, LADDER_FRAMES, (4, 8), 10.0),
    "max_accuracy": (PLANNER_PARAMS, PLANNER_FRAMES, (2, 4), 5.0),
}

_PROGRAM_CACHES = (
    sim_multi_batch._fleet_program,
    sim_multi_batch._acc_fleet_program,
    sim_multi_batch._util_fleet_program,
    sim_multi_batch._jax_acc_fleet_program,
    sim_multi_batch._jax_util_fleet_program,
)


def bench_cell(size: int, policy: str = "offload", *, ref_repeats: int = 1,
               warm_repeats: int = 2) -> dict:
    params, frames, counts, _ = LADDERS[policy]
    grid = make_fleet_grid(size, counts=counts)
    session = Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params),
            n_frames=frames,
            trace=TraceSpec(mbps=6.0),
            fleet=FleetSpec(capacity=CAPACITY),
            label=f"multistream_bench/{policy}/{size}",
        )
    )
    # Best-of-N on both sides of the ratio: single-shot wall clocks on a
    # shared CI box jitter by 20-30%, which is larger than the margin on the
    # planner-ladder speedup gate.
    reference_s = float("inf")
    for _ in range(max(ref_repeats, 1)):
        t0 = time.perf_counter()
        ref = session.run_sweep(grid, backend="reference")
        reference_s = min(reference_s, time.perf_counter() - t0)
    # Drop compiled programs carried over from smaller ladder cells so
    # batched_cold_s honestly includes this cell's jit compilation.
    for cache in _PROGRAM_CACHES:
        cache.cache_clear()
    t0 = time.perf_counter()
    session.run_sweep(grid, backend="batched")
    batched_cold_s = time.perf_counter() - t0
    batched_warm_s = float("inf")
    for _ in range(max(warm_repeats, 1)):
        t0 = time.perf_counter()
        bat = session.run_sweep(grid, backend="batched")
        batched_warm_s = min(batched_warm_s, time.perf_counter() - t0)
    assert bat.meta.get("engine") == "sim_multi_batch", bat.meta
    equivalent, exact, max_diff = _compare_points(ref, bat)
    return {
        "policy": policy,
        "grid_points": len(grid),
        "n_frames": frames,
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "equivalent": equivalent,
        "exact_match": exact,
        "max_abs_diff": max_diff,
    }


def run_ladder(sizes=SIZES, policies=tuple(LADDERS)) -> dict:
    return {
        "bench": "multistream",
        "tolerance": MULTI_TOL,
        "ladders": [
            {
                "policy": policy,
                "n_frames": LADDERS[policy][1],
                "params": LADDERS[policy][0],
                # The planner reference sweep is cheap enough to repeat; the
                # offload reference at 1000 points is the ladder's dominant
                # cost, and its 10x gate has ample margin single-shot.
                "cells": [
                    bench_cell(size, policy,
                               ref_repeats=2 if policy != "offload" else 1)
                    for size in sizes
                ],
            }
            for policy in policies
        ],
    }


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladders are a
# manual / CI-artifact run — see main()).
def multistream_backend_smoke():
    rows = []
    for ladder in run_ladder(sizes=(10,))["ladders"]:
        for cell in ladder["cells"]:
            name = f"multistream/{cell['policy']}/n{cell['grid_points']}"
            rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6, cell["speedup_warm"]))
            rows.append((f"{name}/equivalent", cell["reference_s"] * 1e6, float(cell["equivalent"])))
    return rows


ALL = [multistream_backend_smoke, multistream_scaling, multistream_priority]


def _tables() -> int:
    print(f"{N_FRAMES} frames/client, capacity={CAPACITY} server slots\n")
    print(f"{'B (Mbps)':>8} {'policy':>14} {'N':>3} {'agg acc':>8} {'max miss':>9} "
          f"{'edge frames':>12} {'srv util':>9}")
    ok_bounded = True
    acc: dict[tuple[float, str, int], float] = {}
    for mbps, pol, n, rep in _cells(policies=("weighted_fair", "fifo")):
        edge = sum(s.frames_offloaded for s in rep.streams)
        print(f"{mbps:8.1f} {pol:>14} {n:3d} {rep.aggregate_accuracy:8.3f} "
              f"{rep.max_miss_rate:9.2f} {edge:12d} {rep.meta['server_utilization']:9.2f}")
        acc[(mbps, pol, n)] = rep.aggregate_accuracy
        if pol == "weighted_fair" and rep.max_miss_rate > 0.10:
            ok_bounded = False
    ok_beats_fifo = all(
        acc[(mbps, "weighted_fair", n)] >= acc[(mbps, "fifo", n)] - 1e-9
        for mbps in BANDWIDTHS_MBPS
        for n in CLIENT_COUNTS
        if n >= 2
    )
    print("\npriority demo (4 clients, priorities 0,0,2,2, ONE server slot):")
    for name, _, v in multistream_priority():
        print(f"  {name} = {v:.3f}")
    print(f"\ncoordinated miss rate bounded (<=0.10 at every N): {ok_bounded}")
    print(f"weighted_fair >= fifo aggregate accuracy for N>=2:  {ok_beats_fifo}")
    return 0 if (ok_bounded and ok_beats_fifo) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="10+100-point grids only (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    ap.add_argument("--tables", action="store_true",
                    help="also print the fleet behaviour tables (max_accuracy lattice)")
    args = ap.parse_args(argv)

    result = run_ladder(sizes=(10, 100) if args.smoke else SIZES)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'policy':>14} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} {'warm (s)':>9} "
          f"{'speedup':>8} {'equiv':>6} {'exact':>6}")
    ok = True
    for ladder in result["ladders"]:
        min_speedup = LADDERS[ladder["policy"]][3]
        for c in ladder["cells"]:
            print(f"{c['policy']:>14} {c['grid_points']:>7} {c['reference_s']:>9.2f} "
                  f"{c['batched_cold_s']:>9.2f} {c['batched_warm_s']:>9.2f} "
                  f"{c['speedup_warm']:>7.1f}x {str(c['equivalent']):>6} "
                  f"{str(c['exact_match']):>6}")
            ok &= c["equivalent"]
            if c["grid_points"] >= 1000:
                ok &= c["speedup_warm"] >= min_speedup
    print(f"\nwrote {args.out}")

    if args.tables:
        print()
        ok &= _tables() == 0
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
