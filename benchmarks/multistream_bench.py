"""Multi-stream edge-server benchmark: N clients sharing one uplink + edge.

Reports, per (bandwidth, policy, client-count) cell:
  * fleet aggregate accuracy (mean over all frames of all clients, missed = 0);
  * the worst per-client deadline-miss rate;
  * total frames served on the edge and server utilization.

What the numbers show (acceptance criteria for the multi-tenant subsystem):
  * coordinated policies (weighted_fair / priority) keep every client's
    deadline-miss rate bounded (~0) as the client count grows — saturated
    clients degrade to their local NPU plan instead of missing deadlines;
  * naive FIFO offloading (every client assumes it owns the link) collapses
    under contention, so the edge-server policy beats it on aggregate
    accuracy for every N >= 2.

Run directly for a human-readable table:

    PYTHONPATH=src python benchmarks/multistream_bench.py
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import EdgeServerScheduler, Trace, make_fleet, simulate_multi  # noqa: E402

N_FRAMES = 60
CLIENT_COUNTS = (1, 2, 4, 8)
POLICIES = ("weighted_fair", "fifo")
BANDWIDTHS_MBPS = (6.0, 12.0)
CAPACITY = 4


def _cells(policies=POLICIES, bandwidths=BANDWIDTHS_MBPS, counts=CLIENT_COUNTS):
    for mbps in bandwidths:
        for pol in policies:
            for n in counts:
                sched = EdgeServerScheduler(make_fleet(n), policy=pol, capacity=CAPACITY)
                ms = simulate_multi(sched, Trace.constant(mbps), N_FRAMES)
                yield mbps, pol, n, sched, ms


def multistream_scaling():
    """Fleet accuracy + worst-client miss rate vs client count and policy."""
    rows = []
    for mbps, pol, n, sched, ms in _cells():
        us = sum(s.schedule_time for s in ms.per_client) / max(
            sum(s.schedule_calls for s in ms.per_client), 1
        ) * 1e6
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/agg_acc", us, ms.aggregate_accuracy))
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/max_miss", 0.0, ms.max_miss_rate))
        rows.append(
            (
                f"multistream/B{mbps}/{pol}/n{n}/edge_frames",
                0.0,
                float(sum(s.frames_offloaded for s in ms.per_client)),
            )
        )
    return rows


def multistream_priority():
    """Two priority classes, one server slot: high class keeps the edge."""
    rows = []
    fleet = make_fleet(4, priorities=[0, 0, 2, 2])
    sched = EdgeServerScheduler(fleet, policy="priority", capacity=1)
    ms = simulate_multi(sched, Trace.constant(12.0), N_FRAMES)
    for c, s in zip(fleet, ms.per_client):
        rows.append(
            (
                f"multistream/priority/p{c.priority}/c{c.client_id}/acc",
                0.0,
                s.accuracy_sum / max(s.frames_total, 1),
            )
        )
        rows.append(
            (f"multistream/priority/p{c.priority}/c{c.client_id}/edge_frames", 0.0,
             float(s.frames_offloaded))
        )
    return rows


ALL = [multistream_scaling, multistream_priority]


def main() -> int:
    print(f"{N_FRAMES} frames/client, capacity={CAPACITY} server slots\n")
    print(f"{'B (Mbps)':>8} {'policy':>14} {'N':>3} {'agg acc':>8} {'max miss':>9} "
          f"{'edge frames':>12} {'srv util':>9}")
    ok_bounded = True
    acc: dict[tuple[float, str, int], float] = {}
    for mbps, pol, n, sched, ms in _cells(policies=("weighted_fair", "fifo")):
        edge = sum(s.frames_offloaded for s in ms.per_client)
        print(f"{mbps:8.1f} {pol:>14} {n:3d} {ms.aggregate_accuracy:8.3f} "
              f"{ms.max_miss_rate:9.2f} {edge:12d} {ms.server_utilization:9.2f}")
        acc[(mbps, pol, n)] = ms.aggregate_accuracy
        if pol == "weighted_fair" and ms.max_miss_rate > 0.10:
            ok_bounded = False
    ok_beats_fifo = all(
        acc[(mbps, "weighted_fair", n)] >= acc[(mbps, "fifo", n)] - 1e-9
        for mbps in BANDWIDTHS_MBPS
        for n in CLIENT_COUNTS
        if n >= 2
    )
    print("\npriority demo (4 clients, priorities 0,0,2,2, ONE server slot):")
    for name, _, v in multistream_priority():
        print(f"  {name} = {v:.3f}")
    print(f"\ncoordinated miss rate bounded (<=0.10 at every N): {ok_bounded}")
    print(f"weighted_fair >= fifo aggregate accuracy for N>=2:  {ok_beats_fifo}")
    return 0 if (ok_bounded and ok_beats_fifo) else 1


if __name__ == "__main__":
    raise SystemExit(main())
