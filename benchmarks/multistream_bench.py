"""Multi-stream edge-server benchmark: N clients sharing one uplink + edge.

Reports, per (bandwidth, policy, client-count) cell:
  * fleet aggregate accuracy (mean over all frames of all clients, missed = 0);
  * the worst per-client deadline-miss rate;
  * total frames served on the edge and server utilization.

What the numbers show (acceptance criteria for the multi-tenant subsystem):
  * coordinated policies (weighted_fair / priority) keep every client's
    deadline-miss rate bounded (~0) as the client count grows — saturated
    clients degrade to their local NPU plan instead of missing deadlines;
  * naive FIFO offloading (every client assumes it owns the link) collapses
    under contention, so the edge-server policy beats it on aggregate
    accuracy for every N >= 2.

The whole (bandwidth x allocation x client-count) lattice is ONE declarative
``SweepGrid`` run through ``Session.run_sweep`` (each point executes the
audited ``run_multi`` engine); only the priority demo is a hand-built
single ``ScenarioSpec``.  Run directly for a human-readable table:

    PYTHONPATH=src python benchmarks/multistream_bench.py
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec  # noqa: E402

N_FRAMES = 60
CLIENT_COUNTS = (1, 2, 4, 8)
POLICIES = ("weighted_fair", "fifo")
BANDWIDTHS_MBPS = (6.0, 12.0)
CAPACITY = 4


def _run(mbps: float, allocation: str, n: int, *, capacity: int = CAPACITY,
         priorities=None):
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=N_FRAMES,
        trace=TraceSpec(mbps=mbps),
        fleet=FleetSpec(n_clients=n, allocation=allocation, capacity=capacity,
                        priorities=priorities),
        label=f"multistream/B{mbps}/{allocation}/n{n}",
    )
    return Session(spec).run_multi()


def _cells(policies=POLICIES, bandwidths=BANDWIDTHS_MBPS, counts=CLIENT_COUNTS):
    """Yield (mbps, allocation, n, SweepPoint) for every lattice cell, in the
    legacy bandwidth > policy > count display order."""
    base = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=N_FRAMES,
        fleet=FleetSpec(capacity=CAPACITY),
        label="multistream",
    )
    grid = SweepGrid(
        bandwidth_mbps=bandwidths, n_clients=counts, allocation=policies
    )
    report = Session(base).run_sweep(grid)
    by_cell = {
        (p.overrides["bandwidth_mbps"], p.overrides["allocation"], p.overrides["n_clients"]): p
        for p in report
    }
    for mbps in bandwidths:
        for pol in policies:
            for n in counts:
                yield mbps, pol, n, by_cell[(mbps, pol, n)]


def multistream_scaling():
    """Fleet accuracy + worst-client miss rate vs client count and policy."""
    rows = []
    for mbps, pol, n, rep in _cells():
        us = sum(s.schedule_time for s in rep.streams) / max(
            sum(s.schedule_calls for s in rep.streams), 1
        ) * 1e6
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/agg_acc", us, rep.aggregate_accuracy))
        rows.append((f"multistream/B{mbps}/{pol}/n{n}/max_miss", 0.0, rep.max_miss_rate))
        rows.append(
            (
                f"multistream/B{mbps}/{pol}/n{n}/edge_frames",
                0.0,
                float(sum(s.frames_offloaded for s in rep.streams)),
            )
        )
    return rows


def multistream_priority():
    """Two priority classes, one server slot: high class keeps the edge."""
    rows = []
    priorities = (0, 0, 2, 2)
    rep = _run(12.0, "priority", 4, capacity=1, priorities=priorities)
    for cid, (p, s) in enumerate(zip(priorities, rep.streams)):
        rows.append(
            (
                f"multistream/priority/p{p}/c{cid}/acc",
                0.0,
                s.accuracy_sum / max(s.frames_total, 1),
            )
        )
        rows.append(
            (f"multistream/priority/p{p}/c{cid}/edge_frames", 0.0, float(s.frames_offloaded))
        )
    return rows


ALL = [multistream_scaling, multistream_priority]


def main() -> int:
    print(f"{N_FRAMES} frames/client, capacity={CAPACITY} server slots\n")
    print(f"{'B (Mbps)':>8} {'policy':>14} {'N':>3} {'agg acc':>8} {'max miss':>9} "
          f"{'edge frames':>12} {'srv util':>9}")
    ok_bounded = True
    acc: dict[tuple[float, str, int], float] = {}
    for mbps, pol, n, rep in _cells(policies=("weighted_fair", "fifo")):
        edge = sum(s.frames_offloaded for s in rep.streams)
        print(f"{mbps:8.1f} {pol:>14} {n:3d} {rep.aggregate_accuracy:8.3f} "
              f"{rep.max_miss_rate:9.2f} {edge:12d} {rep.meta['server_utilization']:9.2f}")
        acc[(mbps, pol, n)] = rep.aggregate_accuracy
        if pol == "weighted_fair" and rep.max_miss_rate > 0.10:
            ok_bounded = False
    ok_beats_fifo = all(
        acc[(mbps, "weighted_fair", n)] >= acc[(mbps, "fifo", n)] - 1e-9
        for mbps in BANDWIDTHS_MBPS
        for n in CLIENT_COUNTS
        if n >= 2
    )
    print("\npriority demo (4 clients, priorities 0,0,2,2, ONE server slot):")
    for name, _, v in multistream_priority():
        print(f"  {name} = {v:.3f}")
    print(f"\ncoordinated miss rate bounded (<=0.10 at every N): {ok_bounded}")
    print(f"weighted_fair >= fifo aggregate accuracy for N>=2:  {ok_beats_fifo}")
    return 0 if (ok_bounded and ok_beats_fifo) else 1


if __name__ == "__main__":
    raise SystemExit(main())
