# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> None:
    from benchmarks import (
        adaptivity_bench,
        kernels_bench,
        multistream_bench,
        paper_figures,
        roofline_bench,
    )

    print("name,us_per_call,derived")
    for group in (paper_figures.ALL, adaptivity_bench.ALL, kernels_bench.ALL,
                  roofline_bench.ALL, multistream_bench.ALL):
        for bench in group:
            for name, us, derived in bench():
                print(f"{name},{us:.2f},{derived:.6f}", flush=True)


if __name__ == "__main__":
    main()
