# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Benchmark groups are auto-discovered: every ``benchmarks/*_bench.py`` or
# ``benchmarks/*_figures.py`` module exposing an ``ALL`` list of zero-arg
# row-producers is swept — drop a new module in this directory and it runs,
# no import-list edit needed.
import importlib
import sys
from pathlib import Path

BENCH_DIR = Path(__file__).resolve().parent
sys.path.insert(0, str(BENCH_DIR.parent / "src"))
sys.path.insert(0, str(BENCH_DIR.parent))


def discover_groups() -> list[tuple[str, list]]:
    """(module_name, ALL) for every benchmark module in this directory.

    Every ``*_bench.py`` must also expose ``main(argv)`` accepting
    ``--smoke`` — the CI bench lane invokes exactly that, so a bench that
    drops the flag (or the entry point) fails here at discovery time, not
    silently in CI.
    """
    groups = []
    for path in sorted(BENCH_DIR.glob("*.py")):
        if path.name.startswith("_") or path.stem in ("run", "make_experiments_tables"):
            continue
        mod = importlib.import_module(f"benchmarks.{path.stem}")
        if path.name.endswith("_bench.py"):
            if not callable(getattr(mod, "main", None)) or "--smoke" not in path.read_text():
                raise AssertionError(
                    f"benchmarks/{path.name} must expose main(argv) with a --smoke flag"
                )
        all_ = getattr(mod, "ALL", None)
        if all_:
            groups.append((path.stem, list(all_)))
    return groups


def main() -> None:
    print("name,us_per_call,derived")
    for _name, group in discover_groups():
        for bench in group:
            for name, us, derived in bench():
                print(f"{name},{us:.2f},{derived:.6f}", flush=True)


if __name__ == "__main__":
    main()
