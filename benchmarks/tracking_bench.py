"""Tracking-workload engine benchmark: reference loop vs batched backends.

Two ladders run detect+track grids through both ``Session.run_sweep``
backends and report wall-clock plus the equivalence bit (integer stats
exact; accuracy sums within the certified tolerance — the speedup is
worthless otherwise):

  * the **track ladder** (``track_accuracy``/``track_fixed``): single-stream
    grids at {10, 100, 1000} points over deadline × fps × bandwidth × rtt,
    with a piecewise trace at the small sizes.  **Acceptance bar: >= 5x
    warm at the 1000-point grid** — tracking rounds consume ``k`` frames at
    a time, so the reference loop amortizes its Python planner over fewer
    rounds than classification; the bar is set accordingly.
  * the **fleet ladder** (``track_accuracy`` on a 3-client shared uplink):
    {10, 100} points — detections contend on the link, tracker-carried
    frames do not; the reference event loop is the honest baseline.

Results land in ``BENCH_tracking.json`` so CI can track the trajectory:

    PYTHONPATH=src python benchmarks/tracking_bench.py           # full ladders
    PYTHONPATH=src python benchmarks/tracking_bench.py --smoke   # 10-point grids
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.core.sim_multi_batch import MULTI_TOL  # noqa: E402
from repro.core.tracking import WorkloadSpec  # noqa: E402
from repro.session import (  # noqa: E402
    FleetSpec,
    ScenarioSpec,
    Session,
    SweepGrid,
    TraceSpec,
)

N_FRAMES = 120
POLICIES = (
    ("track_accuracy", {"decay": 0.2, "k_max": 6}),
    ("track_fixed", {"k": 3}),
)
SIZES = (10, 100, 1000)
FLEET_SIZES = (10, 100)
DEFAULT_OUT = "BENCH_tracking.json"

WORKLOAD = WorkloadSpec("track", decay=0.2, density=1.0)

PIECEWISE = TraceSpec(
    kind="piecewise", points=((0.0, 3.0), (0.3, 0.8), (0.9, 6.0)), rtt_ms=60.0
)


def make_grid(size: int) -> tuple[SweepGrid, TraceSpec]:
    """A tracking grid with exactly ``size`` points.

    The small sizes replay a piecewise trace on device (detector-interval
    choices flip as the bandwidth steps); the 1000-point grid sweeps the
    low-bandwidth regime where offload vs NPU detection really alternates.
    """
    if size == 10:
        return SweepGrid(
            deadline_ms=(150.0, 200.0, 250.0, 300.0, 350.0), rtt_ms=(50.0, 100.0)
        ), PIECEWISE
    if size == 100:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            rtt_ms=(50.0, 100.0),
        ), PIECEWISE
    if size == 1000:
        return SweepGrid(
            deadline_ms=tuple(150.0 + 20.0 * i for i in range(10)),
            fps=(10.0, 20.0, 30.0, 40.0, 50.0),
            bandwidth_mbps=(0.3, 0.6, 1.2, 2.5, 5.0),
            rtt_ms=(40.0, 70.0, 100.0, 130.0),
        ), TraceSpec(mbps=1.0)
    raise ValueError(f"no predefined grid of size {size}")


def _stats_equiv(a, b) -> bool:
    """The certified cross-backend contract: ints exact, floats in tol."""
    return (
        abs(a.accuracy_sum - b.accuracy_sum) <= MULTI_TOL
        and a.frames_processed == b.frames_processed
        and a.frames_missed_deadline == b.frames_missed_deadline
        and a.frames_offloaded == b.frames_offloaded
        and a.frames_total == b.frames_total
    )


def bench_cell(policy: str, params: dict, size: int, *, fleet: bool = False) -> dict:
    grid, trace = make_grid(size)
    session = Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params),
            n_frames=N_FRAMES,
            trace=trace,
            workload=WORKLOAD,
            fleet=FleetSpec(n_clients=3, capacity=2) if fleet else None,
            label=f"tracking_bench/{policy}/{size}",
        )
    )
    t0 = time.perf_counter()
    ref = session.run_sweep(grid, backend="reference")
    reference_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    session.run_sweep(grid, backend="batched")
    batched_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = session.run_sweep(grid, backend="batched")
    batched_warm_s = time.perf_counter() - t0
    assert bat.backend == "batched", bat.meta
    exact = all(
        len(pr.streams) == len(pb.streams)
        and all(_stats_equiv(sr, sb) for sr, sb in zip(pr.streams, pb.streams))
        for pr, pb in zip(ref.points, bat.points)
    )
    return {
        "policy": policy,
        "ladder": "fleet" if fleet else "track",
        "trace": trace.kind,
        "grid_points": len(grid),
        "n_frames": N_FRAMES,
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "exact_match": exact,
    }


def run(sizes=SIZES, fleet_sizes=FLEET_SIZES) -> dict:
    cells = [bench_cell(pol, params, size) for size in sizes for pol, params in POLICIES]
    cells += [
        bench_cell("track_accuracy", {"decay": 0.2, "k_max": 6}, size, fleet=True)
        for size in fleet_sizes
    ]
    return {"bench": "tracking", "n_frames": N_FRAMES, "cells": cells}


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladder is a
# manual / CI-artifact run — see main()).
def tracking_backend_smoke():
    rows = []
    for cell in run(sizes=(10,), fleet_sizes=(10,))["cells"]:
        name = f"tracking/{cell['ladder']}/{cell['policy']}/n{cell['grid_points']}"
        rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6, cell["speedup_warm"]))
        rows.append((f"{name}/exact", cell["reference_s"] * 1e6, float(cell["exact_match"])))
    return rows


ALL = [tracking_backend_smoke]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smallest grids only (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    if args.smoke:
        result = run(sizes=(10,), fleet_sizes=(10,))
    else:
        result = run()
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'ladder':>6} {'policy':>15} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} "
          f"{'warm (s)':>9} {'speedup':>8} {'exact':>6}")
    ok = True
    for c in result["cells"]:
        print(f"{c['ladder']:>6} {c['policy']:>15} {c['grid_points']:>7} "
              f"{c['reference_s']:>9.2f} {c['batched_cold_s']:>9.2f} "
              f"{c['batched_warm_s']:>9.2f} {c['speedup_warm']:>7.1f}x "
              f"{str(c['exact_match']):>6}")
        ok &= c["exact_match"]
        # the >= 5x acceptance bar applies to the single-stream 1000-point
        # cells (tracking rounds consume k frames each, so the reference
        # amortizes its Python planner over fewer rounds — see docstring).
        if c["ladder"] == "track" and c["grid_points"] >= 1000:
            ok &= c["speedup_warm"] >= 5.0
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
