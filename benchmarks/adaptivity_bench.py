"""Online-adaptation engine benchmark: reference loop vs batched backend.

The paper's §VI adaptivity story — observe the network, replan against the
EWMA belief, execute against the truth — used to run one Python round at a
time (``Session.run_online``).  This ladder drives the same grids through
``run_sweep(mode="online")`` on both backends at {10, 100, 1000} points over
the scenario-generator's mobility square wave (3.5 <-> 0.8 Mbps: the
estimator has to ride through every collapse), and asserts the certified
equivalence contract *in-bench* on every cell:

  * integer stats (processed / missed / offloaded / total / rounds) exact,
  * accuracy sums within ``AUDIT_TOL``,
  * the final believed bandwidth (``estimated_bps``) bit-for-bit — the
    batched EWMA chain is guarded against XLA fma/reassociation rewrites,
    and this is the gate that proves the guards hold.

The speedup is worthless if any of that fails, so ``main`` exits nonzero on
the first disagreement.  **Acceptance bar: >= 5x warm at the 1000-point
Max-Accuracy grid** (the reference pays ~17 Python DP planning rounds per
point; the batched engine runs every lane's whole observe->replan->execute
loop in one jitted while_loop).  The Max-Utility cells are gated on
equivalence only: as in the fleet bench's network ladder, that planner's
reference is a cheap numpy argmax while its batched round carries the
width-64 beam, so on a small-CPU host it roughly breaks even — the recorded
``speedup_warm`` is the honest number, tracked for parallel hardware where
the lanes are free.

Also kept: the oracle-vs-estimated accuracy rows (``adapt/...`` — the
original beyond-paper comparison of a policy that sees the true trace
against the deployable estimator-driven configuration).

Results land in ``BENCH_adaptivity.json`` so CI can track the trajectory:

    PYTHONPATH=src python benchmarks/adaptivity_bench.py           # full ladder
    PYTHONPATH=src python benchmarks/adaptivity_bench.py --smoke   # 10-point cells
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core import PolicySpec  # noqa: E402
from repro.core import sim_batch, sim_online_batch, sweep_shard  # noqa: E402
from repro.core.audit import AUDIT_TOL  # noqa: E402
from repro.core.compile_cache import CompileCounter  # noqa: E402
from repro.scenariogen import make_trace  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid  # noqa: E402

try:  # run.py imports this module as benchmarks.adaptivity_bench
    from benchmarks.sweep_bench import _RssSampler
except ImportError:  # direct `python benchmarks/adaptivity_bench.py`
    from sweep_bench import _RssSampler

N_FRAMES = 60  # 2 s of the square wave: spans a full collapse + recovery
POLICIES = (("max_accuracy", {"grid": 0.01}), ("max_utility", {"alpha": 200.0}))
SIZES = (10, 100, 1000)
DEFAULT_OUT = "BENCH_adaptivity.json"

# Walking in/out of coverage (scenariogen catalog defaults): 3.5 Mbps for
# one second out of every two, 0.8 Mbps otherwise.
_SQUARE = make_trace("mobility_square")

# One W shape bucket at 30 fps ([200, 233) ms), so every ladder size scales
# the lane count of the *same* compiled program — the rtt axis stretches.
_DEADLINES = (200.0, 208.0, 216.0, 224.0, 232.0)


def make_online_grid(size: int) -> SweepGrid:
    """deadline (5, one shape bucket) x rtt (size/5) online points."""
    n_rtt, rem = divmod(size, len(_DEADLINES))
    if rem or n_rtt < 1:
        raise ValueError(f"grid size must be a positive multiple of 5, got {size}")
    return SweepGrid(
        deadline_ms=_DEADLINES,
        rtt_ms=tuple(50.0 + 60.0 * i / n_rtt for i in range(n_rtt)),
    )


def _clear_compiled() -> None:
    """Fresh-process simulation: drop the online/oracle program factories and
    jax's trace/compile caches so the next run pays the real cold cost."""
    for mod in (sim_batch, sim_online_batch):
        for name in dir(mod):
            obj = getattr(mod, name)
            if callable(getattr(obj, "cache_clear", None)):
                obj.cache_clear()
    sweep_shard._sharded_jit.cache_clear()
    jax.clear_caches()


def _online_equiv(pr, pb) -> bool:
    """The certified contract (tests/test_online_batch.py pins the same)."""
    (sr,), (sb,) = pr.streams, pb.streams
    return (
        sr.frames_total == sb.frames_total
        and sr.frames_processed == sb.frames_processed
        and sr.frames_missed_deadline == sb.frames_missed_deadline
        and sr.frames_offloaded == sb.frames_offloaded
        and sr.schedule_calls == sb.schedule_calls
        and abs(sr.accuracy_sum - sb.accuracy_sum) <= AUDIT_TOL
        and pr.meta["rounds"] == pb.meta["rounds"]
        and pr.meta["estimated_bps"] == pb.meta["estimated_bps"]
    )


def bench_cell(policy: str, params: dict, size: int) -> dict:
    grid = make_online_grid(size)
    session = Session(
        ScenarioSpec(policy=PolicySpec(policy, params), n_frames=N_FRAMES,
                     trace=_SQUARE, label=f"adaptivity_bench/{policy}/{size}")
    )
    _clear_compiled()  # earlier cells must not pre-warm this one's cold pass
    with _RssSampler() as rss:
        t0 = time.perf_counter()
        ref = session.run_sweep(grid, backend="reference", mode="online")
        reference_s = time.perf_counter() - t0
        with CompileCounter() as cc:
            t0 = time.perf_counter()
            session.run_sweep(grid, backend="batched", mode="online")
            batched_cold_s = time.perf_counter() - t0
        with CompileCounter() as cw:
            t0 = time.perf_counter()
            bat = session.run_sweep(grid, backend="batched", mode="online")
            batched_warm_s = time.perf_counter() - t0
    assert bat.backend == "batched" and bat.meta["engine"] == "sim_online_batch", bat.meta
    equivalent = all(_online_equiv(pr, pb) for pr, pb in zip(ref.points, bat.points))
    return {
        "policy": policy,
        "params": params,
        "grid_points": len(grid),
        "n_frames": N_FRAMES,
        "trace": "mobility_square",
        "reference_s": reference_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": reference_s / batched_cold_s if batched_cold_s > 0 else 0.0,
        "speedup_warm": reference_s / batched_warm_s if batched_warm_s > 0 else 0.0,
        "compiles_cold": cc.compiles,
        "compiles_warm": cw.compiles,
        "peak_rss_mib": round(rss.peak_mib, 1),
        "equivalent": equivalent,
    }


def run(sizes=SIZES) -> dict:
    cells = [bench_cell(pol, params, size) for size in sizes for pol, params in POLICIES]
    return {"bench": "adaptivity", "n_frames": N_FRAMES, "cells": cells}


def oracle_vs_estimated(n_frames: int = N_FRAMES):
    """The original beyond-paper rows: a policy that sees the true trace
    (``run_sim``) against the deployable estimator-driven loop."""
    rows = []
    for name in ("max_accuracy", "local", "offload"):
        spec = ScenarioSpec(policy=PolicySpec(name), n_frames=n_frames,
                            trace=_SQUARE, label="adaptivity")
        st = Session(spec).run_sim().stats
        rows.append((f"adapt/oracleB/{name}",
                     st.schedule_time / max(st.schedule_calls, 1) * 1e6,
                     st.mean_accuracy))
    spec = ScenarioSpec(policy=PolicySpec("max_accuracy"), n_frames=n_frames,
                        trace=_SQUARE, label="adaptivity")
    st = Session(spec).run_online().stats
    rows.append(("adapt/estimatedB/max_accuracy",
                 st.schedule_time / max(st.schedule_calls, 1) * 1e6,
                 st.mean_accuracy))
    return rows


# run.py auto-discovery: smoke-sized rows only (the 1000-point ladder is the
# CI-artifact run — see main()).
def online_backend_smoke():
    rows = []
    for cell in run(sizes=(10,))["cells"]:
        name = f"online/{cell['policy']}/n{cell['grid_points']}"
        rows.append((f"{name}/speedup_warm", cell["batched_warm_s"] * 1e6,
                     cell["speedup_warm"]))
        rows.append((f"{name}/equivalent", cell["reference_s"] * 1e6,
                     float(cell["equivalent"])))
    return rows


ALL = [oracle_vs_estimated, online_backend_smoke]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="10-point cells only (CI smoke; still emits the JSON artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    result = run(sizes=(10,) if args.smoke else SIZES)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'policy':>14} {'points':>7} {'ref (s)':>9} {'cold (s)':>9} "
          f"{'warm (s)':>9} {'speedup':>8} {'rss MiB':>8} {'equiv':>6}")
    ok = True
    for c in result["cells"]:
        print(f"{c['policy']:>14} {c['grid_points']:>7} {c['reference_s']:>9.2f} "
              f"{c['batched_cold_s']:>9.2f} {c['batched_warm_s']:>9.2f} "
              f"{c['speedup_warm']:>7.1f}x {c['peak_rss_mib']:>8.0f} "
              f"{str(c['equivalent']):>6}")
        ok &= c["equivalent"]
        # the >= 5x acceptance bar applies to the 1000-point Max-Accuracy
        # cells (see the module docstring for the Max-Utility
        # honest-CPU-number rationale).
        if c["grid_points"] >= 1000 and c["policy"] == "max_accuracy":
            ok &= c["speedup_warm"] >= 5.0
    print(f"\nwrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
