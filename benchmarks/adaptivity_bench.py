"""Beyond-paper benchmark: time-VARYING bandwidth (the paper holds B constant
per run).  A WiFi-like square-wave trace alternates 3.5 <-> 0.8 Mbps; the
online controller must ride through the drops.

derived = mean accuracy.  Rows compare the oracle-B policies against the
same policy driven by the EWMA BandwidthEstimator (pessimism 0.9) fed only
by observed uploads — the deployable configuration.
"""
from __future__ import annotations

from repro.core import (
    PAPER_MODELS,
    PAPER_STREAM,
    BandwidthEstimator,
    NetworkState,
    Trace,
    make_policy,
    simulate,
)
from repro.core.simulator import Policy


def _square_trace(period_s: float = 2.0, hi: float = 3.5, lo: float = 0.8) -> Trace:
    return Trace(
        lambda t: (hi if (t // period_s) % 2 == 0 else lo) * 1e6, lambda t: 0.100
    )


def _estimated_policy(name: str) -> Policy:
    """Wrap a policy so it sees only the estimator's belief, updated from the
    uploads the previous rounds actually performed."""
    est = BandwidthEstimator(init_bps=2e6, beta=0.4, pessimism=0.9)
    inner = make_policy(name)

    def policy(models, stream, net, *, npu_free):
        plan = inner(models, stream, est.state(), npu_free=npu_free)
        # feedback: observe the true bandwidth through this round's uploads
        for d in plan.decisions:
            if d.is_processed() and d.resolution > 0 and d.where.value == "server":
                nbytes = stream.frame_bytes(d.resolution)
                est.observe_upload(nbytes, net.upload_time(nbytes))
        return plan

    return policy


def adaptivity():
    rows = []
    trace = _square_trace()
    n = 240
    for name in ("max_accuracy", "local", "offload"):
        st = simulate(make_policy(name), list(PAPER_MODELS), PAPER_STREAM, trace, n)
        rows.append((f"adapt/oracleB/{name}", st.schedule_time / max(st.schedule_calls, 1) * 1e6,
                     st.mean_accuracy))
    st = simulate(_estimated_policy("max_accuracy"), list(PAPER_MODELS), PAPER_STREAM, trace, n)
    rows.append(("adapt/estimatedB/max_accuracy",
                 st.schedule_time / max(st.schedule_calls, 1) * 1e6, st.mean_accuracy))
    return rows


ALL = [adaptivity]
