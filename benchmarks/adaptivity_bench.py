"""Beyond-paper benchmark: time-VARYING bandwidth (the paper holds B constant
per run).  A WiFi-like square-wave trace alternates 3.5 <-> 0.8 Mbps; the
online controller must ride through the drops.

derived = mean accuracy.  Rows compare the oracle-B policies (``run_sim``:
the policy sees the true trace) against the same policy driven through
``Session.run_online`` — the EWMA ``BandwidthEstimator`` fed only by observed
uploads and audited against the true trace, i.e. the deployable configuration.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.session import ScenarioSpec, Session, TraceSpec  # noqa: E402

N_FRAMES = 240
SMOKE_FRAMES = 60

# WiFi-like square wave, 2 s period: points repeat far past the trace length.
_SQUARE = TraceSpec(
    kind="piecewise",
    rtt_ms=100.0,
    points=tuple(
        (float(t), 3.5 if i % 2 == 0 else 0.8) for i, t in enumerate(range(0, 14, 2))
    ),
)


def _spec(policy: str, n_frames: int = N_FRAMES) -> ScenarioSpec:
    return ScenarioSpec(
        policy=PolicySpec(policy), n_frames=n_frames, trace=_SQUARE, label="adaptivity"
    )


def adaptivity(n_frames: int = N_FRAMES):
    rows = []
    for name in ("max_accuracy", "local", "offload"):
        st = Session(_spec(name, n_frames)).run_sim().stats
        rows.append((f"adapt/oracleB/{name}", st.schedule_time / max(st.schedule_calls, 1) * 1e6,
                     st.mean_accuracy))
    st = Session(_spec("max_accuracy", n_frames)).run_online().stats
    rows.append(("adapt/estimatedB/max_accuracy",
                 st.schedule_time / max(st.schedule_calls, 1) * 1e6, st.mean_accuracy))
    return rows


ALL = [adaptivity]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help=f"short trace ({SMOKE_FRAMES} frames; CI smoke)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in adaptivity(SMOKE_FRAMES if args.smoke else N_FRAMES):
        print(f"{name},{us:.2f},{derived:.6f}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
