"""Kernel micro-benchmarks.

On this CPU host the Pallas kernels run in interpret mode, so wall time is
NOT a TPU performance signal — ``derived`` therefore reports the semantic
quality metric (quantization relative error / max deviation vs oracle), and
the TPU-side performance is covered by the roofline benches (which read the
compiled dry-run artifacts).
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# --smoke drops the larger shape per kernel (interpret mode is slow on CPU).
_SMOKE = False


def kernel_npu_matmul():
    from repro.kernels.npu_matmul import ops, ref

    rows = []
    rng = np.random.default_rng(0)
    shapes = [(128, 512, 128)] if _SMOKE else [(128, 512, 128), (256, 2048, 256)]
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        out = ops.npu_matmul(x, w, interpret=True)
        t0 = time.perf_counter()
        out = ops.npu_matmul(x, w, interpret=True)
        us = (time.perf_counter() - t0) * 1e6
        exact = x @ w
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        rows.append((f"kernel/npu_matmul_{m}x{k}x{n}", us, rel))
    return rows


def kernel_flash_attention():
    from repro.kernels.flash_attention import kernel as fk
    from repro.kernels.flash_attention import ref as fr

    rows = []
    rng = np.random.default_rng(1)
    shapes = (
        [(1, 256, 8, 4, 64)] if _SMOKE else [(1, 256, 8, 4, 64), (1, 512, 8, 8, 128)]
    )
    for b, s, h, kh, hd in shapes:
        q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
        out = fk.flash_attention(q, k, v, causal=True, block_q=128, block_kv=128, interpret=True)
        t0 = time.perf_counter()
        out = fk.flash_attention(q, k, v, causal=True, block_q=128, block_kv=128, interpret=True)
        us = (time.perf_counter() - t0) * 1e6
        ref = fr.sdpa_ref(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append((f"kernel/flash_attn_b{b}s{s}h{h}", us, err))
    return rows


ALL = [kernel_npu_matmul, kernel_flash_attention]


def main(argv=None) -> int:
    global _SMOKE
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smallest shape per kernel (CI smoke)")
    args = ap.parse_args(argv)
    _SMOKE = args.smoke
    print("name,us_per_call,derived")
    for bench in ALL:
        for name, us, derived in bench():
            print(f"{name},{us:.2f},{derived:.6f}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
