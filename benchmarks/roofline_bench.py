"""Roofline summary bench: reads the dry-run artifacts and emits one row per
(arch x shape x mesh) cell — ``us_per_call`` = the roofline step-time lower
bound in microseconds, ``derived`` = the roofline fraction (compute term /
dominant term; 1.0 means compute-bound at the hardware peak)."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"
SERVING_ARTIFACT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"


def roofline_summary():
    rows = []
    if not ARTIFACTS.exists():
        return [("roofline/NO_ARTIFACTS_run_dryrun_first", 0.0, 0.0)]
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        r = rec["roofline"]
        name = f"roofline/{rec['mesh']}/{rec['arch']}/{rec['shape']}"
        rows.append((name, r["step_s_lower_bound"] * 1e6, r["roofline_fraction"]))
    return rows


def measured_serving_summary():
    """Measured (not modeled) rows: per-model latency of the executed int8
    Pallas path vs the fp32 edge path, from serving_bench's calibration
    artifact — ``us_per_call`` = measured t_npu, ``derived`` = the
    server/NPU latency ratio (>1 means the local path is faster here)."""
    if not SERVING_ARTIFACT.exists():
        return []  # optional companion rows; serving_bench emits the artifact
    rec = json.loads(SERVING_ARTIFACT.read_text())
    rows = []
    for m in rec.get("calibration", {}).get("models", []):
        name = f"roofline/serving_measured/{m['name']}"
        ratio = m["t_server_ms"] / m["t_npu_ms"] if m["t_npu_ms"] > 0 else 0.0
        rows.append((f"{name}/t_npu", m["t_npu_ms"] * 1e3, ratio))
    return rows


ALL = [roofline_summary, measured_serving_summary]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    # --smoke is the benchmark entry-point contract (benchmarks/run.py);
    # this bench only reads precomputed artifacts, so both modes are cheap.
    ap.add_argument("--smoke", action="store_true",
                    help="no-op here: the summary just reads dry-run artifacts")
    ap.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in roofline_summary():
        print(f"{name},{us:.2f},{derived:.6f}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
