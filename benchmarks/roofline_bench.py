"""Roofline summary bench: reads the dry-run artifacts and emits one row per
(arch x shape x mesh) cell — ``us_per_call`` = the roofline step-time lower
bound in microseconds, ``derived`` = the roofline fraction (compute term /
dominant term; 1.0 means compute-bound at the hardware peak)."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def roofline_summary():
    rows = []
    if not ARTIFACTS.exists():
        return [("roofline/NO_ARTIFACTS_run_dryrun_first", 0.0, 0.0)]
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        r = rec["roofline"]
        name = f"roofline/{rec['mesh']}/{rec['arch']}/{rec['shape']}"
        rows.append((name, r["step_s_lower_bound"] * 1e6, r["roofline_fraction"]))
    return rows


ALL = [roofline_summary]
