"""SNVA-style end-to-end serving benchmark (emits ``BENCH_serving.json``).

Headline: sustained frames/sec through the real serving stack —
``serving.calibrate`` trains + measures both deployment variants (the NPU
variant's matmuls execute in ``kernels/npu_matmul``'s int8 Pallas kernel),
then ``VideoServer`` + ``EdgeBatchServer`` drive the FastVA controller over a
synthetic video with the *measured* profiles.  One calibration is shared
across every policy run, so the bench isolates scheduling differences.

Also asserted here (exit nonzero on failure): the bandwidth estimator,
started with a deliberately wrong prior, converges to the true trace
bandwidth during ``VideoServer.run`` — the regression gate for the
estimator-echo bug (the serving loop used to feed the estimator its own
prediction, so a wrong prior persisted forever).

    PYTHONPATH=src python benchmarks/serving_bench.py --smoke --out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

DEFAULT_OUT = "BENCH_serving.json"
ARTIFACT = Path(__file__).resolve().parent.parent / DEFAULT_OUT

SMOKE_FRAMES = 48
FULL_FRAMES = 300
POLICIES = ("max_accuracy", "offload", "local")
TRUE_MBPS = 8.0
# Estimator convergence gate: start the belief 10x HIGH on a constant-rate
# trace; after the run the EWMA must sit within this relative band of
# true_bps * pessimism (what .state() reports).  The optimistic direction is
# the one the policy can recover from: an over-pessimistic prior makes the
# Offload baseline skip every frame (nothing to measure — the paper's
# sub-1.5 Mbps collapse), while an optimistic prior keeps frames flowing so
# every transfer is a measured sample.  Before the estimator-echo fix this
# gate fails: the loop fed the estimator its own prediction, so a wrong
# prior persisted forever.
WRONG_PRIOR_FACTOR = 10.0
CONVERGENCE_RTOL = 0.25


def _build_stack(cal, *, policy, stream, trace, init_bps):
    from repro.core import BandwidthEstimator, OnlineController, PolicySpec
    from repro.serving import BatchedEndpoint, EdgeBatchServer, VideoServer
    from repro.session import _model_from_json

    models = [_model_from_json(cm.payload) for cm in cal.models]
    batched = {
        j: BatchedEndpoint(
            f"{cm.payload['name']}-edge-batch",
            lambda x, p=cm.params, f=cm.forward: f(p, x),
            max_batch=16,
        )
        for j, cm in enumerate(cal.models)
    }
    controller = OnlineController(
        models=models,
        stream=stream,
        policy=PolicySpec.coerce(policy),
        estimator=BandwidthEstimator(init_bps=init_bps),
    )
    controller.estimator.observe_rtt(trace.at(0.0).rtt)
    server = VideoServer(
        controller=controller,
        npu_endpoints={j: cm.npu_endpoint for j, cm in enumerate(cal.models)},
        stream=stream,
        trace=trace,
        edge_server=EdgeBatchServer(batched),
    )
    return server, controller, batched


def run_bench(*, smoke: bool = False, seed: int = 0) -> dict:
    import numpy as np

    from repro.core import StreamSpec
    from repro.serving import CalibrationConfig, calibrate, make_synthetic_video
    from repro.session import TraceSpec

    n_frames = SMOKE_FRAMES if smoke else FULL_FRAMES
    cfg = CalibrationConfig.smoke(seed=seed) if smoke else CalibrationConfig(seed=seed)

    t0 = time.perf_counter()
    cal = calibrate(cfg)
    calibration_s = time.perf_counter() - t0

    stream = StreamSpec()
    trace = TraceSpec(mbps=TRUE_MBPS).build()
    true_bps = trace.at(0.0).bandwidth_bps
    frames, labels = make_synthetic_video(n_frames, n_classes=cfg.n_classes, res=cfg.res, seed=seed)

    runs = []
    for policy in POLICIES:
        server, controller, batched = _build_stack(
            cal, policy=policy, stream=stream, trace=trace, init_bps=true_bps
        )
        for ep in batched.values():
            ep.warmup(frames[0])
        summary = server.run(frames, labels)
        runs.append(
            {
                "policy": policy,
                "frames": summary["frames"],
                "fps_sustained": summary["fps_sustained"],
                "wall_s": summary["wall_s"],
                "accuracy": summary["accuracy"],
                "deadline_met_frac": summary["deadline_met_frac"],
                "npu_frames": summary["npu_frames"],
                "edge_frames": summary["edge_frames"],
                "mean_latency_s": summary["mean_latency_s"],
                "batch": summary.get("batch"),
                "scheduler_rounds": controller.rounds,
            }
        )

    # Estimator convergence regression (the echo-bug gate): "offload" sends
    # every frame, so the estimator sees one measured transfer per frame.
    server, controller, batched = _build_stack(
        cal,
        policy="offload",
        stream=stream,
        trace=trace,
        init_bps=true_bps * WRONG_PRIOR_FACTOR,
    )
    for ep in batched.values():
        ep.warmup(frames[0])
    server.run(frames, labels)
    est = controller.estimator
    target = true_bps * est.pessimism
    rel_err = abs(est.state().bandwidth_bps - target) / target
    converged = bool(rel_err <= CONVERGENCE_RTOL) and est.samples >= 8
    convergence = {
        "init_bps": true_bps * WRONG_PRIOR_FACTOR,
        "true_bps": true_bps,
        "pessimism": est.pessimism,
        "final_estimate_bps": est.state().bandwidth_bps,
        "upload_samples": est.samples,
        "rel_err": rel_err,
        "rtol": CONVERGENCE_RTOL,
        "converged": converged,
    }

    headline = next(r for r in runs if r["policy"] == "max_accuracy")
    return {
        "bench": "serving",
        "smoke": smoke,
        "n_frames": n_frames,
        "true_mbps": TRUE_MBPS,
        "calibration_s": calibration_s,
        "calibration": cal.artifact,
        "runs": runs,
        "fps_sustained": headline["fps_sustained"],  # headline: max_accuracy
        "convergence": convergence,
        "ok": converged and all(np.isfinite(r["fps_sustained"]) for r in runs),
    }


# ---------------------------------------------------------------------------
# run.py auto-discovery: summarize the artifact (cheap; the measured run is
# the --smoke/full entry point below, like the dry-run artifacts feeding
# roofline_bench).
# ---------------------------------------------------------------------------

def serving_summary():
    if not ARTIFACT.exists():
        return [("serving/NO_ARTIFACT_run_serving_bench_first", 0.0, 0.0)]
    rec = json.loads(ARTIFACT.read_text())
    rows = []
    for r in rec.get("runs", []):
        base = f"serving/{r['policy']}"
        us = (r["wall_s"] / max(r["frames"], 1)) * 1e6
        rows.append((f"{base}/fps_sustained", us, r["fps_sustained"]))
        rows.append((f"{base}/accuracy", 0.0, r["accuracy"]))
        rows.append((f"{base}/deadline_met", 0.0, r["deadline_met_frac"]))
    conv = rec.get("convergence", {})
    if conv:
        rows.append(("serving/estimator_converged", 0.0, float(conv.get("converged", False))))
    for m in rec.get("calibration", {}).get("models", []):
        rows.append((f"serving/calibrated/{m['name']}/t_npu_ms", m["t_npu_ms"] * 1e3,
                     m["provenance"]["fp32_int8_agreement"]))
    return rows


ALL = [serving_summary]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized calibration budgets + short stream")
    ap.add_argument("--out", default=DEFAULT_OUT, help=f"output path (default {DEFAULT_OUT})")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    result = run_bench(smoke=args.smoke, seed=args.seed)
    with open(args.out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")

    print(f"{'policy':>14} {'frames':>7} {'fps':>9} {'acc':>6} {'met':>6} "
          f"{'npu':>5} {'edge':>5} {'mean batch':>10}")
    for r in result["runs"]:
        b = r["batch"] or {}
        print(f"{r['policy']:>14} {r['frames']:>7} {r['fps_sustained']:>9.1f} "
              f"{r['accuracy']:>6.3f} {r['deadline_met_frac']:>6.2f} "
              f"{r['npu_frames']:>5} {r['edge_frames']:>5} {b.get('mean_batch', 0.0):>10.2f}")
    c = result["convergence"]
    print(f"\nestimator: init {c['init_bps']/1e6:.2f} Mbps -> "
          f"{c['final_estimate_bps']/1e6:.2f} Mbps (target {c['true_bps']*c['pessimism']/1e6:.2f}, "
          f"rel_err {c['rel_err']:.3f}, {c['upload_samples']} samples) "
          f"converged={c['converged']}")
    print(f"calibration took {result['calibration_s']:.1f}s; wrote {args.out}")
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
