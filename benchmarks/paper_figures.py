"""Benchmarks reproducing each FastVA table/figure with the paper's own
constants (Table II profiles, 200 ms deadline, 5 resolutions, 100 ms delay).

Each function returns a list of (name, us_per_call, derived) rows where
``derived`` is the figure's y-value and ``us_per_call`` is the mean wall time
of one policy round (the schedule-decision cost the paper reports < 1 ms).
"""
from __future__ import annotations

import time

from repro.core import (
    PAPER_MODELS,
    PAPER_STREAM,
    StreamSpec,
    Trace,
    brute_force,
    make_policy,
    network_mbps,
    simulate,
)

N_FRAMES = 120
POLICIES = ("max_accuracy", "local", "offload", "deepdecision")


def _row(name: str, stats, derived: float):
    us = stats.schedule_time / max(stats.schedule_calls, 1) * 1e6 if stats else 0.0
    return (name, us, derived)


def table2_profiles():
    """Table II: per-model processing times and accuracy (paper constants
    drive all scheduling benches; derived = top-1 accuracy)."""
    rows = []
    for m in PAPER_MODELS:
        rows.append((f"table2/{m.name}/npu", m.t_npu * 1e6, m.accuracy(224, where="npu")))
        rows.append((f"table2/{m.name}/server", m.t_server * 1e6, m.accuracy(224, where="server")))
    return rows


def fig4_accuracy_resolution():
    rows = []
    for m in PAPER_MODELS:
        for r in PAPER_STREAM.resolutions:
            rows.append((f"fig4/{m.name}/r{r}", 0.0, m.accuracy(r, where="server")))
    return rows


def fig5_bandwidth_accuracy():
    rows = []
    for mbps in (0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5):
        for pol in POLICIES:
            st = simulate(make_policy(pol), list(PAPER_MODELS), PAPER_STREAM,
                          Trace.constant(mbps), N_FRAMES)
            rows.append(_row(f"fig5/B{mbps}/{pol}", st, st.mean_accuracy))
    return rows


def fig6_framerate_accuracy():
    rows = []
    for fps in (10, 20, 30, 40, 50):
        stream = StreamSpec(fps=fps)
        for pol in POLICIES:
            st = simulate(make_policy(pol), list(PAPER_MODELS), stream,
                          Trace.constant(3.0), N_FRAMES)
            rows.append(_row(f"fig6/fps{fps}/{pol}", st, st.mean_accuracy))
    return rows


def fig7_optimal_gap():
    """Fig 7b: Optimal minus Max-Accuracy (derived = the gap, ~0)."""
    rows = []
    for mbps in (1.0, 2.0, 3.0):
        for fps in (20, 30, 40):
            stream = StreamSpec(fps=fps)
            t0 = time.perf_counter()
            opt = brute_force.optimal_accuracy(
                list(PAPER_MODELS), stream, network_mbps(mbps), 40, grid=2e-3
            )
            dt = (time.perf_counter() - t0) * 1e6
            st = simulate(make_policy("max_accuracy"), list(PAPER_MODELS), stream,
                          Trace.constant(mbps), 40)
            rows.append((f"fig7/B{mbps}_fps{fps}/gap", dt, max(opt - st.mean_accuracy, 0.0)))
    return rows


def fig8_delay_accuracy():
    rows = []
    for rtt_ms in (50, 100, 150, 200):
        for fps in (30, 50):
            stream = StreamSpec(fps=fps)
            for pol in POLICIES:
                st = simulate(make_policy(pol), list(PAPER_MODELS), stream,
                              Trace.constant(3.0, rtt_ms=rtt_ms), N_FRAMES)
                rows.append(_row(f"fig8/d{rtt_ms}_fps{fps}/{pol}", st, st.mean_accuracy))
    return rows


def fig9_bandwidth_utility():
    rows = []
    for alpha in (200.0, 50.0):
        for mbps in (0.5, 1.5, 2.5, 3.5):
            for pol in ("max_utility", "local", "offload", "deepdecision"):
                st = simulate(make_policy(pol, alpha=alpha), list(PAPER_MODELS),
                              PAPER_STREAM, Trace.constant(mbps), N_FRAMES)
                rows.append(_row(f"fig9/a{alpha:.0f}_B{mbps}/{pol}", st, st.utility(alpha)))
    return rows


def fig10_framerate_utility():
    rows = []
    for alpha in (200.0, 50.0):
        for fps in (10, 30, 50):
            stream = StreamSpec(fps=fps)
            for pol in ("max_utility", "local", "offload"):
                st = simulate(make_policy(pol, alpha=alpha), list(PAPER_MODELS),
                              stream, Trace.constant(2.5), N_FRAMES)
                rows.append(_row(f"fig10/a{alpha:.0f}_fps{fps}/{pol}", st, st.utility(alpha)))
    return rows


def fig11_delay_utility():
    rows = []
    for alpha in (200.0, 50.0):
        for rtt_ms in (50, 100, 150):
            for pol in ("max_utility", "local", "offload"):
                st = simulate(make_policy(pol, alpha=alpha), list(PAPER_MODELS),
                              PAPER_STREAM, Trace.constant(2.0, rtt_ms=rtt_ms), N_FRAMES)
                rows.append(_row(f"fig11/a{alpha:.0f}_d{rtt_ms}/{pol}", st, st.utility(alpha)))
    return rows


def sched_latency():
    """Paper §VI.A: 'running time ... less than 1 ms'.  Derived = ms/round."""
    from repro.core.jax_sched import local_accuracy_dp_jax, local_utility_dp_jax
    from repro.core.max_accuracy import plan_round as ma_round
    from repro.core.max_utility import plan_round as mu_round

    models = list(PAPER_MODELS)
    net = network_mbps(2.5)
    rows = []
    for name, fn in [
        ("sched/max_accuracy_py", lambda: ma_round(models, PAPER_STREAM, net)),
        ("sched/max_utility_py", lambda: mu_round(models, PAPER_STREAM, net, alpha=200.0)),
        ("sched/accuracy_dp_jax", lambda: local_accuracy_dp_jax(
            models, n_frames=6, gamma=1 / 30, deadline=0.2, npu_free=0.0, first_arrival=1 / 30)),
        ("sched/utility_dp_jax", lambda: local_utility_dp_jax(
            models, n_frames=6, gamma=1 / 30, deadline=0.2, alpha=200.0, npu_free=0.0,
            first_arrival=1 / 30, window=0.2)),
    ]:
        fn()  # warm
        t0 = time.perf_counter()
        n = 30
        for _ in range(n):
            fn()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((name, us, us / 1e3))  # derived = ms
    return rows


ALL = [
    table2_profiles,
    fig4_accuracy_resolution,
    fig5_bandwidth_accuracy,
    fig6_framerate_accuracy,
    fig7_optimal_gap,
    fig8_delay_accuracy,
    fig9_bandwidth_utility,
    fig10_framerate_utility,
    fig11_delay_utility,
    sched_latency,
]
