"""Benchmarks reproducing each FastVA table/figure with the paper's own
constants (Table II profiles, 200 ms deadline, 5 resolutions, 100 ms delay).

Each function returns a list of (name, us_per_call, derived) rows where
``derived`` is the figure's y-value and ``us_per_call`` is the mean wall time
of one policy round (the schedule-decision cost the paper reports < 1 ms).

Every figure sweep is one ``Session.run_sweep`` over a declarative
``SweepGrid`` (bandwidth/fps/rtt/policy-param axes), so adding a policy —
including the ``brute_force`` oracle and the jitted ``jax_*`` DPs, which the
sweep engine routes through the vectorized ``sim_batch`` backend — is just
another name in a tuple.  One-off cells (fig 7's oracle gap) still use a
single-point ``ScenarioSpec``.
"""
from __future__ import annotations

import time

from repro.core import PAPER_MODELS, PAPER_STREAM, PolicySpec, StreamSpec, brute_force, network_mbps
from repro.session import ScenarioSpec, Session, SweepGrid, TraceSpec

N_FRAMES = 120
POLICIES = ("max_accuracy", "local", "offload", "deepdecision")


def _sim(
    policy: str,
    mbps: float,
    *,
    params: dict | None = None,
    fps: float | None = None,
    rtt_ms: float = 100.0,
    n_frames: int = N_FRAMES,
):
    """One front-door cell: build the spec, run the audited simulator."""
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params or {}),
        n_frames=n_frames,
        stream=PAPER_STREAM if fps is None else StreamSpec(fps=fps),
        trace=TraceSpec(mbps=mbps, rtt_ms=rtt_ms),
    )
    return Session(spec).run_sim().stats


def _sweep(
    policy: str,
    *,
    params: dict | None = None,
    params_axes: dict | None = None,
    n_frames: int = N_FRAMES,
    **axes,
):
    """One figure sweep: the base paper scenario crossed with ``axes``
    (scenario axes as kwargs, policy-param axes via ``params_axes``)."""
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params or {}),
        n_frames=n_frames,
        trace=TraceSpec(mbps=2.5),
        label=f"paper_figures/{policy}",
    )
    return Session(spec).run_sweep(SweepGrid(params=params_axes or {}, **axes))


def _row(name: str, stats, derived: float):
    us = stats.schedule_time / max(stats.schedule_calls, 1) * 1e6 if stats else 0.0
    return (name, us, derived)


def table2_profiles():
    """Table II: per-model processing times and accuracy (paper constants
    drive all scheduling benches; derived = top-1 accuracy)."""
    rows = []
    for m in PAPER_MODELS:
        rows.append((f"table2/{m.name}/npu", m.t_npu * 1e6, m.accuracy(224, where="npu")))
        rows.append((f"table2/{m.name}/server", m.t_server * 1e6, m.accuracy(224, where="server")))
    return rows


def fig4_accuracy_resolution():
    rows = []
    for m in PAPER_MODELS:
        for r in PAPER_STREAM.resolutions:
            rows.append((f"fig4/{m.name}/r{r}", 0.0, m.accuracy(r, where="server")))
    return rows


def fig5_bandwidth_accuracy():
    rows = []
    for pol in POLICIES:
        rep = _sweep(pol, bandwidth_mbps=(0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5))
        for pt in rep:
            st = pt.stats
            rows.append(_row(f"fig5/B{pt.overrides['bandwidth_mbps']}/{pol}", st, st.mean_accuracy))
    return rows


def fig6_framerate_accuracy():
    rows = []
    for pol in POLICIES:
        rep = _sweep(pol, bandwidth_mbps=(3.0,), fps=(10, 20, 30, 40, 50))
        for pt in rep:
            st = pt.stats
            rows.append(_row(f"fig6/fps{pt.overrides['fps']}/{pol}", st, st.mean_accuracy))
    return rows


def fig7_optimal_gap():
    """Fig 7b: Optimal minus Max-Accuracy (derived = the gap, ~0)."""
    rows = []
    for mbps in (1.0, 2.0, 3.0):
        for fps in (20, 30, 40):
            t0 = time.perf_counter()
            opt = brute_force.optimal_accuracy(
                list(PAPER_MODELS), StreamSpec(fps=fps), network_mbps(mbps), 40, grid=2e-3
            )
            dt = (time.perf_counter() - t0) * 1e6
            st = _sim("max_accuracy", mbps, fps=fps, n_frames=40)
            rows.append((f"fig7/B{mbps}_fps{fps}/gap", dt, max(opt - st.mean_accuracy, 0.0)))
    return rows


def fig8_delay_accuracy():
    rows = []
    for pol in POLICIES:
        rep = _sweep(pol, bandwidth_mbps=(3.0,), fps=(30, 50), rtt_ms=(50, 100, 150, 200))
        for pt in rep:
            o, st = pt.overrides, pt.stats
            rows.append(_row(f"fig8/d{o['rtt_ms']}_fps{o['fps']}/{pol}", st, st.mean_accuracy))
    return rows


def fig9_bandwidth_utility():
    rows = []
    for pol in ("max_utility", "local", "offload", "deepdecision"):
        rep = _sweep(pol, params={"alpha": 200.0},
                     bandwidth_mbps=(0.5, 1.5, 2.5, 3.5), params_axes={"alpha": (200.0, 50.0)})
        for pt in rep:
            o, st = pt.overrides, pt.stats
            rows.append(_row(f"fig9/a{o['alpha']:.0f}_B{o['bandwidth_mbps']}/{pol}",
                             st, st.utility(o["alpha"])))
    return rows


def fig10_framerate_utility():
    rows = []
    for pol in ("max_utility", "local", "offload"):
        rep = _sweep(pol, params={"alpha": 200.0},
                     bandwidth_mbps=(2.5,), fps=(10, 30, 50), params_axes={"alpha": (200.0, 50.0)})
        for pt in rep:
            o, st = pt.overrides, pt.stats
            rows.append(_row(f"fig10/a{o['alpha']:.0f}_fps{o['fps']}/{pol}",
                             st, st.utility(o["alpha"])))
    return rows


def fig11_delay_utility():
    rows = []
    for pol in ("max_utility", "local", "offload"):
        rep = _sweep(pol, params={"alpha": 200.0},
                     bandwidth_mbps=(2.0,), rtt_ms=(50, 100, 150), params_axes={"alpha": (200.0, 50.0)})
        for pt in rep:
            o, st = pt.overrides, pt.stats
            rows.append(_row(f"fig11/a{o['alpha']:.0f}_d{o['rtt_ms']}/{pol}",
                             st, st.utility(o["alpha"])))
    return rows


def oracle_gap_sweep():
    """Beyond-paper: the oracle and the jitted DPs as *policies*, swept
    uniformly with the heuristics through the sweep front door (the jax_*
    policies route through the batched sim_batch backend here).
    derived = mean accuracy (or utility); the oracle upper-bounds each cell
    up to its time grid (default 5 ms — tighten ``grid`` to close the gap)."""
    rows = []
    for pol in ("max_accuracy", "brute_force", "jax_accuracy", "local"):
        rep = _sweep(pol, n_frames=60, bandwidth_mbps=(1.0, 2.5))
        for pt in rep:
            st = pt.stats
            rows.append(_row(f"oracle/B{pt.overrides['bandwidth_mbps']}/{pol}",
                             st, st.mean_accuracy))
    alpha = 200.0
    for pol in ("max_utility", "brute_force", "jax_utility"):
        rep = _sweep(pol, params={"alpha": alpha}, n_frames=60, bandwidth_mbps=(2.5,))
        rows.append(_row(f"oracle/a{alpha:.0f}_B2.5/{pol}", rep.points[0].stats,
                         rep.points[0].stats.utility(alpha)))
    return rows


def sched_latency():
    """Paper §VI.A: 'running time ... less than 1 ms'.  Derived = ms/round."""
    from repro.core.jax_sched import local_accuracy_dp_jax, local_utility_dp_jax
    from repro.core.max_accuracy import plan_round as ma_round
    from repro.core.max_utility import plan_round as mu_round

    models = list(PAPER_MODELS)
    net = network_mbps(2.5)
    rows = []
    for name, fn in [
        ("sched/max_accuracy_py", lambda: ma_round(models, PAPER_STREAM, net)),
        ("sched/max_utility_py", lambda: mu_round(models, PAPER_STREAM, net, alpha=200.0)),
        ("sched/accuracy_dp_jax", lambda: local_accuracy_dp_jax(
            models, n_frames=6, gamma=1 / 30, deadline=0.2, npu_free=0.0, first_arrival=1 / 30)),
        ("sched/utility_dp_jax", lambda: local_utility_dp_jax(
            models, n_frames=6, gamma=1 / 30, deadline=0.2, alpha=200.0, npu_free=0.0,
            first_arrival=1 / 30, window=0.2)),
    ]:
        fn()  # warm
        t0 = time.perf_counter()
        n = 30
        for _ in range(n):
            fn()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((name, us, us / 1e3))  # derived = ms
    return rows


ALL = [
    table2_profiles,
    fig4_accuracy_resolution,
    fig5_bandwidth_accuracy,
    fig6_framerate_accuracy,
    fig7_optimal_gap,
    fig8_delay_accuracy,
    fig9_bandwidth_utility,
    fig10_framerate_utility,
    fig11_delay_utility,
    oracle_gap_sweep,
    sched_latency,
]
