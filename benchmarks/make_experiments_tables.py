"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts.

    PYTHONPATH=src python -m benchmarks.make_experiments_tables [artifacts/dryrun]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}us"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def main() -> None:
    art = Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    recs = [json.loads(f.read_text()) for f in sorted(art.glob("*.json"))]
    if not recs:
        print("no artifacts found — run the dryrun first")
        return

    print("### §Dry-run — all cells x both meshes (compile + fit proof)\n")
    print("| arch | shape | mesh | compile s | mem/dev GB (CPU-HLO) | mem/dev GB (flash) | HLO flops (jaxpr) | collective B/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        m = r["memory"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {m['peak_per_device_gb']} | {m.get('flash_peak_per_device_gb', '-')} "
            f"| {r['flops_jaxpr']:.3e} | {r['collectives_flash']['total_bytes']:.2e} |"
        )

    print("\n### §Roofline — single-pod (16x16) baselines, flash-kernel system\n")
    print("| arch | shape | compute | memory | collective | bottleneck | roofline frac | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['bottleneck'].replace('_s','')} "
            f"| {rf['roofline_fraction']:.3f} | {r['useful_compute_ratio']:.2f} |"
        )

    print("\n### no-kernel (pure-XLA attention) baseline fractions, 16x16\n")
    print("| arch | shape | bottleneck | frac (no kernel) | frac (flash) |")
    print("|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        rn, rf = r["roofline_no_flash_kernel"], r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {rn['bottleneck'].replace('_s','')} "
            f"| {rn['roofline_fraction']:.3f} | {rf['roofline_fraction']:.3f} |"
        )


if __name__ == "__main__":
    main()
