"""Property tests for the vectorized multi-stream fleet backend.

Random inputs rather than the curated golden lattices:

  * for arbitrary model profiles (including server-only models and models
    with empty NPU accuracy tables), fleet shapes (size, allocation,
    capacity, backlog limit, weights, priorities), and constant|piecewise
    shared-link traces, every fleet planner through
    ``sim_multi_batch.simulate_multi_batch`` reproduces the reference
    ``simulate_multi`` event loop — integer stats exactly, accuracy and
    server busy time within ``MULTI_TOL``, scheduler grants/denials exact;
  * the fluid water-filling kernel never reserves more than the link
    offers: rates are non-negative, per-transfer caps are respected, and
    the total reservation never exceeds B.

Fleet/stream *shape* values are drawn from small sets (allocation, N,
capacity, frame counts, fps, deadlines are static to the jit cache); model
latencies, bandwidths, rtt, weights, and alpha stay continuous — they are
traced, not compiled.
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from jax.experimental import enable_x64  # noqa: E402

from repro.core import (  # noqa: E402
    EdgeServerScheduler,
    PolicySpec,
    Trace,
    make_fleet,
    simulate_multi,
)
from repro.core.profiles import StreamSpec, profile_ms  # noqa: E402
from repro.core.sim_multi_batch import (  # noqa: E402
    EQUIV_INT_FIELDS,
    MULTI_TOL,
    FleetScenario,
    _fleet_physics,
    multi_batched_policies,
    simulate_multi_batch,
)

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()


@st.composite
def model_sets(draw):
    n = draw(st.integers(1, 3))
    models = []
    for i in range(n):
        runs_local = draw(st.booleans()) if n > 1 else True
        has_acc = draw(st.booleans())
        models.append(
            profile_ms(
                f"m{i}",
                t_npu_ms=draw(st.floats(5, 250)) if runs_local else float("inf"),
                t_server_ms=draw(st.floats(5, 120)),
                acc_server={45: 0.2, 224: draw(st.floats(0.3, 0.95))},
                acc_npu={224: draw(st.floats(0.1, 0.9))} if has_acc else {},
            )
        )
    return models


@st.composite
def traces(draw):
    rtt_ms = draw(st.floats(20.0, 150.0))
    if draw(st.booleans()):
        return ("constant", draw(st.floats(0.2, 12.0)), rtt_ms, ())
    points = tuple(
        (t, draw(st.floats(0.2, 12.0)))
        for t in sorted(draw(st.sets(st.sampled_from((0.0, 0.1, 0.25, 0.4, 0.8)),
                                     min_size=1, max_size=3)))
    )
    return ("piecewise", None, rtt_ms, points)


def _build_trace(kind, mbps, rtt_ms, points) -> Trace:
    if kind == "constant":
        return Trace.constant(mbps, rtt_ms=rtt_ms)
    return Trace.piecewise(list(points), rtt_ms=rtt_ms)


def _segments(kind, mbps, rtt_ms, points):
    if kind == "constant":
        return ((0.0, mbps * 1e6),)
    return tuple((t, v * 1e6) for t, v in sorted(points))


@st.composite
def fleet_cases(draw):
    models = draw(model_sets())
    policy = draw(st.sampled_from(sorted(multi_batched_policies())))
    if policy in ("max_utility", "jax_utility"):
        params = {"alpha": draw(st.floats(1.0, 400.0))}
    elif policy in ("max_accuracy", "jax_accuracy"):
        params = {"grid": draw(st.sampled_from((1e-3, 2e-3)))}
    else:
        params = {"alpha": draw(st.floats(1.0, 400.0))} if draw(st.booleans()) else {}
    n = draw(st.integers(1, 3))
    fleet = dict(
        n_clients=n,
        allocation=draw(st.sampled_from(("weighted_fair", "priority", "fifo"))),
        capacity=draw(st.sampled_from((0, 1, 2))),
        backlog_limit=draw(st.sampled_from((0.0, 0.05))),
        weights=tuple(draw(st.floats(0.25, 4.0)) for _ in range(n)),
        priorities=tuple(draw(st.integers(0, 2)) for _ in range(n)),
    )
    stream = StreamSpec(
        fps=draw(st.sampled_from((10.0, 30.0))),
        deadline=draw(st.sampled_from((100.0, 200.0, 350.0))) / 1e3,
    )
    return models, policy, params, stream, draw(st.sampled_from((4, 8, 12))), fleet, draw(traces())


@SETTINGS
@given(fleet_cases())
def test_fleet_batched_stats_equal_simulate_multi(case):
    models, policy, params, stream, n_frames, fleet_kw, tr = case
    spec = PolicySpec(policy, params)
    clients = make_fleet(
        fleet_kw["n_clients"],
        stream=stream,
        models=models,
        policy=spec,
        weights=fleet_kw["weights"],
        priorities=fleet_kw["priorities"],
    )
    sched = EdgeServerScheduler(
        clients,
        policy=fleet_kw["allocation"],
        capacity=fleet_kw["capacity"],
        backlog_limit=fleet_kw["backlog_limit"],
    )
    ms_ref = simulate_multi(sched, _build_trace(*tr), n_frames)
    (ms_bat, meta), = simulate_multi_batch(
        policy,
        models,
        [
            FleetScenario(
                stream=stream,
                n_frames=n_frames,
                bw_segments=_segments(*tr),
                rtt=tr[2] / 1e3,
                params=spec.resolved,
                **fleet_kw,
            )
        ],
    )
    for sr, sb in zip(ms_ref.per_client, ms_bat.per_client):
        for f in EQUIV_INT_FIELDS:
            assert getattr(sr, f) == getattr(sb, f), (policy, fleet_kw, tr, f)
        assert abs(sr.accuracy_sum - sb.accuracy_sum) <= MULTI_TOL, (policy, fleet_kw, tr)
    assert ms_bat.server_jobs == ms_ref.server_jobs
    assert abs(ms_bat.server_busy_s - ms_ref.server_busy_s) <= MULTI_TOL
    assert meta == {"grants": sched.audit.grants, "denials": sched.audit.denials}


# ---------------------------------------------------------------------------
# Water-filling reservation invariant: the fluid link never over-commits.
# ---------------------------------------------------------------------------


@SETTINGS
@given(
    n=st.integers(1, 6),
    data=st.data(),
    bandwidth=st.floats(0.0, 2e7),
)
def test_waterfill_reservation_never_exceeds_link(n, data, bandwidth):
    weights = np.array(
        data.draw(st.lists(st.floats(0.0, 5.0), min_size=n, max_size=n)), np.float64
    )
    active = np.array(
        data.draw(st.lists(st.booleans(), min_size=n, max_size=n)), bool
    )
    caps = np.array(
        data.draw(
            st.lists(st.floats(1e3, 1e8) | st.just(float("inf")), min_size=n, max_size=n)
        ),
        np.float64,
    )
    with enable_x64():
        phys = _fleet_physics(
            "weighted_fair", n, 2, 4,
            bw_t=jnp.zeros((1,)), bw_v=jnp.full((1,), bandwidth),
            rtt=jnp.float64(0.05), L=jnp.float64(0.0),
            w_fluid=jnp.maximum(jnp.asarray(weights), 1e-9),
            w_eff=jnp.asarray(weights), tot_w=jnp.float64(max(weights.sum(), 1.0)),
            prio=jnp.zeros((n,), jnp.int32),
        )
        rates = np.asarray(phys.waterfill(jnp.float64(bandwidth), jnp.asarray(active), jnp.asarray(caps)))
    tol = 1e-9 * max(bandwidth, 1.0)
    assert (rates >= 0.0).all()
    assert (rates[~active] == 0.0).all()
    assert (rates <= caps + tol).all()
    assert rates.sum() <= bandwidth + tol
