"""The sweep scale-out layer: chunked/streamed ``run_sweep`` and the mesh
sharding substrate (docs/simulation.md "Scaling sweeps").

Contracts under test:

* **Chunk invariance** — ``run_sweep(chunk_size=k)`` is bit-identical to
  the unchunked path on every deterministic stats field, for every batched
  policy family (local jitted DPs, network-aware planners, fleet engines,
  detect+track workloads).  Chunking only re-partitions ``_stitch``'s
  shape groups, and padding is inert, so nothing may change but wall time.
* **Streaming** — ``keep_points=False`` folds every chunk into an
  incremental :class:`SweepSummary`, equal to the fold over the kept
  points, and the summary-carrying report JSON round-trips.
* **Sharding fallback** — on a single device (this suite) the mesh path
  is the plain jitted program; ``REPRO_SWEEP_SHARD=0`` must be a no-op.
  Multi-device bit-identity runs in a subprocess with forced host devices
  (XLA_FLAGS must precede the jax import).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.core import PolicySpec
from repro.session import (
    FleetSpec,
    ScenarioSpec,
    Session,
    SweepGrid,
    SweepReport,
    SweepSummary,
    TraceSpec,
)

# schedule_time is measured wall clock (apportioned per group) — everything
# else run_sweep reports is deterministic and must survive re-chunking.
DET_FIELDS = (
    "frames_total",
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "accuracy_sum",
    "elapsed",
    "schedule_calls",
    "npu_busy_s",
)

PIECEWISE = TraceSpec(
    kind="piecewise", points=((0.0, 3.0), (0.4, 0.9), (1.1, 5.0)), rtt_ms=60.0
)


def _assert_det_equal(a: SweepReport, b: SweepReport) -> None:
    assert len(a.points) == len(b.points)
    for pa, pb in zip(a.points, b.points):
        assert pa.overrides == pb.overrides
        assert len(pa.streams) == len(pb.streams)
        for sa, sb in zip(pa.streams, pb.streams):
            for f in DET_FIELDS:
                assert getattr(sa, f) == getattr(sb, f), (pa.overrides, f)


def _fold(points) -> SweepSummary:
    s = SweepSummary()
    for p in points:
        s.update(p)
    return s


# Every batched policy family: (id, spec, grid).  The grids mix window
# buckets (fps axis) and cut at a non-divisor chunk size so chunk
# boundaries split shape groups mid-group.
def _cases():
    yield (
        "jax_accuracy",
        ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=12),
        SweepGrid(deadline_ms=(10.0, 150.0, 350.0), fps=(10.0, 30.0)),
    )
    yield (
        "jax_utility",
        ScenarioSpec(policy=PolicySpec("jax_utility", {"alpha": 200.0}), n_frames=12),
        SweepGrid(fps=(20.0, 50.0), params={"alpha": (50.0, 200.0)}),
    )
    yield (
        "max_accuracy",
        ScenarioSpec(policy=PolicySpec("max_accuracy"), n_frames=14, trace=PIECEWISE),
        SweepGrid(deadline_ms=(150.0, 250.0), fps=(10.0, 30.0), rtt_ms=(40.0, 90.0)),
    )
    yield (
        "max_utility",
        ScenarioSpec(policy=PolicySpec("max_utility", {"alpha": 200.0}), n_frames=14),
        SweepGrid(deadline_ms=(200.0, 350.0), fps=(30.0,), params={"alpha": (50.0, 200.0)}),
    )
    yield (
        "jax_utility-fleet",
        ScenarioSpec(
            policy=PolicySpec("jax_utility", {"alpha": 200.0}),
            n_frames=10,
            fleet=FleetSpec(capacity=2),
        ),
        SweepGrid(n_clients=(1, 2, 3), deadline_ms=(150.0, 250.0)),
    )
    yield (
        "max_accuracy-fleet",
        ScenarioSpec(
            policy=PolicySpec("max_accuracy"),
            n_frames=8,
            fleet=FleetSpec(n_clients=2, capacity=2),
        ),
        SweepGrid(bandwidth_mbps=(1.0, 4.0), deadline_ms=(150.0, 250.0)),
    )
    yield (
        "track_accuracy",
        ScenarioSpec(
            policy=PolicySpec("track_accuracy", {"k_max": 4}),
            n_frames=12,
            workload="track",
        ),
        SweepGrid(bandwidth_mbps=(0.5, 3.0), deadline_ms=(100.0, 200.0)),
    )
    yield (
        "track_fixed-fleet",
        ScenarioSpec(
            policy=PolicySpec("track_fixed", {"k": 3}),
            n_frames=10,
            fleet=FleetSpec(n_clients=2, capacity=2),
            workload="track",
        ),
        SweepGrid(bandwidth_mbps=(1.0, 4.0), deadline_ms=(150.0,)),
    )


CASES = {cid: (spec, grid) for cid, spec, grid in _cases()}
# The two jitted-local families compile in seconds and anchor the fast
# lane; the network-aware/fleet/track programs are multi-second compiles
# and certify chunk invariance in the slow (CI) matrix.
FAST_CASES = ("jax_accuracy", "jax_utility")


def _chunk_case(cid: str) -> None:
    spec, grid = CASES[cid]
    unchunked = Session(spec).run_sweep(grid, backend="batched")
    chunked = Session(spec).run_sweep(grid, backend="batched", chunk_size=3)
    assert unchunked.backend == chunked.backend == "batched"
    assert chunked.meta["chunks"] == -(-len(grid) // 3)
    _assert_det_equal(unchunked, chunked)
    # the incremental summary equals the fold over the kept points
    assert chunked.meta["summary"] == _fold(unchunked.points).to_json()


@pytest.mark.parametrize("cid", FAST_CASES)
def test_chunked_matches_unchunked_fast(cid):
    _chunk_case(cid)


@pytest.mark.slow
@pytest.mark.parametrize("cid", sorted(set(CASES) - set(FAST_CASES)))
def test_chunked_matches_unchunked(cid):
    _chunk_case(cid)


def test_streamed_summary_and_round_trip():
    spec, grid = CASES["jax_accuracy"]
    kept = Session(spec).run_sweep(grid, backend="batched", chunk_size=4)
    streamed = Session(spec).run_sweep(
        grid, backend="batched", chunk_size=4, keep_points=False
    )
    assert streamed.points == []
    assert streamed.meta["points_streamed"] == len(grid)
    assert streamed.meta["summary"] == kept.meta["summary"]
    summary = SweepSummary.from_json(streamed.meta["summary"])
    assert summary.n_points == len(grid)
    assert summary.frames_total == sum(
        s.frames_total for p in kept.points for s in p.streams
    )
    assert summary.best_point in [p.overrides for p in kept.points]
    # a summary-carrying report is still a lossless artifact
    rt = SweepReport.from_json(json.loads(json.dumps(streamed.to_json())))
    assert rt == streamed


def test_chunk_size_validation():
    spec, grid = CASES["jax_accuracy"]
    with pytest.raises(ValueError, match="chunk_size"):
        Session(spec).run_sweep(grid, chunk_size=0)


def test_reference_backend_chunks_too():
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=6)
    grid = SweepGrid(bandwidth_mbps=(1.0, 2.5, 4.0))
    ref = Session(spec).run_sweep(grid)
    chunked = Session(spec).run_sweep(grid, chunk_size=2)
    assert chunked.backend == "reference"
    _assert_det_equal(ref, chunked)


def test_shard_kill_switch_is_identical(monkeypatch):
    spec, grid = CASES["jax_accuracy"]
    on = Session(spec).run_sweep(grid, backend="batched")
    monkeypatch.setenv("REPRO_SWEEP_SHARD", "0")
    off = Session(spec).run_sweep(grid, backend="batched")
    _assert_det_equal(on, off)


def test_cached_reload_is_identical(tmp_path):
    """Executables loaded from the persistent compilation cache must score
    identically to the ones XLA just built.  Regression for the donation
    hazard documented in core/sweep_shard.py: with ``donate_argnums`` set,
    cache-reloaded programs returned corrupted lanes."""
    import jax

    from repro.core import sim_batch
    from repro.core.sweep_shard import _sharded_jit

    spec, grid = CASES["jax_accuracy"]
    cache = str(tmp_path / "jax-cache")
    first = Session(spec).run_sweep(grid, backend="batched", compile_cache=cache)
    # fresh-process simulation: drop every in-process executable, keep disk
    for name in dir(sim_batch):
        obj = getattr(sim_batch, name)
        if callable(getattr(obj, "cache_clear", None)):
            obj.cache_clear()
    _sharded_jit.cache_clear()
    jax.clear_caches()
    reloaded = Session(spec).run_sweep(grid, backend="batched", compile_cache=cache)
    _assert_det_equal(first, reloaded)


def test_lane_program_rejects_interleaved_axes():
    from repro.core.sweep_shard import LaneProgram

    with pytest.raises(ValueError, match="lane args must lead"):
        LaneProgram(lambda a, b, c: a, (0, None, 0))


_SHARD_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
from repro.core import PolicySpec
from repro.session import ScenarioSpec, Session, SweepGrid

import jax
assert jax.device_count() == 4
from repro.launch.mesh import make_sweep_mesh
assert make_sweep_mesh().size == 4

spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=12)
# 5 points: the mesh pads the 5-lane group to 8 — padding must be inert
grid = SweepGrid(deadline_ms=(10.0, 100.0, 150.0, 200.0, 350.0), fps=(30.0,))
sharded = Session(spec).run_sweep(grid, backend="batched")
os.environ["REPRO_SWEEP_SHARD"] = "0"
plain = Session(spec).run_sweep(grid, backend="batched")
fields = ("frames_total", "frames_processed", "frames_missed_deadline",
          "frames_offloaded", "accuracy_sum", "elapsed", "schedule_calls",
          "npu_busy_s")
for pa, pb in zip(sharded.points, plain.points):
    for f in fields:
        a, b = getattr(pa.stats, f), getattr(pb.stats, f)
        assert a == b, (pa.overrides, f, a, b)
print("SHARD_EQUIV_OK")
"""


@pytest.mark.slow
def test_sharded_groups_bit_identical_across_devices():
    """4 forced host devices: shard_map over the scenario mesh (with lane
    padding) must be bit-identical to the plain jitted program.  Needs a
    subprocess because XLA_FLAGS is read at jax import."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_SWEEP_SHARD", None)
    out = subprocess.run(
        [sys.executable, "-c", _SHARD_EQUIV],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARD_EQUIV_OK" in out.stdout


def test_sweep_cli_chunked_summary(tmp_path, capsys):
    from repro.session import main

    spec_file = tmp_path / "scenario.json"
    grid_file = tmp_path / "grid.json"
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=6)
    spec_file.write_text(json.dumps(spec.to_json()))
    grid_file.write_text(json.dumps(SweepGrid(bandwidth_mbps=(1.0, 2.5, 4.0)).to_json()))
    cache_dir = tmp_path / "jax-cache"
    assert main([
        "sweep", str(spec_file), "--grid", str(grid_file),
        "--chunk-size", "2", "--summary-only",
        "--compile-cache", str(cache_dir),
    ]) == 0
    report = SweepReport.from_json(json.loads(capsys.readouterr().out))
    assert report.points == []
    assert report.meta["chunks"] == 2
    assert report.meta["summary"]["n_points"] == 3
    assert report.meta["compile_cache"] == str(cache_dir)
    assert cache_dir.is_dir()
