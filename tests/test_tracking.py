"""Tracking workload class: golden equivalence + contract tests.

The detect+track workload (``core/tracking.py``) couples frames temporally:
a detection's accuracy carries to tracked frames decayed by staleness.  The
contract under test mirrors the classification suite:

  * golden three-path equivalence — the reference ``simulate`` /
    ``simulate_multi`` loops, the batched single-stream engine, and the
    batched fleet engine produce identical audited stats (ints exact,
    accuracy sums within ``AUDIT_TOL`` / ``MULTI_TOL``) for both tracking
    planners, on constant and piecewise traces;
  * the ``run_sweep`` front door routes tracking grids to the batched
    engines and round-trips ``WorkloadSpec`` through JSON;
  * workload/policy gates: classification planners refuse ``kind="track"``
    scenarios and vice versa — at spec time and at the engine boundary;
  * registry error paths for the tracking params (unknown kwarg, ``k < 1``,
    decay outside [0, 1]);
  * hypothesis property: tracked accuracy is monotone non-increasing in
    detector staleness (the table the planners' k-reduction relies on).
"""
from __future__ import annotations

import json

import pytest

from repro.core import PolicySpec
from repro.core.audit import AUDIT_TOL, TrackState, apply_track_round
from repro.core.edge_server import EdgeServerScheduler, make_fleet
from repro.core.profiles import PAPER_MODELS, StreamSpec
from repro.core.sim_batch import BatchScenario, simulate_batch
from repro.core.sim_multi_batch import MULTI_TOL, FleetScenario, simulate_multi_batch
from repro.core.simulator import Trace, simulate, simulate_multi
from repro.core.tracking import WorkloadSpec
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec

INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)

GOLD_FRAMES = 24
MODELS = list(PAPER_MODELS)

# (policy, params) pairs covering both planners; k=3 keeps track_fixed's
# coast-on-stale-state path live at low bandwidth.
PLANNERS = (
    ("track_accuracy", {}),
    ("track_accuracy", {"decay": 0.35, "density": 2.0, "k_max": 4}),
    ("track_fixed", {"k": 3}),
)

# Truth specs decoupled from planner belief (decay 0.0 = lossless tracker,
# 1.0 = tracked frames score zero — both edge rows of the decay table).
WORKLOADS = (
    WorkloadSpec("track"),
    WorkloadSpec("track", decay=0.4, density=2.0),
    WorkloadSpec("track", decay=0.0),
    WorkloadSpec("track", decay=1.0),
)

PIECEWISE = ((0.0, 4.0), (0.25, 0.4), (0.8, 8.0))


def _assert_stats_equal(ref, bat, tol):
    for f in INT_FIELDS:
        assert getattr(ref, f) == getattr(bat, f), f
    assert abs(ref.accuracy_sum - bat.accuracy_sum) <= tol


# ---------------------------------------------------------------------------
# Golden equivalence: reference loop == batched single-stream engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy,params", PLANNERS)
def test_batched_matches_reference_single_stream(policy, params):
    spec = PolicySpec(policy, params)
    for wl in WORKLOADS:
        for segs in (((0.0, 6.0),), ((0.0, 0.0),), PIECEWISE):
            trace = Trace.piecewise(list(segs), rtt_ms=60.0)
            ref = simulate(
                spec.build(), MODELS, StreamSpec(), trace, GOLD_FRAMES, workload=wl
            )
            (bat,) = simulate_batch(
                policy,
                MODELS,
                [
                    BatchScenario(
                        n_frames=GOLD_FRAMES,
                        params=spec.resolved,
                        rtt=0.060,
                        bw_segments=tuple((t, v * 1e6) for t, v in segs),
                        workload=wl,
                    )
                ],
            )
            _assert_stats_equal(ref, bat, AUDIT_TOL)
    # non-vacuous: some configuration actually tracks frames
    assert bat.frames_total == GOLD_FRAMES


# ---------------------------------------------------------------------------
# Golden equivalence: reference fleet loop == batched fleet engine
# ---------------------------------------------------------------------------


def test_fleet_batched_matches_simulate_multi_quick():
    """One fleet scenario per planner in the fast lane; the full
    allocation × planner lattice below is slow-marked (CI --runslow)."""
    _assert_fleet_golden("track_accuracy", {}, [("weighted_fair", 2, 2.0)])


@pytest.mark.slow
@pytest.mark.parametrize("policy,params", PLANNERS)
def test_fleet_batched_matches_simulate_multi(policy, params):
    _assert_fleet_golden(
        policy,
        params,
        [
            ("weighted_fair", 3, 2.0),
            ("fifo", 2, 6.0),
            ("priority", 2, 0.8),
        ],
    )


def _assert_fleet_golden(policy, params, cases):
    spec = PolicySpec(policy, params)
    wl = WorkloadSpec("track", decay=0.25)
    for alloc, n_clients, mbps in cases:
        fleet = make_fleet(
            n_clients,
            policy=spec,
            priorities=tuple(range(n_clients)) if alloc == "priority" else None,
        )
        sched = EdgeServerScheduler(fleet, policy=alloc, capacity=2)
        ms_ref = simulate_multi(
            sched, Trace.constant(mbps, rtt_ms=100.0), GOLD_FRAMES, workload=wl
        )
        ((ms_bat, meta),) = simulate_multi_batch(
            policy,
            MODELS,
            [
                FleetScenario(
                    n_frames=GOLD_FRAMES,
                    bandwidth_bps=mbps * 1e6,
                    n_clients=n_clients,
                    allocation=alloc,
                    capacity=2,
                    priorities=(
                        tuple(range(n_clients)) if alloc == "priority" else None
                    ),
                    params=spec.resolved,
                    workload=wl,
                )
            ],
        )
        assert len(ms_bat.per_client) == len(ms_ref.per_client)
        for sr, sb in zip(ms_ref.per_client, ms_bat.per_client):
            _assert_stats_equal(sr, sb, MULTI_TOL)
        assert ms_bat.server_jobs == ms_ref.server_jobs
        assert abs(ms_bat.server_busy_s - ms_ref.server_busy_s) <= MULTI_TOL
        assert meta["grants"] == sched.audit.grants
        assert meta["denials"] == sched.audit.denials


def test_fleet_detections_contend_tracker_frames_do_not():
    """Tracking's fleet economics: only detections touch the shared uplink
    (at most one per k-frame interval), the tracker carries every other
    frame locally — so offloads stay bounded by the detection count while
    the whole stream is still processed."""
    k = 4
    ((ms, meta),) = simulate_multi_batch(
        "track_fixed",
        MODELS,
        [
            FleetScenario(
                n_frames=GOLD_FRAMES,
                bandwidth_bps=20.0e6,
                n_clients=2,
                params={"k": k},
                workload=WorkloadSpec("track"),
            )
        ],
    )
    for s in ms.per_client:
        assert s.frames_offloaded <= -(-GOLD_FRAMES // k)  # detections only
        assert s.frames_processed + s.frames_missed_deadline == GOLD_FRAMES
    # the shared link saw exactly the offloaded detections, nothing else
    assert ms.server_jobs == sum(s.frames_offloaded for s in ms.per_client)
    assert ms.server_jobs > 0  # non-vacuous: the link is actually used


# ---------------------------------------------------------------------------
# Front door: run_sweep routing + JSON round-trip
# ---------------------------------------------------------------------------


def _track_spec(fleet=None):
    return ScenarioSpec(
        policy=PolicySpec("track_accuracy", {"k_max": 5}),
        n_frames=GOLD_FRAMES,
        trace=TraceSpec(mbps=2.5, rtt_ms=80.0),
        workload=WorkloadSpec("track", decay=0.2, density=1.5),
        fleet=fleet,
    )


def test_run_sweep_tracking_batched_matches_reference():
    grid = SweepGrid(bandwidth_mbps=(0.5, 3.0, 9.0), deadline_ms=(100.0, 200.0))
    for fleet, engine in (
        (None, "sim_batch"),
        (FleetSpec(n_clients=2, capacity=2), "sim_multi_batch"),
    ):
        session = Session(_track_spec(fleet))
        ref = session.run_sweep(grid, backend="reference")
        bat = session.run_sweep(grid, backend="batched")
        assert bat.backend == "batched" and bat.meta["engine"] == engine
        assert len(ref.points) == len(bat.points) == 6
        for pr, pb in zip(ref.points, bat.points):
            assert pr.overrides == pb.overrides
            for sr, sb in zip(pr.streams, pb.streams):
                _assert_stats_equal(sr, sb, MULTI_TOL)
        assert any(s.frames_processed > 0 for p in bat.points for s in p.streams)


def test_scenario_spec_workload_json_round_trip():
    spec = _track_spec(fleet=FleetSpec(n_clients=2))
    rt = ScenarioSpec.from_json(json.dumps(spec.to_json()))
    assert rt == spec
    assert rt.workload == WorkloadSpec("track", decay=0.2, density=1.5)
    # a classify spec omits the default workload from its payload
    classify = ScenarioSpec(policy=PolicySpec("local"))
    assert "workload" not in classify.to_json()
    assert ScenarioSpec.from_json(json.dumps(classify.to_json())) == classify


def test_workload_spec_round_trip_and_coercion():
    wl = WorkloadSpec("track", decay=0.3, density=2.0)
    assert WorkloadSpec.from_json(wl.to_json()) == wl
    # ScenarioSpec coerces strings and mappings into WorkloadSpec
    s = ScenarioSpec(policy=PolicySpec("track_accuracy"), workload="track")
    assert s.workload == WorkloadSpec("track")
    s = ScenarioSpec(
        policy=PolicySpec("track_accuracy"), workload={"kind": "track", "decay": 0.5}
    )
    assert s.workload.decay == 0.5


# ---------------------------------------------------------------------------
# Gates: workload kind vs. policy capability
# ---------------------------------------------------------------------------


def test_workload_policy_gate_at_spec_time():
    with pytest.raises(ValueError, match="plans classify workloads, not 'track'"):
        ScenarioSpec(policy=PolicySpec("max_accuracy"), workload="track")
    with pytest.raises(ValueError, match="plans track workloads, not 'classify'"):
        ScenarioSpec(policy=PolicySpec("track_accuracy"))


def test_workload_policy_gate_at_engine_boundary():
    with pytest.raises(ValueError, match="plans classify workloads, not 'track'"):
        simulate_batch(
            "max_accuracy", MODELS, [BatchScenario(workload=WorkloadSpec("track"))]
        )
    with pytest.raises(ValueError, match="plans track workloads, not 'classify'"):
        simulate_multi_batch("track_accuracy", MODELS, [FleetScenario()])


def test_online_and_serving_reject_tracking():
    spec = _track_spec()
    with pytest.raises(ValueError, match="tracking workload"):
        Session(spec).run_online()
    with pytest.raises(ValueError, match="tracking workload"):
        Session(spec).run_serving()


# ---------------------------------------------------------------------------
# Validation: WorkloadSpec fields + registry param schemas
# ---------------------------------------------------------------------------


def test_workload_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown workload kind"):
        WorkloadSpec("segment")
    with pytest.raises(ValueError, match="decay must be a number"):
        WorkloadSpec("track", decay=-0.1)
    with pytest.raises(ValueError, match="decay must be a number"):
        WorkloadSpec("track", decay=1.5)
    with pytest.raises(ValueError, match="density must be a number"):
        WorkloadSpec("track", density=-1.0)
    with pytest.raises(ValueError, match="not a WorkloadSpec payload"):
        WorkloadSpec.from_json({"decay": 0.2})


def test_registry_rejects_bad_tracking_params():
    with pytest.raises(ValueError, match="accepts no parameter"):
        PolicySpec("track_accuracy", {"interval": 3})
    with pytest.raises(ValueError, match="requires parameter 'k'"):
        PolicySpec("track_fixed")
    with pytest.raises(ValueError, match="must be in \\[1, \\+inf\\]"):
        PolicySpec("track_fixed", {"k": 0})
    with pytest.raises(ValueError, match="must be in \\[1, \\+inf\\]"):
        PolicySpec("track_accuracy", {"k_max": 0})
    with pytest.raises(ValueError, match="must be in \\[0.0, 1.0\\]"):
        PolicySpec("track_accuracy", {"decay": 1.5})
    with pytest.raises(ValueError, match="must be in \\[0.0, 1.0\\]"):
        PolicySpec("track_accuracy", {"decay": -0.1})
    with pytest.raises(ValueError, match="must be in \\[0.0, \\+inf\\]"):
        PolicySpec("track_accuracy", {"density": -2.0})
    with pytest.raises(ValueError, match="expects int"):
        PolicySpec("track_fixed", {"k": 2.5})


def test_track_state_carries_across_rounds():
    """The audit contract's tracking extension: ``apply_track_round`` hands
    back the state a later round needs to score stale frames — a SKIP round
    coasts on the previous detection, decayed per frame of staleness."""
    from repro.core.schedule import Decision, RoundPlan, StreamStats, Where

    wl = WorkloadSpec("track", decay=0.2)
    stream = StreamSpec()
    plan = PolicySpec("track_fixed", {"k": 3}).build()(
        MODELS, stream, Trace.constant(6.0).at(0.0)
    )
    stats = StreamStats()
    state = apply_track_round(
        stats, plan, models=MODELS, stream=stream, state=TrackState(),
        head=0, n_frames=12, horizon=plan.horizon, bad_frames=set(),
        retention=wl.retention,
    )
    assert state.det_frame == 0 and state.det_acc > 0
    assert stats.frames_processed == 3  # detection + 2 tracker-carried frames
    # coast one frame on a SKIP round: score = the detection decayed by age 3
    skip = RoundPlan(decisions=[Decision(0, Where.SKIP)], horizon=1)
    stats2 = StreamStats()
    state2 = apply_track_round(
        stats2, skip, models=MODELS, stream=stream, state=state,
        head=3, n_frames=12, horizon=1, bad_frames=set(),
        retention=wl.retention,
    )
    assert state2 == state
    assert stats2.accuracy_sum == pytest.approx(
        state.det_acc * wl.retention**3, abs=0
    )
