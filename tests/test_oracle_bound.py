"""Differential oracle bound for the network-aware batched planners.

``brute_force.exhaustive_best`` enumerates every (skip | NPU model | offload
model@resolution) assignment per frame in exact continuous time — the true
optimum for tiny instances.  The paper's heuristics execute *some* feasible
schedule in that action space, so their audited stats can never beat it:

  * batched ``max_accuracy``'s mean accuracy <= oracle accuracy;
  * batched ``max_utility``'s utility(alpha)  <= oracle utility.

A cheap sanity bound the golden equivalence tests cannot provide: it checks
the batched engine against the *problem*, not just against the reference
implementation (both could share a bug; the oracle cannot).
"""
from __future__ import annotations

from repro.core import PolicySpec
from repro.core.brute_force import exhaustive_best
from repro.core.profiles import PAPER_MODELS, StreamSpec, network_mbps
from repro.core.tracking import WorkloadSpec, exhaustive_track_best
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec

# Small discretized instance: 2 offload resolutions keep the exhaustive
# search at (2 NPU + 4 offload + skip)^5 states.
STREAM = StreamSpec(fps=10.0, deadline=0.2, resolutions=(90, 224))
N_FRAMES = 5
BANDWIDTHS = (0.5, 2.5, 8.0)
RTT_MS = 50.0
# The audit allows AUDIT_TOL (1e-9 s) of deadline slack the continuous-time
# oracle does not; a comfortably larger epsilon absorbs it.
TOL = 1e-6


def _batched_points(policy: str, params: dict):
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params),
        n_frames=N_FRAMES,
        stream=STREAM,
        trace=TraceSpec(mbps=BANDWIDTHS[0], rtt_ms=RTT_MS),
    )
    rep = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=BANDWIDTHS), backend="batched")
    assert rep.backend == "batched"
    return rep.points


def test_batched_max_accuracy_never_beats_oracle():
    pts = _batched_points("max_accuracy", {})
    for pt in pts:
        net = network_mbps(pt.overrides["bandwidth_mbps"], rtt_ms=RTT_MS)
        opt = exhaustive_best(list(PAPER_MODELS), STREAM, net, N_FRAMES)
        assert pt.stats.mean_accuracy <= opt + TOL, (pt.overrides, pt.stats, opt)
    # and the bound is not vacuous: the heuristic does real work somewhere
    assert any(p.stats.frames_processed > 0 for p in pts)


def test_batched_max_utility_never_beats_oracle():
    alpha = 100.0
    pts = _batched_points("max_utility", {"alpha": alpha})
    for pt in pts:
        net = network_mbps(pt.overrides["bandwidth_mbps"], rtt_ms=RTT_MS)
        opt = exhaustive_best(list(PAPER_MODELS), STREAM, net, N_FRAMES, alpha=alpha)
        assert pt.stats.utility(alpha) <= opt + alpha * TOL, (
            pt.overrides, pt.stats, opt,
        )
    assert any(p.stats.frames_processed > 0 for p in pts)


# ---------------------------------------------------------------------------
# Fleet grids through the batched multi-stream engine: contention only ever
# *removes* options (uploads share the link, the server queue adds delay),
# so each client's achievable set is a subset of the single-client action
# space at the full bandwidth — the single-client oracle still bounds every
# per-client result.
# ---------------------------------------------------------------------------


def _fleet_points(policy: str, params: dict):
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params),
        n_frames=N_FRAMES,
        stream=STREAM,
        trace=TraceSpec(mbps=BANDWIDTHS[0], rtt_ms=RTT_MS),
        fleet=FleetSpec(n_clients=2, capacity=2),
    )
    rep = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=BANDWIDTHS), backend="batched")
    assert rep.backend == "batched"
    assert rep.meta["engine"] == "sim_multi_batch"
    return rep.points


def test_fleet_max_accuracy_clients_never_beat_oracle():
    pts = _fleet_points("max_accuracy", {})
    for pt in pts:
        net = network_mbps(pt.overrides["bandwidth_mbps"], rtt_ms=RTT_MS)
        opt = exhaustive_best(list(PAPER_MODELS), STREAM, net, N_FRAMES)
        for st in pt.streams:
            assert st.mean_accuracy <= opt + TOL, (pt.overrides, st, opt)
    assert any(s.frames_processed > 0 for p in pts for s in p.streams)


def test_fleet_max_utility_clients_never_beat_oracle():
    alpha = 100.0
    pts = _fleet_points("max_utility", {"alpha": alpha})
    for pt in pts:
        net = network_mbps(pt.overrides["bandwidth_mbps"], rtt_ms=RTT_MS)
        opt = exhaustive_best(list(PAPER_MODELS), STREAM, net, N_FRAMES, alpha=alpha)
        for st in pt.streams:
            assert st.utility(alpha) <= opt + alpha * TOL, (pt.overrides, st, opt)
    assert any(s.frames_processed > 0 for p in pts for s in p.streams)


# ---------------------------------------------------------------------------
# Tracking workload: ``tracking.exhaustive_track_best`` enumerates every
# executor-accepted detect+track action (SKIP | NPU detection | offloaded
# detection, each at ANY interval k <= k_max, with exact NPU occupancy
# carry) — a superset of what the registered planners emit.  No tracking
# heuristic, on any backend, can beat it.
# ---------------------------------------------------------------------------

TRACK_WL = WorkloadSpec("track", decay=0.3, density=1.0)
TRACK_K_MAX = 3


def _track_points(policy: str, params: dict, fleet=None):
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params),
        n_frames=N_FRAMES,
        stream=STREAM,
        trace=TraceSpec(mbps=BANDWIDTHS[0], rtt_ms=RTT_MS),
        workload=TRACK_WL,
        fleet=fleet,
    )
    rep = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=BANDWIDTHS), backend="batched")
    assert rep.backend == "batched"
    assert rep.meta["engine"] == ("sim_multi_batch" if fleet else "sim_batch")
    return rep.points


def _track_oracle(mbps: float) -> float:
    net = network_mbps(mbps, rtt_ms=RTT_MS)
    return exhaustive_track_best(
        list(PAPER_MODELS), STREAM, net, N_FRAMES,
        retention=TRACK_WL.retention, k_max=TRACK_K_MAX,
    )


def test_batched_track_planners_never_beat_oracle():
    for policy, params in (
        ("track_accuracy", {"decay": 0.3, "k_max": TRACK_K_MAX}),
        ("track_fixed", {"k": 2}),
    ):
        pts = _track_points(policy, params)
        for pt in pts:
            opt = _track_oracle(pt.overrides["bandwidth_mbps"])
            assert pt.stats.accuracy_sum <= opt + TOL, (policy, pt.overrides, opt)
        assert any(p.stats.frames_processed > 0 for p in pts)


def test_fleet_track_clients_never_beat_oracle():
    """Contention only removes options (detections share the uplink, the
    server queue delays state refreshes), so the full-bandwidth
    single-client oracle still bounds every per-client accuracy sum."""
    pts = _track_points(
        "track_accuracy", {"decay": 0.3, "k_max": TRACK_K_MAX},
        fleet=FleetSpec(n_clients=2, capacity=2),
    )
    for pt in pts:
        opt = _track_oracle(pt.overrides["bandwidth_mbps"])
        for st in pt.streams:
            assert st.accuracy_sum <= opt + TOL, (pt.overrides, st, opt)
    assert any(s.frames_processed > 0 for p in pts for s in p.streams)
