"""End-to-end behaviour tests for the full FastVA system: the serving stack
(real models + controller + deadlines) and the small-mesh dry-run (subprocess
with 8 emulated devices, so this test suite keeps its single real device)."""
from __future__ import annotations

import subprocess
import sys

import jax

import numpy as np
import pytest


@pytest.mark.slow
def test_serving_end_to_end_deadlines():
    """Serve a synthetic video through the full stack; all executed frames
    must have met their planned deadline and accuracy must beat chance."""
    from repro.launch import serve as S

    summary = S.main(
        ["--policy", "max_accuracy", "--frames", "80", "--bandwidth", "2.0", "--fps", "30"]
    )
    assert summary["frames"] >= 60
    assert summary["deadline_met_frac"] == 1.0
    assert summary["accuracy"] > 0.2  # > chance (10 classes)
    assert summary["npu_frames"] + summary["edge_frames"] == summary["frames"]


def test_serving_controller_adapts_bandwidth():
    from repro.core import BandwidthEstimator

    est = BandwidthEstimator(init_bps=8e6, beta=0.5, pessimism=1.0)
    for _ in range(12):
        est.observe_upload(125_000, 1.0)  # 1 Mbps observed
    assert est.state().bandwidth_bps == pytest.approx(1e6, rel=0.05)


def test_scheduler_latency_budget():
    """Paper: scheduling runs in < 1 ms on a phone.  Our Python planner must
    stay well under the 200 ms frame deadline; the jitted DP under 20 ms."""
    import time

    from repro.core import PAPER_MODELS, PAPER_STREAM, network_mbps
    from repro.core.jax_sched import local_accuracy_dp_jax
    from repro.core.max_accuracy import plan_round

    models = list(PAPER_MODELS)
    net = network_mbps(2.0)
    plan_round(models, PAPER_STREAM, net)  # warm caches
    t0 = time.perf_counter()
    for _ in range(20):
        plan_round(models, PAPER_STREAM, net)
    py_ms = (time.perf_counter() - t0) / 20 * 1e3
    assert py_ms < 50, f"python planner too slow: {py_ms:.1f} ms"

    kw = dict(n_frames=6, gamma=1 / 30, deadline=0.2, npu_free=0.0, first_arrival=1 / 30)
    local_accuracy_dp_jax(models, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(20):
        local_accuracy_dp_jax(models, **kw)
    jit_ms = (time.perf_counter() - t0) / 20 * 1e3
    assert jit_ms < 20, f"jitted DP too slow: {jit_ms:.1f} ms"


def test_small_mesh_dryrun_subprocess():
    """Lower+compile three representative cells on an emulated 8-device
    3-axis mesh — the same code path as the 512-device production dry-run."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses, jax
from repro import configs
from repro.arch import ShapeSpec
from repro.launch import steps, analysis
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import MeshRules, train_rules, serve_rules

mesh = make_host_mesh(data=2, model=2, pod=2)
for name, spec in [
    ("qwen2-moe-a2.7b", ShapeSpec("t", "train", 8, seq=64)),
    ("qwen3-0.6b", ShapeSpec("d", "decode", 8, seq=128)),
    ("resnet-50", ShapeSpec("c", "classify_train", 8, img=32)),
]:
    a = configs.get(name, smoke=True)
    a = dataclasses.replace(a, shapes=(spec,))
    rules = MeshRules(mesh, train_rules(mesh) if "train" in spec.kind else serve_rules(mesh))
    prog = steps.build_cell(a, spec.name, rules=rules)
    # jax.set_mesh is newer than 0.4.x; Mesh itself is a context manager.
    with (jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh):
        compiled = prog.jit().lower(*prog.abstract_args()).compile()
    mem = compiled.memory_analysis()
    coll = analysis.parse_collectives(compiled.as_text())
    assert mem.temp_size_in_bytes >= 0
    assert coll["total_bytes"] > 0, f"{name}: expected collectives on an 8-way mesh"
    print("OK", name, sorted(coll["by_kind"]))
print("ALL OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, cwd=".", timeout=900
    )
    assert "ALL OK" in out.stdout, out.stderr[-3000:]


def test_npu_edge_paths_disagree_predictably():
    """System-level NPU characterization (paper §III.A): the quantized path
    agrees with full precision on most inputs but not all."""
    from repro import configs, quant
    from repro.arch import abstract_params, classifier_forward
    from repro.models.common import init_tree

    rng_in = jax.random.normal(jax.random.key(5), (64, 32, 32, 3))
    agreements = {}
    for name in ("squeezenet", "resnet-50"):
        a = configs.get(name, smoke=True)
        specs, st_specs = abstract_params(a)
        params = init_tree(jax.random.key(0), specs)
        state = init_tree(jax.random.key(1), st_specs)
        qparams, _ = quant.npu_variant(params)
        fwd = lambda p, x, a=a, s=state: classifier_forward(a, p, s, x, train=False)[0]
        agreements[name] = quant.agreement(fwd, params, qparams, rng_in)
    assert all(0.3 <= v <= 1.0 for v in agreements.values()), agreements
