"""Regression tests for the RUN_SLOW env-var truthiness rules (conftest).

A CI fork once enabled every slow test by exporting ``RUN_SLOW=0`` — any
non-empty string was truthy.  The parsing now lives in one pure helper with
an explicit falsy set; these tests pin it down.
"""
from __future__ import annotations

import pytest

from conftest import run_slow_enabled


@pytest.mark.parametrize(
    "value",
    [None, "", "  ", "0", "false", "False", "FALSE", " 0 ", "no", "No", "off", "OFF"],
)
def test_falsy_values_keep_fast_lane(value):
    assert run_slow_enabled(value) is False


@pytest.mark.parametrize("value", ["1", "true", "True", "yes", "on", " 1 ", "anything"])
def test_truthy_values_enable_slow_tests(value):
    assert run_slow_enabled(value) is True
