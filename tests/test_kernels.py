"""Per-kernel validation: Pallas kernel (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests on the
quantization scheme."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.kernels.flash_attention import kernel as fk
from repro.kernels.flash_attention import ref as fr
from repro.kernels.npu_matmul import ops as nops
from repro.kernels.npu_matmul import ref as nref

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()


@pytest.mark.parametrize(
    "m,k,n",
    [(128, 512, 128), (256, 1024, 384), (64, 300, 100), (8, 128, 128), (1, 64, 1), (130, 70, 9)],
)
def test_int8_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + n)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    ref = nref.npu_matmul_ref(x, w)
    out = nops.npu_matmul(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 256)), dtype)
    w = jnp.asarray(rng.normal(size=(256, 64)), dtype)
    out = nops.npu_matmul(x, w, interpret=True)
    ref = nref.npu_matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        # Shapes where M, K, N are each NOT multiples of the default
        # (128, 512, 128) blocks — exercises the adaptive block sizing +
        # padding path in npu_matmul_prequant end to end.
        (130, 700, 129),
        (3, 33, 65),
        (257, 513, 127),
    ],
)
def test_int8_prequant_non_block_multiple_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    xq, xs = nref.quantize_rowwise(x)
    wq, ws = nref.quantize_colwise(w)
    ref = nref.int8_matmul_ref(xq, wq, xs, ws)
    out = nops.npu_matmul_prequant(xq, xs, wq, ws, interpret=True)
    assert out.shape == (m, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_int8_prequant_single_row_golden():
    """M=1 — the serving loop's per-frame head GEMM.  The adaptive block
    size (bm=1 instead of padding M to 128) must not change the numbers:
    golden-compared against the pure-jnp int8 reference."""
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(1, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 10)), jnp.float32)
    xq, xs = nref.quantize_rowwise(x)
    wq, ws = nref.quantize_colwise(w)
    ref = nref.int8_matmul_ref(xq, wq, xs, ws)
    out = nops.npu_matmul_prequant(xq, xs, wq, ws, interpret=True)
    assert out.shape == (1, 10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)


def test_quant_error_stats_counts_mixed_tree():
    """Non-float leaves (step counters, bool masks) must count as kept, so
    leaves_quantized + leaves_kept == total leaves on any params tree."""
    from repro.quant import fake_quant_tree, quant_error_stats

    rng = np.random.default_rng(5)
    params = {
        "w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),  # quantized
        "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32),  # kept (ndim < 2)
        "step": jnp.asarray(3, jnp.int32),  # kept (int)
        "mask": jnp.ones((4, 4), jnp.bool_),  # kept (bool)
    }
    q = fake_quant_tree(params)
    stats = quant_error_stats(params, q)
    total = len(jax.tree.leaves(params))
    assert stats.leaves_quantized == 1
    assert stats.leaves_kept == total - 1 == 3
    assert stats.mean_rel_err > 0


def test_int8_quant_error_bounded():
    """int8 symmetric quantization keeps the GEMM within ~2% relative error
    on well-conditioned inputs — the 'NPU is less accurate' premise, bounded."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    out = nops.npu_matmul(x, w, interpret=True)
    exact = x @ w
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02


@given(
    st.integers(1, 6).map(lambda i: 2**i),
    st.integers(4, 9).map(lambda i: 2**i),
    st.floats(0.1, 100.0),
)
@SETTINGS
def test_quantize_roundtrip_property(m, k, scale):
    rng = np.random.default_rng(m * k)
    x = jnp.asarray(rng.normal(size=(m, k)) * scale, jnp.float32)
    q, s = nref.quantize_rowwise(x)
    deq = q.astype(jnp.float32) * s[:, None]
    # max round-off is half a quantization step per element
    step = jnp.abs(x).max(axis=1) / 127.0
    assert bool(jnp.all(jnp.abs(deq - x) <= step[:, None] * 0.5 + 1e-7))
    assert int(jnp.max(jnp.abs(q))) <= 127


@pytest.mark.parametrize(
    "b,s,t,h,kh,hd,causal",
    [
        (2, 128, 128, 8, 4, 64, True),
        (1, 100, 200, 4, 4, 32, False),
        (2, 257, 257, 8, 2, 64, True),
        (1, 64, 512, 16, 8, 128, True),
        (1, 33, 65, 2, 1, 16, False),
    ],
)
def test_flash_attention_matches_ref(b, s, t, h, kh, hd, causal):
    rng = np.random.default_rng(s * t)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    ref = fr.sdpa_ref(q, k, v, causal=causal)
    out = fk.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 128, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 4, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 4, 64)), jnp.bfloat16)
    ref = fr.sdpa_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    out = fk.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=0.05, atol=0.02
    )


def test_blockwise_oracle_matches_dense():
    """The jnp blockwise path (what models use off-TPU) == dense attention."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 100, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 100, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 100, 4, 32)), jnp.float32)
    ref = fr.sdpa_ref(q, k, v, causal=True)
    out = fr.blockwise_ref(q, k, v, causal=True, q_block=32, kv_block=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=2e-6)


@given(st.integers(1, 4), st.integers(1, 5), st.booleans())
@SETTINGS
def test_flash_attention_property(b, blocks, causal):
    """Random (ragged vs block) sizes: kernel == oracle."""
    s = 17 * blocks + 3
    rng = np.random.default_rng(b * blocks)
    q = jnp.asarray(rng.normal(size=(b, s, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, 2, 32)), jnp.float32)
    ref = fr.sdpa_ref(q, k, v, causal=causal)
    out = fk.flash_attention(q, k, v, causal=causal, block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=2e-5)
