"""Property tests for the vectorized sweep backend.

Two invariants, on *random* inputs rather than the curated golden grid:

  * for arbitrary model profiles (including server-only models and models
    with empty NPU accuracy tables), stream shapes, frame budgets, and
    policy params, every scenario of a mixed batch through
    ``sim_batch.simulate_batch`` returns stats identical to the reference
    ``simulate`` loop — the padding/grouping machinery must be invisible;
  * ``SweepReport`` JSON round-trips losslessly through ``to_json`` /
    ``from_json`` for random grids on both backends.

Stream shapes are drawn from small value sets (not continuous floats) so the
jit cache is shared across examples; model latencies and policy params stay
continuous — they are traced, not compiled.
"""
from __future__ import annotations

import json

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from repro.core import PolicySpec, StreamSpec, Trace, profile_ms, simulate  # noqa: E402
from repro.core.sim_batch import BatchScenario, simulate_batch  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid, SweepReport  # noqa: E402

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()

STATS_FIELDS = (
    "accuracy_sum",
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)


@st.composite
def model_sets(draw):
    n = draw(st.integers(1, 3))
    models = []
    for i in range(n):
        runs_local = draw(st.booleans()) if n > 1 else True
        has_acc = draw(st.booleans())
        models.append(
            profile_ms(
                f"m{i}",
                t_npu_ms=draw(st.floats(5, 250)) if runs_local else float("inf"),
                t_server_ms=draw(st.floats(5, 120)),
                acc_server={45: 0.2, 224: draw(st.floats(0.3, 0.95))},
                acc_npu={224: draw(st.floats(0.1, 0.9))} if has_acc else {},
            )
        )
    return models


@st.composite
def batch_cases(draw):
    models = draw(model_sets())
    policy = draw(st.sampled_from(("jax_accuracy", "jax_utility")))
    scens = []
    for _ in range(draw(st.integers(1, 3))):
        stream = StreamSpec(
            fps=draw(st.sampled_from((10.0, 30.0, 50.0))),
            deadline=draw(st.sampled_from((15.0, 50.0, 100.0, 200.0, 350.0))) / 1e3,
        )
        if policy == "jax_utility":
            params = {
                "alpha": draw(st.floats(1.0, 400.0)),
                "width": draw(st.sampled_from((16, 64))),
            }
        else:
            params = {"grid": draw(st.sampled_from((1e-3, 2e-3)))}
        scens.append(
            (stream, draw(st.integers(1, 30)), PolicySpec(policy, params))
        )
    return models, policy, scens


@SETTINGS
@given(batch_cases())
def test_batched_stats_equal_reference_simulate(case):
    models, policy, scens = case
    batch = [
        BatchScenario(stream=stream, n_frames=n, params=spec.resolved)
        for stream, n, spec in scens
    ]
    out = simulate_batch(policy, models, batch)
    assert len(out) == len(scens)
    for (stream, n, spec), got in zip(scens, out):
        ref = simulate(spec.build(), models, stream, Trace.constant(2.5), n)
        for f in STATS_FIELDS:
            assert getattr(got, f) == getattr(ref, f), (spec, stream, n, f)


# ---------------------------------------------------------------------------
# Network-aware planners (max_accuracy / max_utility): random traces too.
# Stream/trace shape values come from small sets (shared jit cache); model
# latencies, bandwidths, rtt, and alpha stay continuous — they are traced.
# ---------------------------------------------------------------------------

from repro.core.audit import AUDIT_TOL  # noqa: E402

INT_FIELDS = tuple(f for f in STATS_FIELDS if f != "accuracy_sum")


@st.composite
def traces(draw):
    rtt_ms = draw(st.floats(20.0, 150.0))
    if draw(st.booleans()):
        return ("constant", draw(st.floats(0.2, 12.0)), rtt_ms, ())
    points = tuple(
        (t, draw(st.floats(0.2, 12.0)))
        for t in sorted(draw(st.sets(st.sampled_from((0.0, 0.1, 0.25, 0.4, 0.8)),
                                     min_size=1, max_size=3)))
    )
    return ("piecewise", None, rtt_ms, points)


@st.composite
def net_batch_cases(draw):
    models = draw(model_sets())
    policy = draw(st.sampled_from(("max_accuracy", "max_utility")))
    params = (
        {"alpha": draw(st.floats(1.0, 400.0))} if policy == "max_utility" else {}
    )
    scens = []
    for _ in range(draw(st.integers(1, 2))):
        stream = StreamSpec(
            fps=draw(st.sampled_from((10.0, 30.0, 50.0))),
            deadline=draw(st.sampled_from((15.0, 100.0, 200.0, 350.0))) / 1e3,
        )
        scens.append((stream, draw(st.integers(1, 20)), draw(traces())))
    return models, policy, params, scens


def _build_trace(kind, mbps, rtt_ms, points) -> Trace:
    if kind == "constant":
        return Trace.constant(mbps, rtt_ms=rtt_ms)
    return Trace.piecewise(list(points), rtt_ms=rtt_ms)


def _segments(kind, mbps, rtt_ms, points):
    if kind == "constant":
        return ((0.0, mbps * 1e6),)
    return tuple((t, v * 1e6) for t, v in sorted(points))


@SETTINGS
@given(net_batch_cases())
def test_network_batched_stats_equal_reference_simulate(case):
    """For arbitrary profiles, streams, and (constant|piecewise) traces the
    network-aware batched planners reproduce the reference ``simulate``
    loop: integer stats exactly, accuracy sums within AUDIT_TOL."""
    models, policy, params, scens = case
    spec = PolicySpec(policy, params)
    batch = [
        BatchScenario(
            stream=stream, n_frames=n, params=spec.resolved,
            rtt=tr[2] / 1e3, bw_segments=_segments(*tr),
        )
        for stream, n, tr in scens
    ]
    out = simulate_batch(policy, models, batch)
    assert len(out) == len(scens)
    for (stream, n, tr), got in zip(scens, out):
        ref = simulate(spec.build(), models, stream, _build_trace(*tr), n)
        for f in INT_FIELDS:
            assert getattr(got, f) == getattr(ref, f), (spec, stream, n, tr, f)
        assert abs(got.accuracy_sum - ref.accuracy_sum) <= AUDIT_TOL, (spec, stream, n, tr)


@SETTINGS
@given(
    policy=st.sampled_from(("jax_accuracy", "local")),
    bandwidths=st.lists(st.floats(0.5, 4.0), min_size=1, max_size=2, unique=True),
    deadlines=st.lists(st.sampled_from((100.0, 150.0, 200.0, 250.0)), min_size=1,
                       max_size=2, unique=True),
    alpha_axis=st.booleans(),
)
def test_sweep_report_round_trips_losslessly(policy, bandwidths, deadlines, alpha_axis):
    grid = SweepGrid(
        bandwidth_mbps=tuple(bandwidths),
        deadline_ms=tuple(deadlines),
        params={"alpha": (50.0, 200.0)} if alpha_axis and policy == "local" else {},
    )
    spec = ScenarioSpec(policy=PolicySpec(policy), n_frames=6, label="prop-rt")
    rep = Session(spec).run_sweep(grid)
    assert rep.backend == ("batched" if policy == "jax_accuracy" else "reference")
    rt = SweepReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert rt == rep
    assert rt.grid.points() == grid.points()
