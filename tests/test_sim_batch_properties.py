"""Property tests for the vectorized sweep backend.

Two invariants, on *random* inputs rather than the curated golden grid:

  * for arbitrary model profiles (including server-only models and models
    with empty NPU accuracy tables), stream shapes, frame budgets, and
    policy params, every scenario of a mixed batch through
    ``sim_batch.simulate_batch`` returns stats identical to the reference
    ``simulate`` loop — the padding/grouping machinery must be invisible;
  * ``SweepReport`` JSON round-trips losslessly through ``to_json`` /
    ``from_json`` for random grids on both backends.

Stream shapes are drawn from small value sets (not continuous floats) so the
jit cache is shared across examples; model latencies and policy params stay
continuous — they are traced, not compiled.
"""
from __future__ import annotations

import json

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from repro.core import PolicySpec, StreamSpec, Trace, profile_ms, simulate  # noqa: E402
from repro.core.sim_batch import BatchScenario, simulate_batch  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid, SweepReport  # noqa: E402

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()

STATS_FIELDS = (
    "accuracy_sum",
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)


@st.composite
def model_sets(draw):
    n = draw(st.integers(1, 3))
    models = []
    for i in range(n):
        runs_local = draw(st.booleans()) if n > 1 else True
        has_acc = draw(st.booleans())
        models.append(
            profile_ms(
                f"m{i}",
                t_npu_ms=draw(st.floats(5, 250)) if runs_local else float("inf"),
                t_server_ms=draw(st.floats(5, 120)),
                acc_server={45: 0.2, 224: draw(st.floats(0.3, 0.95))},
                acc_npu={224: draw(st.floats(0.1, 0.9))} if has_acc else {},
            )
        )
    return models


@st.composite
def batch_cases(draw):
    models = draw(model_sets())
    policy = draw(st.sampled_from(("jax_accuracy", "jax_utility")))
    scens = []
    for _ in range(draw(st.integers(1, 3))):
        stream = StreamSpec(
            fps=draw(st.sampled_from((10.0, 30.0, 50.0))),
            deadline=draw(st.sampled_from((15.0, 50.0, 100.0, 200.0, 350.0))) / 1e3,
        )
        if policy == "jax_utility":
            params = {
                "alpha": draw(st.floats(1.0, 400.0)),
                "width": draw(st.sampled_from((16, 64))),
            }
        else:
            params = {"grid": draw(st.sampled_from((1e-3, 2e-3)))}
        scens.append(
            (stream, draw(st.integers(1, 30)), PolicySpec(policy, params))
        )
    return models, policy, scens


@SETTINGS
@given(batch_cases())
def test_batched_stats_equal_reference_simulate(case):
    models, policy, scens = case
    batch = [
        BatchScenario(stream=stream, n_frames=n, params=spec.resolved)
        for stream, n, spec in scens
    ]
    out = simulate_batch(policy, models, batch)
    assert len(out) == len(scens)
    for (stream, n, spec), got in zip(scens, out):
        ref = simulate(spec.build(), models, stream, Trace.constant(2.5), n)
        for f in STATS_FIELDS:
            assert getattr(got, f) == getattr(ref, f), (spec, stream, n, f)


@SETTINGS
@given(
    policy=st.sampled_from(("jax_accuracy", "local")),
    bandwidths=st.lists(st.floats(0.5, 4.0), min_size=1, max_size=2, unique=True),
    deadlines=st.lists(st.sampled_from((100.0, 150.0, 200.0, 250.0)), min_size=1,
                       max_size=2, unique=True),
    alpha_axis=st.booleans(),
)
def test_sweep_report_round_trips_losslessly(policy, bandwidths, deadlines, alpha_axis):
    grid = SweepGrid(
        bandwidth_mbps=tuple(bandwidths),
        deadline_ms=tuple(deadlines),
        params={"alpha": (50.0, 200.0)} if alpha_axis and policy == "local" else {},
    )
    spec = ScenarioSpec(policy=PolicySpec(policy), n_frames=6, label="prop-rt")
    rep = Session(spec).run_sweep(grid)
    assert rep.backend == ("batched" if policy == "jax_accuracy" else "reference")
    rt = SweepReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert rt == rep
    assert rt.grid.points() == grid.points()
