"""Serving-loop regressions: the estimator-echo fix (a wrong bandwidth belief
must converge to the TRUE link during ``VideoServer.run``), frame degradation,
the matmul-backend hook that routes convolutions through ``kernels/npu_matmul``,
and the measured-profile calibration pipeline."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import BandwidthEstimator, OnlineController, PolicySpec, profile_ms
from repro.core.profiles import NetworkState, StreamSpec


# ---------------------------------------------------------------------------
# Toy serving stack: real VideoServer/controller, trivial models
# ---------------------------------------------------------------------------

def _toy_stack(*, policy="offload", true_mbps=4.0, init_bps=None, fps=10.0,
               use_edge_server=False):
    import jax.numpy as jnp

    from repro.serving import (
        BatchedEndpoint,
        EdgeBatchServer,
        ModelEndpoint,
        VideoServer,
        make_synthetic_video,
    )

    res = 8
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.standard_normal((res * res * 3, 10)).astype(np.float32))

    def forward(x):
        return jnp.tanh(x).reshape(x.shape[0], -1) @ W

    prof = profile_ms(
        "toy",
        t_npu_ms=5.0,
        t_server_ms=5.0,
        acc_server={45: 0.30, 134: 0.55, 224: 0.80},
        acc_npu={224: 0.60},
    )
    stream = StreamSpec(fps=fps)
    true_net = NetworkState(bandwidth_bps=true_mbps * 1e6, rtt=0.02)
    controller = OnlineController(
        models=[prof],
        stream=stream,
        policy=PolicySpec.coerce(policy),
        estimator=BandwidthEstimator(
            init_bps=init_bps if init_bps is not None else true_net.bandwidth_bps
        ),
    )
    npu = ModelEndpoint("toy-npu", forward, profile_latency_s=prof.t_npu)
    kwargs = {}
    if use_edge_server:
        ep = BatchedEndpoint("toy-edge", forward, max_batch=8)
        ep.warmup(np.zeros((res, res, 3), np.float32))
        kwargs["edge_server"] = EdgeBatchServer({0: ep})
    else:
        kwargs["edge_endpoints"] = {0: ModelEndpoint("toy-edge", forward, profile_latency_s=prof.t_server)}
    server = VideoServer(
        controller=controller, npu_endpoints={0: npu}, stream=stream,
        trace=true_net, **kwargs,
    )
    frames, labels = make_synthetic_video(60, n_classes=10, res=res, seed=3)
    return server, controller, frames, labels, true_net


# ---------------------------------------------------------------------------
# Estimator echo fix: wrong beliefs converge during run()
# ---------------------------------------------------------------------------

def test_estimator_converges_from_optimistic_prior():
    """Belief starts 10x HIGH; the loop must report measured transfer times
    (not its own predictions) so the EWMA converges down to the true link.
    With the echo bug, each observation reproduced the belief and the wrong
    prior persisted forever."""
    server, controller, frames, labels, true_net = _toy_stack(
        policy="offload", true_mbps=4.0, init_bps=40e6
    )
    server.run(frames, labels)
    est = controller.estimator
    assert est.samples >= 20  # offload ships (and measures) nearly every frame
    rel_err = abs(est._bps - true_net.bandwidth_bps) / true_net.bandwidth_bps
    assert rel_err < 0.1, f"estimator stuck at {est._bps:.3g} (true {true_net.bandwidth_bps:.3g})"


def test_estimator_converges_from_pessimistic_prior():
    """Belief starts 4x LOW with a generous frame gap (so the Offload policy
    still believes shipping is sustainable and keeps probing): it converges up."""
    server, controller, frames, labels, true_net = _toy_stack(
        policy="offload", true_mbps=4.0, init_bps=1e6, fps=4.0
    )
    server.run(frames, labels)
    est = controller.estimator
    assert est.samples >= 20
    rel_err = abs(est._bps - true_net.bandwidth_bps) / true_net.bandwidth_bps
    assert rel_err < 0.1, f"estimator stuck at {est._bps:.3g} (true {true_net.bandwidth_bps:.3g})"


def test_dead_link_misses_frames_without_poisoning_the_clock():
    """True link dead while the belief says fine: offloaded frames miss (no
    inference result), the estimator decays, and the virtual uplink clock
    stays finite so a later recovery could still transmit."""
    server, controller, frames, labels, _ = _toy_stack(
        policy="offload", true_mbps=4.0, init_bps=4e6
    )
    server._net_at = lambda t: NetworkState(bandwidth_bps=0.0, rtt=0.02)
    summary = server.run(frames, labels)
    dead = [r for r in server.results if r.where == "server"]
    assert dead and all(not r.deadline_met and not r.correct for r in dead)
    assert np.isfinite(server._net_free_abs)
    assert summary["deadline_met_frac"] < 1.0
    # inf-time observations drive the belief toward zero, not to NaN.
    assert 0.0 <= controller.estimator._bps < 4e6


def test_videoserver_measured_latency_includes_uplink_queueing():
    """Two offloads in one round share the serial uplink: the second frame's
    measured finish must queue behind the first's transfer."""
    server, controller, frames, labels, true_net = _toy_stack(
        policy="offload", true_mbps=4.0
    )
    server.run(frames[:10], labels[:10])
    lats = [r.latency_s for r in server.results if r.where == "server"]
    t_up_224 = true_net.upload_time(server.stream.frame_bytes(224))
    # every measured latency >= one true transfer + rtt + service
    assert all(lat >= min(t_up_224, true_net.upload_time(server.stream.frame_bytes(45))) for lat in lats)
    assert summary_finite(server.summary())


def summary_finite(s: dict) -> bool:
    return np.isfinite(s["fps_sustained"]) and np.isfinite(s["mean_latency_s"])


def test_videoserver_edge_server_batches_and_matches_endpoints():
    """With an EdgeBatchServer attached, predictions are identical to the
    per-frame endpoint path and batch stats land in the summary."""
    s1, _, frames, labels, _ = _toy_stack(policy="offload", use_edge_server=False)
    s2, _, _, _, _ = _toy_stack(policy="offload", use_edge_server=True)
    sum1 = s1.run(frames, labels)
    sum2 = s2.run(frames, labels)
    assert sum1["accuracy"] == sum2["accuracy"]
    assert sum1["edge_frames"] == sum2["edge_frames"] > 0
    assert sum2["batch"]["flushes"] > 0
    assert sum2["batch"]["mean_batch"] >= 1.0


# ---------------------------------------------------------------------------
# degrade_frame
# ---------------------------------------------------------------------------

def test_degrade_frame_identity_at_full_resolution():
    from repro.serving import degrade_frame

    f = np.random.default_rng(1).standard_normal((16, 16, 3)).astype(np.float32)
    assert degrade_frame(f, 224, r_ref=224) is f
    assert degrade_frame(f, 500, r_ref=224) is f


def test_degrade_frame_loses_information_monotonically():
    from repro.serving import degrade_frame

    f = np.random.default_rng(2).standard_normal((32, 32, 3)).astype(np.float32)
    errs = []
    for r in (179, 90, 45):
        g = degrade_frame(f, r, r_ref=224)
        assert g.shape == f.shape and g.dtype == f.dtype
        errs.append(float(np.linalg.norm(g - f)))
    assert errs[0] > 0
    assert errs == sorted(errs)  # smaller resolution -> more loss


# ---------------------------------------------------------------------------
# matmul backend hook + im2col conv lowering
# ---------------------------------------------------------------------------

def test_matmul_backend_conv_equivalence():
    """conv() through the backend hook (im2col + GEMM) == lax.conv, including
    the strided-1x1 projection case."""
    import jax.numpy as jnp

    from repro.models import convnets
    from repro.models.common import matmul_backend

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 4)).astype(np.float32))
    cases = [
        (jnp.asarray(rng.standard_normal((3, 3, 4, 8)).astype(np.float32)), 1),
        (jnp.asarray(rng.standard_normal((3, 3, 4, 8)).astype(np.float32)), 2),
        (jnp.asarray(rng.standard_normal((1, 1, 4, 8)).astype(np.float32)), 1),
        (jnp.asarray(rng.standard_normal((1, 1, 4, 8)).astype(np.float32)), 2),  # strided proj
    ]
    for p, stride in cases:
        direct = convnets.conv(p, x, stride=stride)
        with matmul_backend(lambda a, b: a @ b):
            routed = convnets.conv(p, x, stride=stride)
        np.testing.assert_allclose(
            np.asarray(routed), np.asarray(direct), rtol=1e-5, atol=1e-5
        )


def test_matmul_backend_counts_and_restores():
    """The hook is a stack: active inside the context (every matmul counted),
    inert outside (plain @)."""
    import jax.numpy as jnp

    from repro.models.common import current_matmul, matmul, matmul_backend

    calls = []

    def counting(a, b):
        calls.append((a.shape, b.shape))
        return a @ b

    x = jnp.ones((3, 4, 5))
    w = jnp.ones((5, 6))
    base = matmul(x, w)
    assert not calls and current_matmul() is None
    with matmul_backend(counting):
        out = matmul(x, w)
    assert len(calls) == 1 and calls[0] == ((12, 5), (5, 6))  # leading dims flattened
    assert current_matmul() is None
    np.testing.assert_allclose(np.asarray(out), np.asarray(base))


def test_npu_forward_routes_model_matmuls_through_kernel():
    """A squeezenet-smoke forward under the NPU execution context runs its
    convs/head as int8 kernel GEMMs: close to (quantization error), but not
    bit-identical to, the full-precision forward."""
    import jax
    import jax.numpy as jnp

    from repro import configs, quant
    from repro.arch import abstract_params as arch_params
    from repro.arch import classifier_forward
    from repro.models.common import init_tree

    arch = configs.get("squeezenet", smoke=True)
    specs, state_specs = arch_params(arch)
    params = init_tree(jax.random.key(0), specs)
    state = init_tree(jax.random.key(1), state_specs)

    def forward(p, x):
        return classifier_forward(arch, p, state, x, train=False)[0]

    x = jnp.asarray(np.random.default_rng(4).standard_normal((1, 16, 16, 3)).astype(np.float32))
    fp = np.asarray(forward(params, x), np.float32)
    routed = np.asarray(quant.npu_forward(forward, interpret=True)(params, x), np.float32)
    assert fp.shape == routed.shape
    assert np.any(fp != routed), "kernel routing was a no-op (backend never engaged)"
    assert np.all(np.isfinite(routed))
    # Untrained logits are tiny (relu kills most), so judge the int8 error
    # relative to the logit scale, not the near-zero vector norm.
    denom = max(float(np.max(np.abs(fp))), 1e-6)
    assert float(np.max(np.abs(fp - routed))) / denom < 0.25  # round-off, not garbage


# ---------------------------------------------------------------------------
# Calibration pipeline (heavy: trains + compiles both variants)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_calibration_artifact_roundtrips_into_scenariospec(tmp_path):
    import dataclasses

    from repro.serving import CalibrationConfig, calibrate, load_calibration, save_calibration
    from repro.session import ScenarioSpec

    cfg = dataclasses.replace(
        CalibrationConfig.smoke(),
        model_names=("squeezenet",),
        train_steps={"squeezenet": 10},
        holdout_frames=32,
        batch_sizes=(1,),
        repeats=1,
    )
    cal = calibrate(cfg)
    path = save_calibration(cal.artifact, tmp_path / "calibration.json")
    art = load_calibration(path)

    (m,) = art["models"]
    assert m["name"] == "squeezenet"
    assert m["t_npu_ms"] >= 1.0 and m["t_server_ms"] >= 1.0  # measured, floored
    assert set(m["acc_server"]) == {"45", "90", "134", "179", "224"}
    assert m["provenance"]["source"] == "measured"
    assert m["provenance"]["kernel"].startswith("kernels/npu_matmul")
    assert 0.0 <= m["provenance"]["fp32_int8_agreement"] <= 1.0

    spec = ScenarioSpec(policy="max_accuracy", models=art["models"], n_frames=4)
    prof = spec.models[0]
    assert prof.t_npu == pytest.approx(m["t_npu_ms"] / 1e3)
    assert prof.acc_server[45] == m["acc_server"]["45"]
    assert prof.accuracy(100, where="server") >= 0.0  # interpolation works

    # The endpoints returned alongside the artifact are live and agree with
    # the payload's provenance (same variants that were measured).
    logits = cal.models[0].npu_endpoint(np.zeros((1, cfg.res, cfg.res, 3), np.float32))
    assert logits.shape == (1, cfg.n_classes)


def test_load_calibration_rejects_foreign_json(tmp_path):
    import json

    from repro.serving import load_calibration

    p = tmp_path / "other.json"
    p.write_text(json.dumps({"schema": "something-else", "models": []}))
    with pytest.raises(ValueError):
        load_calibration(p)
