"""Unit tests for the roofline analyzer: jaxpr FLOPs/bytes counting (scan
multipliers, remat traversal) and the HLO collective parser (trip-count
weighting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import analysis


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analysis.traced_costs(f, a, b)
    assert c.flops == 2 * 64 * 128 * 32


def test_scan_multiplies_body():
    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None

        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jax.ShapeDtypeStruct((7, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    c = analysis.traced_costs(f, w, x)
    assert c.flops >= 7 * 2 * 4 * 16 * 16  # 7 scan iterations counted
    assert c.flops < 7 * 2 * 4 * 16 * 16 * 1.5


def test_remat_counts_recompute():
    def block(w, x):
        return jnp.tanh(x @ w)

    def loss_plain(w, x):
        return jnp.sum(block(w, x))

    def loss_remat(w, x):
        return jnp.sum(jax.checkpoint(block)(w, x))

    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    g_plain = analysis.traced_costs(lambda w, x: jax.grad(loss_plain)(w, x), w, x)
    g_remat = analysis.traced_costs(lambda w, x: jax.grad(loss_remat)(w, x), w, x)
    assert g_remat.flops > g_plain.flops  # the forward recompute is visible


def test_conv_flops():
    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    x = jax.ShapeDtypeStruct((2, 8, 8, 3), jnp.float32)
    k = jax.ShapeDtypeStruct((3, 3, 3, 16), jnp.float32)
    c = analysis.traced_costs(f, x, k)
    assert c.flops == pytest.approx(2 * 2 * 8 * 8 * (3 * 3 * 3 * 16), rel=0.01)


SYNTHETIC_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %ag.1 = f32[4,4]{1,0} all-gather(%x), channel_id=1, dimensions={0}
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %ag.1)
}

%cond.1 (p: (s32[], f32[4,4])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %ar = f32[8,8]{1,0} all-reduce(%a), channel_id=2, to_apply=%add.c
  %w = (s32[], f32[4,4]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[4,4] get-tuple-element(%w), index=1
}

%add.c (x: f32[], y: f32[]) -> f32[] {
  ROOT %s = f32[] add(%x, %y)
}
"""


def test_collective_parser_trip_counts():
    out = analysis.parse_collectives(SYNTHETIC_HLO)
    # all-gather inside the while body: 4*4*4 bytes x 5 trips = 320
    assert out["by_kind"]["all-gather"] == 4 * 4 * 4 * 5
    # all-reduce in entry: 8*8*4 = 256, counted once
    assert out["by_kind"]["all-reduce"] == 8 * 8 * 4


def test_flash_accounting_reduces_bytes():
    from repro.models import layers as L

    cfg = L.AttnCfg(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    specs = L.attention_specs(cfg)
    from repro.models.common import abstract_tree

    params = abstract_tree(specs)
    x = jax.ShapeDtypeStruct((2, 256, 64), jnp.float32)

    def f(p, x):
        out, _ = L.attention(cfg, p, x)
        return out

    plain = analysis.traced_costs(f, params, x)
    with L.flash_accounting():
        flash = analysis.traced_costs(f, params, x)
    assert flash.bytes < plain.bytes * 0.8
    # flops intentionally differ (the stub removes the attention dots); the
    # dry-run takes flops from the real trace.


def test_roofline_bottleneck_classification():
    r = analysis.roofline(1e15, 1e12, {"est_seconds": 0.001}, chips=256)
    assert r["bottleneck"] == "compute_s"
    assert r["roofline_fraction"] == 1.0
    r = analysis.roofline(1e12, 1e15, {"est_seconds": 0.001}, chips=256)
    assert r["bottleneck"] == "memory_s"
    assert r["roofline_fraction"] < 0.1
