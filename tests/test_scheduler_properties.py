"""Property tests for the FastVA schedulers (the paper's core contribution).

Invariants:
  * every emitted plan is feasible (deadlines, no NPU overlap);
  * Max-Accuracy >= both Local and Offload on any instance (it contains them);
  * Max-Accuracy / Max-Utility <= the exhaustive optimum on tiny instances;
  * Max-Utility >= Local on the utility objective;
  * the dominance-pruned DP equals a brute-force subset enumeration;
  * JAX DPs == Python DPs.
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from repro.core import (
    PAPER_MODELS,
    NetworkState,
    StreamSpec,
    Trace,
    make_policy,
    network_mbps,
    profile_ms,
    simulate,
)
from repro.core import brute_force, max_accuracy, max_utility
from repro.core.schedule import validate_plan

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()


@st.composite
def model_profiles(draw):
    n = draw(st.integers(1, 3))
    models = []
    for i in range(n):
        t_npu = draw(st.floats(5, 120))
        t_srv = draw(st.floats(5, 120))
        a_srv = draw(st.floats(0.2, 0.95))
        a_npu = draw(st.floats(0.1, 0.9))
        models.append(
            profile_ms(
                f"m{i}",
                t_npu_ms=t_npu,
                t_server_ms=t_srv,
                acc_server={45: a_srv * 0.4, 134: a_srv * 0.8, 224: a_srv},
                acc_npu={224: a_npu},
            )
        )
    return models


@st.composite
def scenario(draw):
    models = draw(model_profiles())
    fps = draw(st.sampled_from([10.0, 20.0, 30.0, 50.0]))
    mbps = draw(st.floats(0.3, 8.0))
    rtt = draw(st.floats(10.0, 150.0))
    return models, StreamSpec(fps=fps), network_mbps(mbps, rtt_ms=rtt)


@given(scenario())
@SETTINGS
def test_max_accuracy_plans_feasible(s):
    models, stream, net = s
    for npu_free in (0.0, 0.05):
        plan = max_accuracy.plan_round(models, stream, net, npu_free=npu_free)
        # npu_free shifts the NPU availability; frames must still meet deadlines
        errors = validate_plan(plan, gamma=stream.gamma, deadline=stream.deadline)
        assert not errors, errors


@given(scenario())
@SETTINGS
def test_max_utility_plans_feasible(s):
    models, stream, net = s
    for alpha in (50.0, 200.0):
        plan = max_utility.plan_round(models, stream, net, alpha=alpha, npu_free=0.0)
        errors = validate_plan(plan, gamma=stream.gamma, deadline=stream.deadline)
        assert not errors, errors


@given(scenario())
@SETTINGS
def test_max_accuracy_dominates_baselines(s):
    models, stream, net = s
    tr = Trace(lambda t: net.bandwidth_bps, lambda t: net.rtt)
    n = 60
    acc_ma = simulate(make_policy("max_accuracy"), models, stream, tr, n).mean_accuracy
    acc_lo = simulate(make_policy("local"), models, stream, tr, n).mean_accuracy
    acc_of = simulate(make_policy("offload"), models, stream, tr, n).mean_accuracy
    assert acc_ma >= acc_lo - 1e-6
    assert acc_ma >= acc_of - 1e-6


@given(scenario())
@SETTINGS
def test_max_utility_dominates_local(s):
    """Max-Utility contains a Local-equivalent candidate per round, so it can
    only trail Local through round-BOUNDARY effects (the NPU-backlog state at
    which each policy happens to re-plan differs).  Bound that slack at 1%;
    on the paper's own profiles the dominance is exact (see
    test_paper_claims_reproduce)."""
    models, stream, net = s
    tr = Trace(lambda t: net.bandwidth_bps, lambda t: net.rtt)
    for alpha in (50.0, 200.0):
        u_mu = simulate(make_policy("max_utility", alpha=alpha), models, stream, tr, 60).utility(alpha)
        u_lo = simulate(make_policy("local", alpha=alpha), models, stream, tr, 60).utility(alpha)
        assert u_mu >= u_lo * 0.99 - 1e-5


@given(scenario())
@SETTINGS
def test_policies_below_exhaustive_optimum(s):
    models, stream, net = s
    n = 4
    opt = brute_force.exhaustive_best(models, stream, net, n)
    tr = Trace(lambda t: net.bandwidth_bps, lambda t: net.rtt)
    acc_ma = simulate(make_policy("max_accuracy"), models, stream, tr, n).mean_accuracy
    assert acc_ma <= opt + 1e-6
    alpha = 100.0
    opt_u = brute_force.exhaustive_best(models, stream, net, n, alpha=alpha)
    u_mu = simulate(make_policy("max_utility", alpha=alpha), models, stream, tr, n).utility(alpha)
    assert u_mu <= opt_u + 1e-4


@given(scenario())
@SETTINGS
def test_grid_dp_below_exhaustive(s):
    models, stream, net = s
    n = 4
    exh = brute_force.exhaustive_best(models, stream, net, n)
    grid = brute_force.optimal_accuracy(models, stream, net, n, grid=1e-3)
    assert grid <= exh + 1e-6
    # and converges from below with a fine grid
    assert grid >= exh - 0.25


@given(scenario(), st.integers(1, 8))
@SETTINGS
def test_jax_dps_match_python(s, n_frames):
    from repro.core.jax_sched import local_accuracy_dp_jax, local_utility_dp_jax
    from repro.core.max_accuracy import local_dp
    from repro.core.max_utility import local_utility_dp

    models, stream, net = s
    gamma, T = stream.gamma, stream.deadline
    py = local_dp(models, n_frames=n_frames, gamma=gamma, deadline=T, npu_free=0.0, first_arrival=gamma)
    jt, jm = local_accuracy_dp_jax(
        models, n_frames=n_frames, gamma=gamma, deadline=T, npu_free=0.0, first_arrival=gamma
    )
    if py.feasible:
        assert abs(py.total_accuracy - jt) < 1e-4
    else:
        assert jt < -1e17

    w = n_frames * gamma
    alpha = 100.0
    pu = local_utility_dp(
        models, n_frames=n_frames, gamma=gamma, deadline=T, alpha=alpha, npu_free=0.0,
        first_arrival=0.0, window=w,
    )
    ju, jd = local_utility_dp_jax(
        models, n_frames=n_frames, gamma=gamma, deadline=T, alpha=alpha, npu_free=0.0,
        first_arrival=0.0, window=w,
    )
    # The f32 DP may pick a boundary-different schedule; the property that
    # matters: its schedule is feasible and achieves the same utility when
    # re-evaluated in f64.
    t = 0.0
    acc_sum, m_count = 0.0, 0
    for k, j in jd:
        arrival = k * gamma
        start = max(t, arrival)
        t = start + models[j].t_npu
        assert t <= arrival + T + 1e-5, "JAX schedule infeasible"
        acc_sum += models[j].acc_npu[224]
        m_count += 1
    ju64 = (m_count / w + alpha * acc_sum / m_count) if m_count else 0.0
    assert ju64 >= pu.utility - max(1e-3, 1e-3 * abs(pu.utility))
    assert ju64 <= pu.utility + max(1e-3, 1e-3 * abs(pu.utility))


def test_dominance_pruning_is_lossless():
    """The pruned DP must equal brute-force enumeration over local subsets."""
    models = list(PAPER_MODELS)
    stream = StreamSpec(fps=30)
    gamma, T, alpha = stream.gamma, stream.deadline, 150.0
    n = 6
    w = n * gamma

    from itertools import product

    best = 0.0
    local_models = [j for j, m in enumerate(models) if m.runs_local]
    for choice in product([None, *local_models], repeat=n):
        t = 0.0
        acc, m_count = 0.0, 0
        ok = True
        for k, j in enumerate(choice):
            if j is None:
                continue
            arrival = k * gamma
            start = max(t, arrival)
            t = start + models[j].t_npu
            if t > arrival + T + 1e-12:
                ok = False
                break
            acc += models[j].acc_npu[224]
            m_count += 1
        if ok and m_count:
            best = max(best, m_count / w + alpha * acc / m_count)
    from repro.core.max_utility import local_utility_dp

    dp = local_utility_dp(
        models, n_frames=n, gamma=gamma, deadline=T, alpha=alpha, npu_free=0.0,
        first_arrival=0.0, window=w,
    )
    assert dp.utility == pytest.approx(best, abs=1e-6)


def test_paper_claims_reproduce():
    """Quantitative claims from §VI with the paper's own profile constants."""
    models = list(PAPER_MODELS)
    stream = StreamSpec(fps=30)
    # Offload collapses when it cannot sustain the frame rate (Fig. 5b).
    st_off = simulate(make_policy("offload"), models, stream, Trace.constant(0.5), 120)
    assert st_off.mean_accuracy == 0.0
    # Local == Max-Accuracy at low bandwidth; Max-Accuracy wins at high B (Fig. 5).
    lo = simulate(make_policy("local"), models, stream, Trace.constant(1.0), 120).mean_accuracy
    ma_low = simulate(make_policy("max_accuracy"), models, stream, Trace.constant(1.0), 120).mean_accuracy
    ma_high = simulate(make_policy("max_accuracy"), models, stream, Trace.constant(3.5), 120).mean_accuracy
    assert ma_low == pytest.approx(lo, abs=1e-6)
    assert ma_high >= ma_low
    # DeepDecision under-utilizes the NPU vs Local at 30fps (paper §VI.C).
    dd = simulate(make_policy("deepdecision"), models, stream, Trace.constant(1.0), 120).mean_accuracy
    assert lo > dd
    # Max-Accuracy ~= Optimal (Fig. 7b) on the grid DP.
    opt = brute_force.optimal_accuracy(models, stream, network_mbps(2.5), 30, grid=2e-3)
    ma = simulate(make_policy("max_accuracy"), models, stream, Trace.constant(2.5), 30).mean_accuracy
    assert abs(opt - ma) < 0.05
