"""The shape-bucketing policy contract (repro/core/bucketing.py).

Every quantizer the batched engines compile against must (a) never shrink,
(b) be monotone, and (c) be idempotent on its own outputs — together these
guarantee padding is always an over-approximation, bigger scenarios never
land in smaller buckets, and bucket sizes are fixed points so repeated
sweeps hash to the same executables.  The properties run exhaustively over
a dense range everywhere, and as hypothesis properties over 1..10^6 where
hypothesis is installed (CI).  The executable-reuse regression at the
bottom closes the loop: two sweeps differing only *within* one bucket must
not trigger a single new XLA compile.
"""
from __future__ import annotations

import pytest

from repro.core.bucketing import W_LADDER, quant_bins, quant_pow2, quant_w

QUANTIZERS = {
    "quant_w": quant_w,
    "quant_bins": quant_bins,
    "quant_bins_q32": lambda n: quant_bins(n, 32),
    "quant_pow2": quant_pow2,
}

# Exhaustive over the dense operating range (every window/horizon the
# engines see in practice), plus spot checks far past it.
DENSE = list(range(1, 2049)) + [10_000, 65_537, 1_000_000]


@pytest.mark.parametrize("name", sorted(QUANTIZERS))
def test_never_shrinks_and_idempotent_dense(name):
    q = QUANTIZERS[name]
    for n in DENSE:
        qn = q(n)
        assert qn >= n, (name, n)
        assert q(qn) == qn, (name, n)


@pytest.mark.parametrize("name", sorted(QUANTIZERS))
def test_monotone_dense(name):
    q = QUANTIZERS[name]
    prev = 0
    for n in range(1, 2049):
        qn = q(n)
        assert qn >= prev, (name, n)
        prev = qn
    assert q(10_000) >= prev


try:  # hypothesis widens the range in CI; the dense tests always run
    from hypothesis import given, settings
    from hypothesis import strategies as st

    ns = st.integers(min_value=1, max_value=1_000_000)

    @pytest.mark.parametrize("name", sorted(QUANTIZERS))
    @settings()
    @given(n=ns)
    def test_never_shrinks_property(name, n):
        assert QUANTIZERS[name](n) >= n

    @pytest.mark.parametrize("name", sorted(QUANTIZERS))
    @settings()
    @given(n=ns)
    def test_idempotent_property(name, n):
        q = QUANTIZERS[name]
        assert q(q(n)) == q(n)

    @pytest.mark.parametrize("name", sorted(QUANTIZERS))
    @settings()
    @given(m=ns, n=ns)
    def test_monotone_property(name, m, n):
        q = QUANTIZERS[name]
        lo, hi = sorted((m, n))
        assert q(lo) <= q(hi)

except ImportError:
    pass


def test_ladder_values_are_fixed_points():
    for w in W_LADDER:
        assert quant_w(w) == w
    assert quant_bins(128) == 128 and quant_bins(129) == 256
    assert quant_bins(32, 32) == 32 and quant_bins(33, 32) == 64
    assert quant_pow2(1) == 1 and quant_pow2(5) == 8


def test_engines_share_the_bucketing_module():
    """Both engines must quantize through the one documented policy, not
    private copies — the aliases are the module's functions themselves."""
    from repro.core import sim_batch, sim_multi_batch

    assert sim_batch._quant_w is quant_w
    assert sim_batch._quant_bins is quant_bins
    assert sim_batch._quant_pow2 is quant_pow2
    assert sim_multi_batch._quant_w is quant_w
    assert sim_multi_batch._quant_bins is quant_bins


def test_same_bucket_sweeps_reuse_executable():
    """Two sweeps whose shapes differ only within one bucket (deadline 150
    vs 152 ms: same quantized window, same quantized bin count) must reuse
    the compiled executable — zero new XLA compiles on the second run,
    counted via jax's own monitoring events."""
    from repro.core import sim_batch
    from repro.core.compile_cache import CompileCounter
    from repro.core.registry import PolicySpec
    from repro.session import ScenarioSpec, Session, SweepGrid

    spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=10)
    with CompileCounter():
        warm = Session(spec).run_sweep(
            SweepGrid(deadline_ms=(150.0,), fps=(30.0,)), backend="batched"
        )
    assert warm.backend == "batched"
    factory_size = sim_batch._accuracy_program.cache_info().currsize
    with CompileCounter() as c2:
        rerun = Session(spec).run_sweep(
            SweepGrid(deadline_ms=(152.0,), fps=(30.0,)), backend="batched"
        )
    assert rerun.backend == "batched"
    assert rerun.points[0].stats.frames_processed > 0
    # same bucket => same program factory key => same jitted executable
    assert sim_batch._accuracy_program.cache_info().currsize == factory_size
    assert c2.backend_compiles == 0 and c2.compiles == 0
