import os
import sys
from pathlib import Path

import pytest

# Tests must see the real single CPU device (the dry-run sets 512 in its own
# process); make sure no leaked XLA_FLAGS reach us.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# ---------------------------------------------------------------------------
# Shared hypothesis profiles.  Property-test modules use the *active* profile
# (``SETTINGS = settings()``) instead of hard-coding example counts, so one
# env var switches the whole suite's thoroughness:
#
#   tier-1 fast lane (default) ...... HYPOTHESIS_PROFILE=ci       (15 examples)
#   CI nightly / full matrix ........ HYPOTHESIS_PROFILE=nightly (150 examples)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, settings

    _COMMON = dict(deadline=None, suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("ci", max_examples=15, **_COMMON)
    settings.register_profile("nightly", max_examples=150, **_COMMON)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # property-test modules importorskip hypothesis themselves
    pass


# ---------------------------------------------------------------------------
# ``slow`` marker: heavy tests (multi-second jit compiles, end-to-end serving,
# large golden grids) are excluded from the tier-1 fast lane so a local
# ``pytest -x -q`` stays well under two minutes.  CI's full matrix runs them
# with ``--runslow`` (or RUN_SLOW=1).
# ---------------------------------------------------------------------------


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="run tests marked slow (the CI full matrix)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy test excluded from the tier-1 fast lane "
        "(enable with --runslow or RUN_SLOW=1)",
    )


def run_slow_enabled(value: str | None) -> bool:
    """Interpret the RUN_SLOW env var: unset / empty / common falsy spellings
    ("0", "false", "no", "off", any case, surrounding whitespace) leave the
    fast lane on; anything else enables the slow tests.  Kept as a pure
    helper so CI forks can't silently regress the truthiness rules (see the
    regression tests in test_conftest_runslow.py)."""
    return (value or "").strip().lower() not in ("", "0", "false", "no", "off")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow") or run_slow_enabled(os.environ.get("RUN_SLOW")):
        return
    skip_slow = pytest.mark.skip(reason="slow: excluded from the fast lane (use --runslow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
