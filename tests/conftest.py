import os
import sys
from pathlib import Path

# Tests must see the real single CPU device (the dry-run sets 512 in its own
# process); make sure no leaked XLA_FLAGS reach us.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
