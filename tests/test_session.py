"""Front-door tests: policy registry, declarative specs, and the Session
facade.

Three layers of coverage:
  * registry — registration/lookup, strict parameter validation (unknown
    name, unknown param, missing required param, wrong type all raise
    ``ValueError`` at spec-construction time);
  * serialization — ``PolicySpec`` and ``ScenarioSpec`` (incl. fleet,
    piecewise trace, custom model profile) round-trip through JSON;
  * golden equivalence — ``Session.run_sim`` reproduces the legacy
    ``simulate(make_policy(...))`` stats exactly for EVERY registered
    policy, so the front door never drifts from the audited simulator.
"""
from __future__ import annotations

import json

import pytest

from repro.core import (
    PAPER_MODELS,
    PAPER_STREAM,
    OnlineController,
    PolicySpec,
    StreamSpec,
    Trace,
    WorkloadSpec,
    make_policy,
    profile_ms,
    simulate,
    simulate_multi,
)
from repro.core.edge_server import EdgeServerScheduler, make_fleet
from repro.core.registry import Param, available_policies, get_policy, register_policy
from repro.session import FleetSpec, RunReport, ScenarioSpec, Session, TraceSpec

# Every registered policy with the params a sweep would use.  The golden
# test below iterates available_policies() and fails if something registers
# without being added here — new policies must join the equivalence sweep.
POLICY_PARAMS: dict[str, dict] = {
    "max_accuracy": {},
    "max_utility": {"alpha": 200.0},
    "local": {},
    "offload": {},
    "deepdecision": {},
    "brute_force": {},
    "jax_accuracy": {},
    "jax_utility": {"alpha": 200.0},
    "track_accuracy": {},
    "track_fixed": {"k": 3},
}

# Policies that plan the detect+track workload — their golden runs carry a
# tracking WorkloadSpec (the registry gate refuses the classify default).
TRACK_POLICIES = frozenset({"track_accuracy", "track_fixed"})


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_all_paper_policies_registered():
    names = available_policies()
    for expect in POLICY_PARAMS:
        assert expect in names
    entry = get_policy("max_utility")
    assert entry.fn is not None and entry.param("alpha").required


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("definitely_not_a_policy")
    with pytest.raises(ValueError, match="unknown policy"):
        PolicySpec("definitely_not_a_policy")


def test_unknown_param_is_hard_error():
    with pytest.raises(ValueError, match="accepts no parameter"):
        PolicySpec("max_accuracy", {"alpha": 200.0})
    with pytest.raises(ValueError, match="accepts no parameter"):
        make_policy("max_accuracy", alpha=200.0)


def test_missing_required_param_raises_value_error():
    # The legacy code path asserted; the registry raises a proper ValueError.
    with pytest.raises(ValueError, match="requires parameter 'alpha'"):
        PolicySpec("max_utility")
    with pytest.raises(ValueError, match="requires parameter 'alpha'"):
        make_policy("max_utility")


def test_param_type_checked():
    with pytest.raises(ValueError, match="expects"):
        PolicySpec("max_utility", {"alpha": "two hundred"})
    with pytest.raises(ValueError, match="expects"):
        PolicySpec("local", {"window_frames": 2.5})
    # nullable param accepts None; non-nullable rejects it
    PolicySpec("local", {"alpha": None})
    with pytest.raises(ValueError, match="must not be None"):
        PolicySpec("max_utility", {"alpha": None})


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):

        @register_policy("max_accuracy", params=(Param.number("grid", 1e-3),))
        def impostor(models, stream, net, *, npu_free=0.0, grid=1e-3):  # pragma: no cover
            raise AssertionError


def test_defaults_resolved_into_spec():
    spec = PolicySpec("deepdecision")
    assert spec.params == {"alpha": None, "window_s": 1.0}
    assert spec == PolicySpec("deepdecision", {"window_s": 1.0})


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------


def test_policy_spec_json_round_trip():
    for name, params in POLICY_PARAMS.items():
        spec = PolicySpec(name, params)
        assert PolicySpec.from_json(json.loads(json.dumps(spec.to_json()))) == spec


def test_scenario_spec_json_round_trip():
    custom = profile_ms(
        "tiny", t_npu_ms=5.0, acc_npu={224: 0.3}, acc_server={45: 0.1, 224: 0.4}
    )
    spec = ScenarioSpec(
        policy=PolicySpec("max_utility", {"alpha": 50.0}),
        n_frames=42,
        stream=StreamSpec(fps=25.0, deadline=0.15),
        models=("resnet-50", custom),
        trace=TraceSpec(kind="piecewise", rtt_ms=80.0, points=((0.0, 3.5), (2.0, 0.8))),
        fleet=FleetSpec(n_clients=3, allocation="priority", capacity=2,
                        priorities=(0, 1, 2)),
        strict=False,
        seed=7,
        label="round-trip",
    )
    rt = ScenarioSpec.from_json(json.dumps(spec.to_json()))
    assert rt == spec
    assert rt.models[1].t_npu == pytest.approx(5e-3)
    assert rt.models[1].acc_server == {45: 0.1, 224: 0.4}


def test_policy_spec_hashable_and_trace_spec_normalizes():
    # Frozen specs must be usable as dict keys / set members for sweep dedup.
    assert hash(PolicySpec("max_accuracy")) == hash(PolicySpec("max_accuracy", {"grid": 1e-3}))
    assert len({PolicySpec("local"), PolicySpec("local")}) == 1
    # Fields the active trace kind does not use are normalized away, so the
    # JSON round-trip (which only serializes active fields) stays exact.
    t = TraceSpec(kind="piecewise", mbps=9.9, points=((0.0, 3.5),))
    assert TraceSpec.from_json(t.to_json()) == t
    c = TraceSpec(kind="constant", points=((0.0, 1.0),))
    assert c.points == () and TraceSpec.from_json(c.to_json()) == c


def test_piecewise_trace_validation_errors():
    """Non-monotonic time points or negative bandwidth raise one-line
    ``ValueError``s — at spec construction AND in ``Trace.piecewise``
    itself, so malformed traces never become nonsense lookups
    mid-simulation."""
    for bad_points in (((0.0, 3.0), (0.0, 1.0)), ((0.5, 3.0), (0.2, 1.0))):
        with pytest.raises(ValueError, match="strictly increasing"):
            TraceSpec(kind="piecewise", points=bad_points)
        with pytest.raises(ValueError, match="strictly increasing"):
            Trace.piecewise(list(bad_points))
    with pytest.raises(ValueError, match=">= 0 Mbps"):
        TraceSpec(kind="piecewise", points=((0.0, 3.0), (1.0, -0.5)))
    with pytest.raises(ValueError, match=">= 0 Mbps"):
        Trace.piecewise([(0.0, -1.0)])
    # ...and a zero-bandwidth (dead link) segment stays legal
    assert TraceSpec(kind="piecewise", points=((0.0, 0.0),)).build().at(0.0)


def test_session_cli_bad_trace_is_exit_2(tmp_path, capsys):
    """A spec with a malformed piecewise trace exits 2 with a one-line
    ``error: ...`` on stderr — the validation surfaces through the CLI,
    never as a traceback."""
    from repro.session import main

    bad = tmp_path / "bad_trace.json"
    bad.write_text(json.dumps({
        "policy": {"name": "local"},
        "trace": {"kind": "piecewise", "rtt_ms": 50.0,
                  "points": [[0.0, 3.0], [0.0, 1.0]]},
    }))
    assert main([str(bad)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "strictly increasing" in err
    assert "Traceback" not in err and err.strip().count("\n") == 0


def test_scenario_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown trace kind"):
        TraceSpec(kind="sinusoid")
    with pytest.raises(ValueError, match="piecewise trace needs"):
        TraceSpec(kind="piecewise")
    with pytest.raises(ValueError, match="unknown allocation"):
        FleetSpec(allocation="round_robin")
    with pytest.raises(ValueError, match="n_clients=2 entries"):
        FleetSpec(n_clients=2, weights=(1.0,))
    with pytest.raises(ValueError, match="unknown model preset"):
        ScenarioSpec(policy=PolicySpec("local"), models=("alexnet",))
    with pytest.raises(ValueError, match="n_frames"):
        ScenarioSpec(policy=PolicySpec("local"), n_frames=0)


# ---------------------------------------------------------------------------
# Golden equivalence: front door == legacy path, for every policy
# ---------------------------------------------------------------------------

GOLD_FRAMES = 24


@pytest.mark.parametrize("name", sorted(POLICY_PARAMS))
def test_run_sim_matches_legacy_simulate_exactly(name):
    params = POLICY_PARAMS[name]
    workload = WorkloadSpec("track" if name in TRACK_POLICIES else "classify")
    legacy = simulate(
        make_policy(name, **params),
        list(PAPER_MODELS),
        PAPER_STREAM,
        Trace.constant(2.5),
        GOLD_FRAMES,
        workload=workload,
    )
    report = Session(
        ScenarioSpec(
            policy=PolicySpec(name, params), n_frames=GOLD_FRAMES,
            trace=TraceSpec(mbps=2.5), workload=workload,
        )
    ).run_sim()
    st = report.stats
    assert st.accuracy_sum == legacy.accuracy_sum  # bit-identical, not approx
    assert st.frames_processed == legacy.frames_processed
    assert st.frames_missed_deadline == legacy.frames_missed_deadline
    assert st.frames_offloaded == legacy.frames_offloaded
    assert st.frames_total == legacy.frames_total == GOLD_FRAMES


def test_every_registered_policy_is_in_golden_sweep():
    assert set(available_policies()) == set(POLICY_PARAMS)


# ---------------------------------------------------------------------------
# Session modes
# ---------------------------------------------------------------------------


def test_run_multi_matches_direct_scheduler_path():
    fleet = FleetSpec(n_clients=3, allocation="weighted_fair", capacity=4)
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=GOLD_FRAMES,
        trace=TraceSpec(mbps=12.0),
        fleet=fleet,
    )
    rep = Session(spec).run_multi()
    assert rep.mode == "multi" and len(rep.streams) == 3

    sched = EdgeServerScheduler(
        make_fleet(3, policy=PolicySpec("max_accuracy")), policy="weighted_fair", capacity=4
    )
    ms = simulate_multi(sched, Trace.constant(12.0), GOLD_FRAMES)
    for got, want in zip(rep.streams, ms.per_client):
        assert got.accuracy_sum == want.accuracy_sum
        assert got.frames_missed_deadline == want.frames_missed_deadline
    assert rep.meta["server_jobs"] == ms.server_jobs


def test_run_online_audits_against_true_trace():
    # Bandwidth halves after 1 s; the estimator must adapt and the audit must
    # never report more processed frames than exist.
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=90,
        trace=TraceSpec(kind="piecewise", points=((0.0, 3.5), (1.0, 0.8))),
    )
    rep = Session(spec).run_online()
    st = rep.stats
    assert rep.mode == "online"
    assert st.frames_total == 90
    assert 0 < st.frames_processed <= 90
    assert st.frames_processed + st.frames_missed_deadline <= 90 + st.frames_offloaded
    assert rep.meta["rounds"] == st.schedule_calls > 0
    assert rep.meta["estimated_bps"] < 3.5e6  # belief moved off the initial value


def test_run_dispatch_and_report_json():
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=12)
    rep = Session(spec).run("sim")
    assert isinstance(rep, RunReport)
    payload = json.loads(json.dumps(rep.to_json()))
    assert payload["mode"] == "sim"
    assert payload["streams"][0]["frames_total"] == 12
    with pytest.raises(ValueError, match="unknown mode"):
        Session(spec).run("warp")


def test_session_cli_smoke(tmp_path, capsys):
    from repro.session import main

    spec_file = tmp_path / "scenario.json"
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=12, label="cli-smoke")
    spec_file.write_text(json.dumps(spec.to_json()))
    assert main([str(spec_file), "--mode", "sim"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["label"] == "cli-smoke" and out["streams"][0]["frames_total"] == 12
    assert main(["--list-policies"]) == 0
    assert set(capsys.readouterr().out.split()) == set(available_policies())


def test_session_cli_invalid_spec_is_one_line_error(tmp_path, capsys):
    """A malformed / unknown-policy spec exits 2 with ``error: ...`` on
    stderr — never a traceback (the CLI is a CI smoke surface)."""
    from repro.session import main

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"policy": {"name": "definitely_not_a_policy"}}))
    assert main([str(bad)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "unknown policy" in err
    assert "Traceback" not in err and err.strip().count("\n") == 0

    bad.write_text("{not json")
    assert main([str(bad)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "Traceback" not in err

    assert main([str(tmp_path / "missing.json")]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "Traceback" not in err


def test_make_policy_emits_deprecation_warning():
    with pytest.warns(DeprecationWarning, match="make_policy"):
        make_policy("max_accuracy")
    # ...but still validates eagerly through the registry.
    with pytest.warns(DeprecationWarning, match="make_policy"):
        with pytest.raises(ValueError, match="requires parameter 'alpha'"):
            make_policy("max_utility")


# ---------------------------------------------------------------------------
# Retrofitted constructors
# ---------------------------------------------------------------------------


def test_controller_accepts_spec_and_legacy_kwargs():
    c1 = OnlineController(models=list(PAPER_MODELS), stream=PAPER_STREAM,
                          policy=PolicySpec("max_utility", {"alpha": 100.0}))
    c2 = OnlineController(models=list(PAPER_MODELS), stream=PAPER_STREAM,
                          policy_name="max_utility", alpha=100.0)
    assert c1.policy == c2.policy
    p1, p2 = c1.next_plan(0), c2.next_plan(0)
    assert [(d.frame, d.where, d.model) for d in p1.decisions] == [
        (d.frame, d.where, d.model) for d in p2.decisions
    ]
    with pytest.raises(ValueError, match="requires parameter 'alpha'"):
        OnlineController(models=list(PAPER_MODELS), stream=PAPER_STREAM,
                         policy_name="max_utility")


def test_edge_client_accepts_policy_spec():
    fleet = make_fleet(2, policy=PolicySpec("max_utility", {"alpha": 200.0}))
    assert all(c.policy.name == "max_utility" for c in fleet)
    legacy = make_fleet(2, policy_name="max_utility", alpha=200.0)
    assert [c.policy for c in fleet] == [c.policy for c in legacy]


def test_oracle_policy_upper_bounds_max_accuracy():
    """The brute-force oracle, run as a policy, must do at least as well as
    Max-Accuracy on the same trace (it searches a superset of schedules)."""
    kw = dict(models=list(PAPER_MODELS), stream=PAPER_STREAM)
    ma = simulate(make_policy("max_accuracy"), kw["models"], kw["stream"],
                  Trace.constant(2.5), GOLD_FRAMES)
    oracle = simulate(make_policy("brute_force", grid=2e-3), kw["models"], kw["stream"],
                      Trace.constant(2.5), GOLD_FRAMES)
    assert oracle.frames_missed_deadline == 0
    assert oracle.mean_accuracy >= ma.mean_accuracy - 1e-9
