"""Per-architecture smoke tests: every assigned arch (reduced config) runs a
forward/train step on CPU with correct output shapes and finite values —
plus LM decode==prefill consistency and the MoE dispatch invariants."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.arch import ShapeSpec
from repro.launch import steps
from repro.train.optim import AdamWConfig


def _smoke_shapes(fam: str) -> list[ShapeSpec]:
    if fam == "lm":
        return [
            ShapeSpec("t", "train", 2, seq=32),
            ShapeSpec("p", "prefill", 2, seq=32),
            ShapeSpec("d", "decode", 2, seq=32),
        ]
    if fam in ("dit", "flux"):
        return [
            ShapeSpec("t", "denoise_train", 2, img=64, steps=2),
            ShapeSpec("g", "denoise_step", 2, img=64, steps=2),
        ]
    return [
        ShapeSpec("t", "classify_train", 2, img=32),
        ShapeSpec("s", "classify_serve", 2, img=32),
    ]


def _all_finite(tree) -> bool:
    return all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.mark.slow
@pytest.mark.parametrize("arch_name", configs.ALL)
def test_arch_smoke(arch_name):
    a = configs.get(arch_name, smoke=True)
    shapes = _smoke_shapes(a.family)
    a2 = dataclasses.replace(a, shapes=tuple(shapes))
    for s in shapes:
        prog = steps.build_cell(a2, s.name, adamw=AdamWConfig(warmup_steps=1, total_steps=4))
        out = prog.jit()(*prog.init_args())
        assert _all_finite(out), f"{arch_name}/{s.kind} produced non-finite values"
        if s.kind == "train":
            _, metrics = out
            assert float(metrics["loss"]) > 0


def test_lm_decode_matches_prefill():
    from repro.models import lm
    from repro.models.common import init_tree

    a = configs.get("qwen3-0.6b", smoke=True)
    cfg = a.cfg
    params = init_tree(jax.random.key(0), lm.abstract_params(cfg))
    tokens = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    logits_p, _ = jax.jit(lambda p, t: lm.prefill(cfg, p, t, max_len=16))(params, tokens)
    cache = lm.make_cache(cfg, 2, 16)
    lg = None
    step = jax.jit(lambda p, t, c: lm.decode_step(cfg, p, t, c))
    for s in range(12):
        lg, cache = step(params, tokens[:, s : s + 1], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_p), rtol=2e-4, atol=2e-4)


def test_lm_train_loss_decreases():
    a = configs.get("qwen3-0.6b", smoke=True)
    a2 = dataclasses.replace(a, shapes=(ShapeSpec("t", "train", 4, seq=32),))
    prog = steps.build_cell(a2, "t", adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30))
    step = prog.jit()
    ts, batch = prog.init_args()
    losses = []
    for _ in range(15):
        ts, metrics = step(ts, batch)  # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_moe_dispatch_invariants():
    from repro.models import layers as L

    c = L.MoECfg(d_model=16, d_ff_expert=8, n_experts=4, top_k=2, capacity_factor=8.0)
    N = 12 * 2  # tokens * k
    eid = jax.random.randint(jax.random.key(0), (N,), 0, 4)
    cap = int(round(N / 4 * 8.0))
    token_idx, slot_valid, pos, kept = L._dispatch_indices(eid, 4, cap)
    # with a huge capacity factor nothing drops
    assert bool(jnp.all(kept))
    # every valid slot maps to a token routed to that expert
    for e in range(4):
        for ci in range(cap):
            if bool(slot_valid[e, ci]):
                assert int(eid[token_idx[e, ci]]) == e
    # and each kept token occupies exactly one valid slot
    filled = int(slot_valid.sum())
    assert filled == N


def test_moe_capacity_drops_tokens():
    from repro.models import layers as L

    eid = jnp.zeros((16,), jnp.int32)  # everything routed to expert 0
    token_idx, slot_valid, pos, kept = L._dispatch_indices(eid, 4, 4)
    assert int(kept.sum()) == 4  # capacity 4 -> 4 kept, 12 dropped
    assert int(slot_valid[0].sum()) == 4


def test_moe_matches_dense_when_single_expert():
    """1 expert + top-1 + ample capacity == plain SwiGLU with that expert."""
    from repro.models import layers as L
    from repro.models.common import init_tree

    c = L.MoECfg(d_model=32, d_ff_expert=64, n_experts=1, top_k=1, capacity_factor=2.0)
    p = init_tree(jax.random.key(0), L.moe_specs(c))
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    out, aux = L.moe(c, p, x)
    dense = L.swiglu(
        {
            "w_gate": p["experts"]["w_gate"][0],
            "w_up": p["experts"]["w_up"][0],
            "w_down": p["experts"]["w_down"][0],
        },
        x,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_quant_variant_close_but_not_equal():
    from repro import quant
    from repro.arch import classifier_forward
    from repro.arch import abstract_params as ap
    from repro.models.common import init_tree

    a = configs.get("resnet-50", smoke=True)
    specs, st_specs = ap(a)
    params = init_tree(jax.random.key(0), specs)
    state = init_tree(jax.random.key(1), st_specs)
    qparams, stats = quant.npu_variant(params)
    assert stats.leaves_quantized > 0
    assert 0 < stats.mean_rel_err < 0.05  # real but small int8 error
    x = jax.random.normal(jax.random.key(2), (2, 32, 32, 3))
    lo_fp, _ = classifier_forward(a, params, state, x, train=False)
    lo_q, _ = classifier_forward(a, qparams, state, x, train=False)
    assert not bool(jnp.allclose(lo_fp, lo_q))  # quantization does something
    rel = float(jnp.linalg.norm(lo_fp - lo_q) / jnp.maximum(jnp.linalg.norm(lo_fp), 1e-9))
    assert rel < 0.5


def test_int8_kv_cache_decode_close_to_fp():
    """Cell D (EXPERIMENTS §Perf): int8 KV decode tracks bf16 KV decode with
    ~1% logit error and identical top-1s on the smoke model."""
    import dataclasses as dc

    from repro.models import lm
    from repro.models.common import init_tree

    a = configs.get("qwen3-0.6b", smoke=True)
    cfg = a.cfg
    cfgq = dc.replace(cfg, kv_quant=True)
    params = init_tree(jax.random.key(0), lm.abstract_params(cfg))
    tokens = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab)
    c_fp = lm.make_cache(cfg, 2, 12)
    c_q = lm.make_cache(cfgq, 2, 12)
    step_fp = jax.jit(lambda p, t, c: lm.decode_step(cfg, p, t, c))
    step_q = jax.jit(lambda p, t, c: lm.decode_step(cfgq, p, t, c))
    lf = lq = None
    for s in range(10):
        lf, c_fp = step_fp(params, tokens[:, s : s + 1], c_fp)
        lq, c_q = step_q(params, tokens[:, s : s + 1], c_q)
    rel = float(jnp.linalg.norm(lf - lq) / jnp.linalg.norm(lf))
    assert 0 < rel < 0.05  # real but small quantization error
    assert bool(jnp.all(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
