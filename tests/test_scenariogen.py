"""Scenario-generator library: every kind lowers to a valid, JSON-stable
TraceSpec and runs through the engines' front door unchanged; the fault
generator's detected-outage window reflects the heartbeat monitor's real
detection lag."""
from __future__ import annotations

import json

import pytest

from repro import scenariogen
from repro.core import PAPER_MODELS, PAPER_STREAM, Trace, make_policy, simulate
from repro.scenariogen import dead_edge_models, degrade, edge_failure
from repro.session import ScenarioSpec, Session, SweepGrid, TraceSpec


def test_catalog_is_sorted_and_closed():
    kinds = scenariogen.trace_kinds()
    assert kinds == tuple(sorted(kinds))
    assert set(kinds) == set(scenariogen.TRACE_KINDS)
    with pytest.raises(ValueError, match="unknown scenario kind"):
        scenariogen.make_trace("tsunami")


@pytest.mark.parametrize("kind", scenariogen.trace_kinds())
def test_every_kind_yields_a_valid_json_stable_trace(kind):
    trace = scenariogen.make_trace(kind)
    assert isinstance(trace, TraceSpec)
    assert trace.kind == "piecewise"
    ts = [t for t, _ in trace.points]
    assert ts == sorted(ts) and len(ts) == len(set(ts))  # strictly increasing
    assert all(bw >= 0.0 for _, bw in trace.points)
    # the round trip is exact: a catalog entry pins its trace bit-for-bit
    back = TraceSpec.from_json(json.loads(json.dumps(trace.to_json())))
    assert back == trace


@pytest.mark.parametrize("kind", scenariogen.trace_kinds())
def test_generators_are_pure(kind):
    assert scenariogen.make_trace(kind) == scenariogen.make_trace(kind)


def test_mobility_square_shape():
    tr = scenariogen.make_trace(
        "mobility_square", high_mbps=3.0, low_mbps=1.0, period_s=2.0,
        duty=0.25, duration_s=4.0,
    )
    assert tr.points == ((0.0, 3.0), (0.5, 1.0), (2.0, 3.0), (2.5, 1.0))


def test_mobility_ramp_holds_peak_with_centered_dip():
    tr = scenariogen.make_trace(
        "mobility_ramp", low_mbps=1.0, high_mbps=4.0, ramp_s=3.0, hold_s=2.0,
        steps=4, dip_mbps=0.2, dip_s=1.0,
    )
    vals = dict(tr.points)
    assert vals[3.0] == 4.0  # peak opens the hold
    assert vals[3.5] == 0.2 and vals[4.5] == 4.0  # dip centered in the hold
    assert tr.points[-1][1] == 1.0  # staircase returns to low


def test_flash_crowd_events_never_overlap_and_seed_pins_the_trace():
    a = scenariogen.make_trace("flash_crowd", n_events=5, seed=7)
    b = scenariogen.make_trace("flash_crowd", n_events=5, seed=7)
    assert a == b
    assert a != scenariogen.make_trace("flash_crowd", n_events=5, seed=8)
    # alternating collapse/restore implies the events are disjoint
    levels = [bw for _, bw in a.points]
    for prev, cur in zip(levels, levels[1:]):
        assert prev != cur


def test_diurnal_respects_amplitude_bound():
    with pytest.raises(ValueError, match="amplitude_mbps"):
        scenariogen.make_trace("diurnal", base_mbps=1.0, amplitude_mbps=2.0)
    tr = scenariogen.make_trace("diurnal", base_mbps=2.0, amplitude_mbps=2.0)
    assert min(bw for _, bw in tr.points) >= 0.0
    assert tr.points[0][1] == pytest.approx(4.0)  # peak at t=0


def test_edge_failure_detection_lags_the_crash():
    rep = edge_failure(
        fail_at_s=4.0, recover_at_s=8.0, duration_s=16.0,
        interval_s=0.25, suspect_after=2.0, dead_after=4.0,
    )
    # last beat lands at 3.75; DEAD after 4 intervals (1 s) of silence
    assert rep.fail_at_s == 4.0
    assert rep.detected_at_s == 5.0
    assert rep.recovered_at_s == 8.0  # first post-recovery heartbeat
    assert ("suspect" in {s for _, s in rep.events})
    # the degraded window of the trace is the *detected* outage
    vals = dict(rep.trace.points)
    assert vals[5.0] == 0.05 and vals[8.0] == 3.5


def test_edge_failure_rejects_undetectable_outages():
    with pytest.raises(ValueError, match="outage too short"):
        edge_failure(fail_at_s=4.0, recover_at_s=4.5, duration_s=16.0,
                     interval_s=0.25, dead_after=8.0)
    with pytest.raises(ValueError, match="fail_at_s"):
        edge_failure(fail_at_s=5.0, recover_at_s=4.0)


def test_degrade_splices_windows_and_validates():
    base = TraceSpec(kind="constant", mbps=3.5, rtt_ms=80.0)
    tr = degrade(base, [(2.0, 5.0)], to_mbps=0.1)
    assert tr.points == ((0.0, 3.5), (2.0, 0.1), (5.0, 3.5))
    assert tr.rtt_ms == 80.0
    with pytest.raises(ValueError, match="start < end"):
        degrade(base, [(5.0, 2.0)])
    with pytest.raises(ValueError, match="overlap"):
        degrade(base, [(1.0, 4.0), (3.0, 6.0)])
    with pytest.raises(ValueError, match="to_mbps"):
        degrade(base, [(1.0, 2.0)], to_mbps=-1.0)


def test_degrade_overrides_base_points_inside_the_window():
    base = TraceSpec(kind="piecewise", points=((0.0, 3.0), (3.0, 1.0)), rtt_ms=100.0)
    tr = degrade(base, [(2.0, 4.0)], to_mbps=0.0)
    # the (3.0, 1.0) base point is swallowed; its value resumes at the end
    assert tr.points == ((0.0, 3.0), (2.0, 0.0), (4.0, 1.0))


def test_dead_edge_models_force_the_npu_path():
    dead = dead_edge_models(PAPER_MODELS)
    assert all(m.t_server == float("inf") for m in dead)
    st = simulate(
        make_policy("max_accuracy"), list(dead), PAPER_STREAM,
        Trace.constant(3.0), 60,
    )
    assert st.frames_offloaded == 0
    assert st.frames_processed == 60


def test_make_scenario_runs_through_the_front_door():
    spec = scenariogen.make_scenario(
        "mobility_square", policy="max_accuracy", n_frames=30, period_s=2.0
    )
    assert spec.label == "mobility_square"
    back = ScenarioSpec.from_json(json.loads(json.dumps(spec.to_json())))
    assert back.trace == spec.trace
    sim = Session(spec).run_sim()
    online = Session(spec).run_online()
    assert sim.streams[0].frames_total == online.streams[0].frames_total == 30
    assert online.meta["rounds"] == online.streams[0].schedule_calls


def test_generated_fault_scenario_sweeps_on_the_batched_backends():
    spec = scenariogen.make_scenario(
        "edge_failure", policy={"name": "max_accuracy", "params": {"grid": 0.01}},
        n_frames=45, fail_at_s=1.0, recover_at_s=2.0, duration_s=4.0,
        suspect_after=1.0, dead_after=2.0,
    )
    grid = SweepGrid(rtt_ms=(60.0, 100.0))
    oracle = Session(spec).run_sweep(grid, backend="batched")
    online = Session(spec).run_sweep(grid, backend="batched", mode="online")
    assert oracle.meta["engine"] == "sim_batch"
    assert online.meta["engine"] == "sim_online_batch"
    assert len(oracle.points) == len(online.points) == 2
