"""Hypothesis property tests for the EdgeServerScheduler contract.

The scheduler is mechanism only — it grants (bandwidth, slot) leases — so
its invariants are statable without any simulation:

  * the sum of link-active lease bandwidth never exceeds the link, no
    matter what op sequence drove the scheduler there (weighted_fair and
    priority; fifo deliberately oversubscribes);
  * with a clean scheduler, weighted_fair grants are weight-proportional
    within float rounding;
  * priority never hands a slot-consuming grant to a lower class while the
    free slots are all spoken for by slotless higher-priority clients
    ("no starvation of the higher class");
  * ``release``/``release_link``/``reset`` return the scheduler to a clean
    state: every lease freed, the backlog estimate cleared, and a fresh
    allocate behaving exactly like a new scheduler's.

Random op sequences are the point: the simulator only ever drives the
scheduler through one well-behaved call pattern, while these tests
interleave allocate/register/release_link/release arbitrarily.
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import EdgeServerScheduler, make_fleet, network_mbps  # noqa: E402
from repro.core.edge_server import effective_weight, fair_share  # noqa: E402

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()

MBPS = 10.0


@st.composite
def fleet_configs(draw):
    n = draw(st.integers(1, 6))
    weights = draw(
        st.lists(st.floats(0.1, 8.0), min_size=n, max_size=n)
    )
    priorities = draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
    capacity = draw(st.integers(0, 5))
    policy = draw(st.sampled_from(("weighted_fair", "priority")))
    return n, weights, priorities, capacity, policy


@st.composite
def op_sequences(draw):
    """(kind, client) ops; clients resolved modulo fleet size at replay."""
    return draw(
        st.lists(
            st.tuples(
                st.sampled_from(("allocate", "release_link", "release")),
                st.integers(0, 5),
            ),
            max_size=40,
        )
    )


def _build(config):
    n, weights, priorities, capacity, policy = config
    fleet = make_fleet(n, weights=weights, priorities=priorities)
    return fleet, EdgeServerScheduler(fleet, policy=policy, capacity=capacity)


def _replay(sched, fleet, ops, net):
    """Drive the scheduler through an arbitrary op sequence; every granted
    allocate immediately registers (the worst case for reservation)."""
    t = 0.0
    for kind, idx in ops:
        cid = fleet[idx % len(fleet)].client_id
        if kind == "allocate":
            grant = sched.allocate(cid, t, net)
            if grant > 0.0:
                sched.register(cid, grant, t=t, server_s=0.05)
        elif kind == "release_link":
            sched.release_link(cid)
        else:
            sched.release(cid)
        t += 0.01


@SETTINGS
@given(fleet_configs(), op_sequences())
def test_link_reservation_never_exceeds_capacity(config, ops):
    fleet, sched = _build(config)
    net = network_mbps(MBPS)
    for kind, idx in ops:
        cid = fleet[idx % len(fleet)].client_id
        if kind == "allocate":
            grant = sched.allocate(cid, 0.0, net)
            if grant > 0.0:
                sched.register(cid, grant)
            # The invariant must hold after EVERY mutation, not just at end.
            assert sched._link_reserved() <= net.bandwidth_bps + 1e-6
            assert sched._n_leases() <= sched.capacity + len(fleet)
        elif kind == "release_link":
            sched.release_link(cid)
        else:
            sched.release(cid)
    assert sched._link_reserved() <= net.bandwidth_bps + 1e-6
    assert sched.audit.max_concurrent_bps <= net.bandwidth_bps + 1e-6


@SETTINGS
@given(fleet_configs())
def test_clean_scheduler_grants_are_weight_proportional(config):
    n, weights, priorities, capacity, _ = config
    fleet = make_fleet(n, weights=weights, priorities=priorities)
    sched = EdgeServerScheduler(fleet, policy="weighted_fair", capacity=max(capacity, 1))
    net = network_mbps(MBPS)
    total = sum(weights)
    for c in fleet:
        grant = sched.allocate(c.client_id, 0.0, net)
        # Nothing is leased (grants are quotes until register), so every
        # client sees exactly its static share.
        assert grant == pytest.approx(
            fair_share(net.bandwidth_bps, c.weight, total), rel=1e-12
        )
    # And the shares are mutually proportional within rounding.
    g0 = sched.allocate(fleet[0].client_id, 0.0, net)
    for c in fleet[1:]:
        g = sched.allocate(c.client_id, 0.0, net)
        assert g * fleet[0].weight == pytest.approx(g0 * c.weight, rel=1e-9)


@SETTINGS
@given(fleet_configs(), op_sequences())
def test_priority_reserves_slots_for_higher_classes(config, ops):
    """Whenever the priority policy grants a slot-consuming lease, the free
    slots before that grant must exceed the number of slotless strictly
    higher-priority clients — otherwise the higher class could starve."""
    n, weights, priorities, capacity, _ = config
    fleet = make_fleet(n, weights=weights, priorities=priorities)
    sched = EdgeServerScheduler(fleet, policy="priority", capacity=capacity)
    net = network_mbps(MBPS)
    for kind, idx in ops:
        c = fleet[idx % len(fleet)]
        if kind == "allocate":
            free_before = sched.capacity - sched._n_leases()
            higher_waiting = sum(
                1
                for other in fleet
                if other.priority > c.priority
                and not sched.leases.get(other.client_id)
            )
            grant = sched.allocate(c.client_id, 0.0, net)
            if grant > 0.0:
                assert free_before > higher_waiting, (
                    f"client p={c.priority} got a slot while {higher_waiting} "
                    f"higher-priority clients waited on {free_before} free slots"
                )
                sched.register(c.client_id, grant)
        elif kind == "release_link":
            sched.release_link(c.client_id)
        else:
            sched.release(c.client_id)


@SETTINGS
@given(fleet_configs(), op_sequences())
def test_release_and_reset_restore_clean_state(config, ops):
    fleet, sched = _build(config)
    net = network_mbps(MBPS)
    _replay(sched, fleet, ops, net)

    # Releasing every lease one by one empties the table completely.
    for c in fleet:
        while sched.leases.get(c.client_id):
            sched.release_link(c.client_id)
            sched.release(c.client_id)
    assert sched._n_leases() == 0
    assert sched.leases == {}
    assert sched._link_reserved() == 0.0

    # reset() additionally clears the backlog estimate and audit counters,
    # and a fresh allocate matches a brand-new scheduler's bit for bit.
    _replay(sched, fleet, ops, net)
    sched.reset()
    assert sched.leases == {}
    assert sched.server_busy_until == 0.0
    assert sched.audit.grants == 0 and sched.audit.denials == 0
    _, fresh = _build(config)
    for c in fleet:
        assert sched.allocate(c.client_id, 0.0, net) == fresh.allocate(
            c.client_id, 0.0, net
        )


def test_effective_weight_matches_scheduler():
    fleet = make_fleet(3, weights=[1.0, 2.0, 4.0], priorities=[0, 1, 2])
    sched = EdgeServerScheduler(fleet, policy="priority")
    for c in fleet:
        assert sched._effective_weight(c) == effective_weight("priority", c.weight, c.priority)
        assert effective_weight("weighted_fair", c.weight, c.priority) == c.weight
