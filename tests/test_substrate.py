"""Substrate tests: optimizer, checkpoint (sync/async/restart determinism),
data pipeline skip-ahead, fault-tolerance units, elastic remesh planning,
sharding rules."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.arch import ShapeSpec
from repro.launch import steps
from repro.train import optim


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    c = optim.AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0, grad_clip=0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = optim.init_opt_state(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = optim.adamw_update(c, params, g, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_bounds_update():
    c = optim.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = optim.init_opt_state(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, metrics = optim.adamw_update(c, params, g, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_lr_schedule_shape():
    c = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(optim.lr_at(c, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, rel=0.2)
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)
    assert lrs[3] < lrs[2]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tiny_state():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ck

    state = _tiny_state()
    ck.save(tmp_path, 3, state, {"loss": 1.5})
    assert ck.latest_step(tmp_path) == 3
    restored, extra = ck.restore(tmp_path, 3, jax.tree.map(jnp.zeros_like, state))
    assert extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro import checkpoint as ck

    ck.save(tmp_path, 1, _tiny_state())
    bad = _tiny_state()
    bad["params"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError, match="shape"):
        ck.restore(tmp_path, 1, bad)


def test_async_checkpointer(tmp_path):
    from repro import checkpoint as ck

    acp = ck.AsyncCheckpointer(tmp_path)
    state = _tiny_state()
    for s in (1, 2, 3):
        acp.save(s, state, {"s": s})
    acp.close()
    assert ck.latest_step(tmp_path) == 3


@pytest.mark.slow
def test_train_restart_determinism(tmp_path):
    """Training N steps straight == training k, restarting, training N-k."""
    from repro.launch import train as T

    common = [
        "--arch", "resnet-50", "--smoke", "--batch", "2", "--img", "32", "--seed", "3",
        "--total-steps", "8",
    ]
    full = T.main(common + ["--steps", "8"])
    part = T.main(common + ["--steps", "4", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    resumed = T.main(
        common + ["--steps", "8", "--ckpt-dir", str(tmp_path), "--ckpt-every", "100", "--resume"]
    )
    assert resumed["last_loss"] == pytest.approx(full["last_loss"], rel=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_counter_mode_determinism():
    from repro.data import DataSpec, SyntheticStream

    a = configs.get("qwen3-0.6b", smoke=True)
    a = dataclasses.replace(a, shapes=(ShapeSpec("t", "train", 2, seq=16),))
    s1 = SyntheticStream(DataSpec(a, a.shape("t"), seed=5))
    s2 = SyntheticStream(DataSpec(a, a.shape("t"), seed=5))
    b1, b2 = s1.batch_at(42), s2.batch_at(42)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    assert not np.array_equal(s1.batch_at(42)["tokens"], s1.batch_at(43)["tokens"])


def test_data_iterator_skip_ahead():
    from repro.data import DataSpec, SyntheticStream, make_batch_iterator

    a = configs.get("qwen3-0.6b", smoke=True)
    a = dataclasses.replace(a, shapes=(ShapeSpec("t", "train", 2, seq=16),))
    stream = SyntheticStream(DataSpec(a, a.shape("t"), seed=5))
    it = make_batch_iterator(stream, start_step=10, prefetch=1)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], stream.batch_at(10)["tokens"])


# ---------------------------------------------------------------------------
# fault tolerance / elasticity
# ---------------------------------------------------------------------------


def test_heartbeat_detection():
    from repro.runtime import HeartbeatMonitor, WorkerState

    t = [0.0]
    mon = HeartbeatMonitor(interval_s=1.0, suspect_after=2.0, dead_after=5.0, clock=lambda: t[0])
    for w in ("a", "b"):
        mon.register(w)
    t[0] = 1.5
    mon.beat("a")
    t[0] = 3.0  # b silent for 3s -> suspect
    changed = mon.sweep()
    assert changed == {"b": WorkerState.SUSPECT}
    t[0] = 7.0  # b silent for 7s -> dead; a silent 5.5 -> dead too
    changed = mon.sweep()
    assert changed["b"] is WorkerState.DEAD
    assert "b" in mon.dead()


def test_straggler_detection_and_mitigation():
    from repro.runtime import StragglerMitigator

    m = StragglerMitigator(threshold=1.5, min_samples=3)
    for step in range(5):
        for w in range(8):
            m.observe(f"w{w}", 1.0)
        m.observe("slow", 2.5)
    assert m.stragglers() == ["slow"]
    assert m.mitigation("slow") == "rebalance_input"
    for _ in range(10):
        m.observe("slow", 10.0)
    assert m.mitigation("slow") == "replace"


def test_elastic_remesh_plans():
    from repro.runtime import plan_elastic_remesh

    # full 2 pods
    p = plan_elastic_remesh(512)
    assert p.mesh_shape == (2, 16, 16)
    # lost part of a pod: model axis preserved, data axis takes the survivors
    p = plan_elastic_remesh(300)
    assert p.mesh_shape == (18, 16) and p.dropped_chips == 300 - 288
    # deeper loss: shrink data axis further, keep model axis
    p = plan_elastic_remesh(200)
    assert p.mesh_shape == (12, 16)
    with pytest.raises(ValueError):
        plan_elastic_remesh(8)


def test_checkpoint_restore_resharded(tmp_path):
    from repro import checkpoint as ck

    state = _tiny_state()
    ck.save(tmp_path, 1, state)
    shardings = jax.tree.map(lambda x: None, state)
    restored, _ = ck.restore_resharded(tmp_path, 1, state, shardings)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_sharding_rules_guards():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh

    from repro.sharding.rules import MeshRules, train_rules

    mesh = make_host_mesh(data=1, model=1)
    rules = MeshRules(mesh, {"a": "data", "b": "model", "c": ("data", "model")})
    # extent 1 -> everything replicated
    assert rules._resolve((8, 8), ("a", "b")) == P()


def test_sharding_divisibility_and_reuse(monkeypatch):
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import MeshRules, train_rules
mesh = make_host_mesh(data=2, model=4)
rules = MeshRules(mesh, train_rules(mesh))
# divisible dims shard; non-divisible are skipped
assert rules._resolve((8, 12), ("embed", "mlp")) == P("data", "model")
assert rules._resolve((8, 10), ("embed", "mlp")) == P("data"), rules._resolve((8,10),("embed","mlp"))
# the same mesh axis is never used twice in one spec
got = rules._resolve((8, 8, 4), ("mlp", "heads", "kv_heads"))
assert got == P("model"), got
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, cwd="."
    )
    assert "OK" in out.stdout, out.stderr


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 on the same global batch == a single full-batch step
    (the elastic lever that preserves batch semantics on a shrunk mesh)."""
    a = configs.get("vit-s16", smoke=True)
    a = dataclasses.replace(a, shapes=(ShapeSpec("t", "classify_train", 4, img=32),))
    kw = dict(adamw=optim.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10, grad_clip=0.0))
    p1 = steps.build_cell(a, "t", **kw)
    p2 = steps.build_cell(a, "t", accum_steps=2, **kw)
    ts1 = p1.init_args(jax.random.key(0))[0]
    ts2 = p2.init_args(jax.random.key(0))[0]
    batch = p1.init_args(jax.random.key(1))[1]
    ts1, m1 = p1.jit()(ts1, batch)
    ts2, m2 = p2.jit()(ts2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]), rel=5e-2)
    # bf16 microbatch rounding + Adam's ~sign(g)*lr first step means per-param
    # agreement is only up to the update magnitude; bound by 2.5*lr.
    for x, y in zip(jax.tree.leaves(ts1["params"]), jax.tree.leaves(ts2["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2.5e-3)
